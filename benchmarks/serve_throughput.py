"""Serving throughput: paged vs contiguous continuous batching vs static
length bucketing, plus oversubscribed admission vs worst-case reservation.

Three traces (field-by-field output reference: ``docs/benchmarks.md``):

* **mixed** — prompt lengths cycle, generation lengths vary: the workload
  where static bucketing loses (it pads every batch to the bucket length,
  cannot refill a finished row, and serializes buckets).
* **shared-prefix** — every request starts with the same system prompt.
  The paged engine maps the shared full blocks into each request's block
  table (refcount++, prefill skipped) so the common prefix is resident
  ONCE; the report includes peak KV bytes resident next to tokens/sec,
  paged-shared vs paged-unshared vs the contiguous reservation.
* **long-tail oversubscribed** — mixed ``max_new_tokens`` with a heavy
  tail, served through a pool too small for the worst-case reservations
  of all admitted requests.  Worst-case admission (``reserved``)
  serializes the queue and idles the pool; ``oversubscribed`` admission
  reserves prompt-sized budgets, preempts a victim when the pool runs dry
  mid-decode, and resumes it losslessly — same tokens (asserted against
  an ample-pool ``uncontended`` run), fewer scheduler ticks, higher
  utilization.

``--chaos`` adds a fourth section (:func:`run_chaos`): the mixed trace
re-served through the deterministic chaos harness — scripted host
crashes with snapshot/restore, drafter and kernel faults, forced
preemptions, an interrupted snapshot write — plus a QoS trace with SLO
classes, deadlines and load shedding.  Everything it reports (snapshots
taken, requests shed, degradations, the ``bit_identical`` flag) is a
pure function of the trace, so the fields gate in CI like any counter.

``--check`` turns the claims into assertions (the CI gate): the
oversubscribed arm must observe >= 1 preemption, emit token streams
bit-identical to the uncontended run, and spend fewer decode ticks than
worst-case reservation — all scheduling-level counters, deterministic on
any host.  With ``--chaos`` it also asserts the fault storm changed no
token and the shed/truncation sets are exact.  ``--out`` writes every
trace's rows to ``results/BENCH_serve.json``.

    PYTHONPATH=src python benchmarks/serve_throughput.py
    PYTHONPATH=src python benchmarks/serve_throughput.py --impl bitstopper_xla
    PYTHONPATH=src python benchmarks/serve_throughput.py --smoke --check
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

if "--host-devices" in sys.argv:
    # Must land in XLA_FLAGS before jax is imported: forces N host (CPU)
    # devices so --mesh runs on a single-machine CI runner.
    _n = int(sys.argv[sys.argv.index("--host-devices") + 1])
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count"
                                 f"={_n}")

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.serving import (
    ContinuousBatchingEngine,
    FaultPlan,
    PagedEngine,
    Request,
    ServeConfig,
    StaticBucketEngine,
    serve_with_chaos,
)


def make_trace(rng, vocab, n_requests, lens, new_lo, new_hi,
               shared_prefix=0):
    """Heterogeneous trace: prompt lengths cycle through `lens`, generation
    lengths vary — the shape that defeats static bucketing.  With
    ``shared_prefix`` every prompt carries the same leading system prompt
    (an int draws one of that length; an array is used verbatim)."""
    if isinstance(shared_prefix, (int, np.integer)):
        prefix = rng.integers(0, vocab, shared_prefix, dtype=np.int32)
    else:
        prefix = np.asarray(shared_prefix, np.int32)
    reqs = []
    for i in range(n_requests):
        tail = rng.integers(0, vocab, int(lens[i % len(lens)]),
                            dtype=np.int32)
        reqs.append(Request(
            prompt=np.concatenate([prefix, tail]),
            max_new_tokens=int(rng.integers(new_lo, new_hi + 1))))
    return reqs


def _timed(engine, trace, seed, publish=None, warm_full=False):
    # Warm-up on a full same-shaped copy of the trace (short generations):
    # every jit shape the engine will hit — per-bucket prefill and decode
    # batch shapes included — compiles outside the timed region.  The jit
    # caches live on the engine instance, so the SAME instance is measured.
    # ``warm_full`` replays the trace's real generation lengths instead:
    # an oversubscribed engine only hits its preemption-resume prefill
    # shapes when the pool actually runs dry, which short warm generations
    # never trigger.
    warm = [Request(prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens if warm_full else 2)
            for r in trace]
    engine.generate(warm, seed=seed)
    if hasattr(engine, "pool"):
        # Fresh pool: the warm-up served the SAME prompts, so its prefix
        # registry would let the timed run skip prefill entirely —
        # replay caching, not the cross-request sharing being measured.
        # (Stale device blocks are unobservable: tables are zeroed and
        # reads are fill-level masked.)
        from repro.serving import KVBlockPool
        engine.pool = KVBlockPool(engine.layout.pool_blocks,
                                  engine.layout.page_size,
                                  prefix_sharing=engine.scfg.prefix_sharing)
        if publish is not None and len(publish):
            # Steady-state framing for the shared-prefix trace: the system
            # prompt is resident from prior traffic.  Only the SHARED
            # prefix is published — per-request tails still prefill.
            engine.generate([Request(prompt=np.asarray(publish, np.int32),
                                     max_new_tokens=1)], seed=seed)
        engine.pool.peak_live_blocks = 0
    if hasattr(engine, "counters"):
        engine.counters = {k: 0 for k in engine.counters}

    reqs = [Request(prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens)
            for r in trace]
    t0 = time.monotonic()
    engine.generate(reqs, seed=seed)
    dt = time.monotonic() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    return n_tok, dt, engine, reqs


def _row(name, engine, n_tok, dt):
    row = {"engine": name, "tokens": n_tok, "seconds": dt,
           "tok_per_s": n_tok / dt}
    if hasattr(engine, "counters"):
        row.update(engine.counters)
    if isinstance(engine, (PagedEngine, ContinuousBatchingEngine)):
        row["kv_bytes_resident"] = engine.kv_bytes_resident()
    return row


def run(arch="stablelm-1.6b", impl="xla", alpha=0.6, n_requests=8,
        slots=4, seed=0, lens=(8, 24, 40), new_lo=8, new_hi=24, mesh=None):
    """Mixed-length trace: paged vs contiguous vs static-bucket.  With a
    ``mesh``, a fourth ``paged-sharded`` arm serves the same trace over the
    (data, model) device mesh and must emit bit-identical tokens."""
    cfg = reduced_config(arch).replace(
        attn_impl=impl, bitstopper=BitStopperConfig(alpha=alpha))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    max_len = max(lens) + new_hi + 8
    scfg = ServeConfig(max_len=max_len, max_slots=slots, prefill_bucket=8,
                       page_size=8)

    rng = np.random.default_rng(seed)
    trace = make_trace(rng, cfg.vocab, n_requests, lens, new_lo, new_hi)

    rows, outs = [], {}
    arms = [
        ("paged", PagedEngine(cfg, params, scfg)),
        ("continuous", ContinuousBatchingEngine(cfg, params, scfg)),
        ("static-bucket", StaticBucketEngine(cfg, params, scfg)),
    ]
    if mesh is not None:
        arms.append(("paged-sharded", PagedEngine(
            cfg, params, dataclasses.replace(scfg, mesh=mesh))))
    for name, eng in arms:
        n, dt, eng, reqs = _timed(eng, trace, seed)
        row = _row(name, eng, n, dt)
        if name == "paged-sharded":
            row["mesh"] = dict(zip(mesh.axis_names, mesh.devices.shape))
        rows.append(row)
        outs[name] = [r.generated for r in reqs]
    if mesh is not None:
        # The standing serving invariant, now across devices: the sharded
        # engine must re-serve the exact single-device token streams.
        assert outs["paged-sharded"] == outs["paged"], \
            "sharded serving diverged from single-device paged serving"
    return rows


def run_shared_prefix(arch="stablelm-1.6b", impl="xla", alpha=0.6,
                      n_requests=8, slots=4, seed=0, prefix_len=48,
                      tail_lens=(4, 12, 20), new_lo=8, new_hi=16):
    """Shared-prefix trace (common system prompt): tokens/sec and peak KV
    bytes resident, paged-shared vs paged-unshared vs contiguous."""
    cfg = reduced_config(arch).replace(
        attn_impl=impl, bitstopper=BitStopperConfig(alpha=alpha))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    max_len = prefix_len + max(tail_lens) + new_hi + 8
    base = dict(max_len=max_len, max_slots=slots, prefill_bucket=8,
                page_size=8)

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, prefix_len, dtype=np.int32)
    trace = make_trace(rng, cfg.vocab, n_requests, tail_lens, new_lo,
                       new_hi, shared_prefix=prefix)

    rows = []
    for name, eng in (
        ("paged-shared",
         PagedEngine(cfg, params, ServeConfig(**base))),
        ("paged-unshared",
         PagedEngine(cfg, params,
                     ServeConfig(**base, prefix_sharing=False))),
        ("contiguous",
         ContinuousBatchingEngine(cfg, params, ServeConfig(**base))),
    ):
        n, dt, eng, _ = _timed(eng, trace, seed, publish=prefix)
        rows.append(_row(name, eng, n, dt))
    return rows


def run_oversubscribed(arch="stablelm-1.6b", impl="xla", alpha=0.6,
                       n_requests=8, slots=4, seed=0, lens=(8, 16, 12),
                       new_short=8, new_long=48, long_every=3,
                       pool_blocks=None, check=False):
    """Long-tail oversubscribed trace: most requests generate a few
    tokens' worth of ``max_new_tokens`` budget, every ``long_every``-th
    carries a worst case ``new_long`` budget — and every request runs its
    budget to the end, so the *reservation* gap (not an eos lottery) is
    what the arms differ on.  The pool is sized for roughly the actual
    long-tail footprint: far below the sum of worst-case reservations.

    Arms: ``reserved`` (worst-case admission, same small pool: the head
    of line blocks until capacity frees — utilization idles),
    ``oversubscribed`` (prompt-sized reservations + victim preemption),
    and ``uncontended`` (ample pool — the losslessness reference)."""
    cfg = reduced_config(arch).replace(
        attn_impl=impl, bitstopper=BitStopperConfig(alpha=alpha))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    page = 8
    max_len = max(lens) + new_long + 8
    rng = np.random.default_rng(seed)
    trace = make_trace(rng, cfg.vocab, n_requests, lens, new_short,
                       new_short)
    for i in range(0, n_requests, long_every):
        trace[i].max_new_tokens = new_long
    if pool_blocks is None:
        # Roughly the long-tail working set: every request's prompt + the
        # SHORT generation budget, plus one long tail — far below the
        # worst case `sum(prompt + new_long)` a reserved admission needs
        # to run all slots concurrently.
        need = sum(-(-(len(r.prompt) + new_short) // page) for r in trace)
        pool_blocks = 1 + max(need // 2, -(-(max(lens) + new_long) // page) + 2)
    base = dict(max_len=max_len, max_slots=slots, prefill_bucket=8,
                page_size=page, prefix_sharing=False)

    rows, outs = [], {}
    for name, scfg in (
        ("uncontended", ServeConfig(**base)),
        ("reserved", ServeConfig(**base, pool_blocks=pool_blocks)),
        ("oversubscribed", ServeConfig(**base, pool_blocks=pool_blocks,
                                       oversubscribe=True)),
    ):
        n, dt, eng, reqs = _timed(PagedEngine(cfg, params, scfg), trace,
                                  seed, warm_full=True)
        row = _row(name, eng, n, dt)
        row["pool_blocks"] = eng.layout.pool_blocks
        row["peak_live_blocks"] = eng.pool.peak_live_blocks
        # What a worst-case-reserved pool would need to admit the same
        # peak concurrency this arm reached — the residency the
        # oversubscribed scheduler stops paying for.
        row["worst_case_blocks"] = sum(
            -(-(len(r.prompt) + r.max_new_tokens - 1) // page)
            for r in trace)
        rows.append(row)
        outs[name] = [r.generated for r in reqs]

    if check:
        over = next(r for r in rows if r["engine"] == "oversubscribed")
        res = next(r for r in rows if r["engine"] == "reserved")
        unc = next(r for r in rows if r["engine"] == "uncontended")
        assert over["preemptions"] >= 1, \
            f"oversubscribed trace saw no preemption ({over})"
        assert outs["oversubscribed"] == outs["uncontended"], \
            "oversubscribed tokens diverged from the uncontended run"
        assert outs["reserved"] == outs["uncontended"], \
            "reserved tokens diverged from the uncontended run"
        assert over["decode_steps"] < res["decode_steps"], \
            (f"oversubscription should serve the trace in fewer ticks: "
             f"{over['decode_steps']} vs {res['decode_steps']}")
        assert unc["preemptions"] == 0 and res["preemptions"] == 0
    return rows


def run_chaos(arch="stablelm-1.6b", impl="xla", alpha=0.6, seed=0,
              check=False):
    """Chaos section (docs/robustness.md): two scenarios, four arms, all
    scheduling fields deterministic.

    **Fault storm** — a mixed trace (shared system prompt + n-gram
    speculative decoding + oversubscribed pool) served twice: once
    undisturbed, once through :func:`serve_with_chaos` under a scripted
    :class:`FaultPlan` (host crashes with snapshot/restore, a drafter
    failure, a forced pool-dry preemption, an interrupted snapshot write
    — plus a fused-kernel fault and circuit-breaker degrade when the
    fused BitStopper kernel is on).  The ``bit_identical`` field records
    the headline claim: the fault storm must not change one token.

    **QoS** — a saturated trace with SLO classes, a shed watermark and
    per-request deadlines, against a no-QoS reference: sheds are exact
    (``shed_rids``), deadline truncation keeps every emitted stream a
    prefix of the reference, and both sets are pure functions of the
    trace — committed into the smoke baseline like any counter."""
    import tempfile

    cfg = reduced_config(arch).replace(
        attn_impl=impl, bitstopper=BitStopperConfig(alpha=alpha))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    fused = impl == "bitstopper_xla"
    rng = np.random.default_rng(seed)

    # --- fault-storm scenario -----------------------------------------
    # Generations deliberately run past the prompt+1-block oversubscribed
    # reservation, so decode makes *unreserved* claims — the seam the
    # scripted pool_dry fault (and natural preemption) bites on.
    prefix_len, tail_lens, new_lo, new_hi = 16, (4, 9, 6), 14, 20
    prefix = rng.integers(0, cfg.vocab, prefix_len, dtype=np.int32)
    trace = make_trace(rng, cfg.vocab, 6, tail_lens, new_lo, new_hi,
                       shared_prefix=prefix)
    scfg = ServeConfig(max_len=prefix_len + max(tail_lens) + new_hi + 8,
                       max_slots=3, prefill_bucket=8, page_size=8,
                       pool_blocks=16, oversubscribe=True,
                       speculative="ngram", fused_decode=fused,
                       snapshot_every=2)

    def copies():
        return [Request(prompt=r.prompt.copy(),
                        max_new_tokens=r.max_new_tokens) for r in trace]

    rows = []
    ref = copies()
    t0 = time.monotonic()
    eng = PagedEngine(cfg, params, scfg)
    eng.generate(ref, seed=seed)
    row = _row("undisturbed", eng, sum(len(r.generated) for r in ref),
               time.monotonic() - t0)
    row["pool_blocks"] = eng.layout.pool_blocks
    rows.append(row)

    events = [("crash", 2), ("drafter_fail", 3), ("pool_dry", 5),
              ("checkpoint_interrupt", 6), ("crash", 8)]
    if fused:
        events.append(("kernel_fail", 2))
    plan = FaultPlan.scripted(events)
    snap_dir = tempfile.mkdtemp(prefix="bench_chaos_")
    t0 = time.monotonic()
    creqs, rep = serve_with_chaos(
        lambda: PagedEngine(cfg, params, scfg), copies(), seed=seed,
        plan=plan, snapshot_dir=snap_dir)
    dt = time.monotonic() - t0
    c = rep["engine_counters"]
    crow = {"engine": "chaos", "tokens": sum(len(r.generated)
                                             for r in creqs),
            "seconds": dt, "tok_per_s": sum(len(r.generated)
                                            for r in creqs) / dt}
    crow.update(c)
    crow.update({k: rep[k] for k in
                 ("crashes", "restores", "snapshots_taken",
                  "snapshots_interrupted", "staging_reclaimed")})
    crow["fired_by_kind"] = rep["fired_by_kind"]
    crow["bit_identical"] = ([r.generated for r in creqs]
                             == [r.generated for r in ref])
    crow["pool_blocks"] = scfg.pool_blocks
    rows.append(crow)

    # --- QoS scenario --------------------------------------------------
    qlens = (9,)
    qtrace = make_trace(rng, cfg.vocab, 4, qlens, 8, 8)
    qtrace[0].max_new_tokens = 10

    def qcopies(qos):
        out = []
        for i, r in enumerate(qtrace):
            out.append(Request(
                prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens,
                slo="standard" if i == 0 else "besteffort",
                deadline_ticks=6 if (qos and i == 0) else None))
        return out

    qbase = dict(max_len=64, max_slots=4, prefill_bucket=8, page_size=8,
                 pool_blocks=6, oversubscribe=True)
    qref = qcopies(qos=False)
    t0 = time.monotonic()
    eng = PagedEngine(cfg, params, ServeConfig(**qbase))
    eng.generate(qref, seed=seed)
    rows.append(_row("qos-reference", eng,
                     sum(len(r.generated) for r in qref),
                     time.monotonic() - t0))

    qreqs = qcopies(qos=True)
    t0 = time.monotonic()
    eng = PagedEngine(cfg, params,
                      ServeConfig(**qbase, shed_watermark=0.5))
    eng.generate(qreqs, seed=seed)
    qrow = _row("qos", eng, sum(len(r.generated) for r in qreqs),
                time.monotonic() - t0)
    qrow["shed_rids"] = sorted(r.rid for r in qreqs if r.shed_reason)
    qrow["truncated_rids"] = sorted(r.rid for r in qreqs
                                    if r.deadline_hit)
    rows.append(qrow)

    if check:
        assert crow["bit_identical"], \
            "fault storm changed the served tokens"
        assert crow["crashes"] >= 1 and crow["restores"] == crow["crashes"]
        assert crow["snapshots_interrupted"] >= 1
        assert crow["staging_reclaimed"] >= 1
        assert crow["drafter_failures"] >= 1
        assert crow["forced_preemptions"] >= 1, \
            "pool_dry fault never forced a preemption"
        assert crow["degradations"] == (1 if fused else 0)
        assert qrow["requests_shed"] >= 1 and qrow["shed_watermark"] >= 1
        assert qrow["deadline_truncated"] >= 1
        by_rid = {r.rid: r for r in qref}
        for r in qreqs:
            if r.shed_reason:
                assert r.slo == "besteffort" and not r.generated
            else:
                assert r.generated == by_rid[r.rid].generated[
                    :len(r.generated)], \
                    f"rid {r.rid} diverged from the QoS-free reference"
        assert qreqs[0].deadline_hit and \
            len(qreqs[0].generated) < qtrace[0].max_new_tokens
    return rows


def run_hierarchy(arch="stablelm-1.6b", impl="xla", alpha=0.6, seed=0,
                  check=False):
    """Memory-hierarchy section (docs/serving.md "Memory hierarchy"): two
    deterministic scenarios, four arms, gated on counters and token
    bit-identity — never wall clock.

    **Swap-to-host resume** — an oversubscribed preempting trace served
    twice: ``recompute-resume`` (no host budget: victims re-prefill from
    their token history) vs ``swap-resume`` (victim KV device→host
    copied at preemption, resume splices it back).  The gate is the
    losslessness claim pinned by tests/test_swap.py: identical tokens,
    every swap-out consumed by a splice (no recompute fallbacks), and
    strictly fewer resume prefill chunks than the recompute arm.

    **Persistent prefix store** — a shared-system-prompt trace served by
    a seeding engine whose registered prefix blocks are flushed to an
    on-disk store (graceful shutdown), then re-served by a ``store-cold``
    engine (no store) and a ``store-warmed`` restarted engine: the warm
    arm must re-emit the cold arm's tokens while prefilling strictly
    fewer chunks (>=1 store hit)."""
    import tempfile

    cfg = reduced_config(arch).replace(
        attn_impl=impl, bitstopper=BitStopperConfig(alpha=alpha))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)

    # --- swap-to-host resume ------------------------------------------
    # Lengths sized so a third admission preempts a decoding victim that
    # owns >1 block of exclusive KV — enough history that recompute
    # resume pays visibly more prefill chunks than a splice.
    trace = make_trace(rng, cfg.vocab, 3, (12, 9, 11), 16, 16)
    base = dict(max_len=64, max_slots=3, prefill_bucket=8, page_size=8,
                pool_blocks=10, oversubscribe=True)
    rows, outs = [], {}
    for name, scfg in (
        ("recompute-resume", ServeConfig(**base)),
        ("swap-resume", ServeConfig(**base, swap_host_bytes=1 << 22)),
    ):
        n, dt, eng, reqs = _timed(PagedEngine(cfg, params, scfg), trace,
                                  seed, warm_full=True)
        row = _row(name, eng, n, dt)
        row["pool_blocks"] = eng.layout.pool_blocks
        row.update({k: v for k, v in eng.memory_report().items()
                    if k in ("host_swap_bytes", "host_swap_bytes_peak")})
        rows.append(row)
        outs[name] = [r.generated for r in reqs]

    # --- persistent prefix store --------------------------------------
    store_dir = tempfile.mkdtemp(prefix="bench_prefix_store_")
    prefix = rng.integers(0, cfg.vocab, 16, dtype=np.int32)
    strace = make_trace(rng, cfg.vocab, 3, (6, 9, 7), 8, 8,
                        shared_prefix=prefix)
    # prefill_chunk=8: store injection only covers whole chunk groups, so
    # the chunk boundary must not exceed the 16-token system prompt.
    sbase = dict(max_len=64, max_slots=2, prefill_bucket=8, page_size=8,
                 prefill_chunk=8)

    def copies(trace_):
        return [Request(prompt=r.prompt.copy(),
                        max_new_tokens=r.max_new_tokens) for r in trace_]

    seeder = PagedEngine(cfg, params,
                         ServeConfig(**sbase, prefix_store_dir=store_dir))
    seeder.generate(copies(strace), seed=seed)
    flushed = seeder.flush_prefixes()
    del seeder

    for name, scfg in (
        ("store-cold", ServeConfig(**sbase)),
        ("store-warmed", ServeConfig(**sbase,
                                     prefix_store_dir=store_dir)),
    ):
        reqs = copies(strace)
        t0 = time.monotonic()
        eng = PagedEngine(cfg, params, scfg)
        eng.generate(reqs, seed=seed)
        dt = time.monotonic() - t0
        row = _row(name, eng, sum(len(r.generated) for r in reqs), dt)
        row["disk_prefix_bytes"] = eng.memory_report()["disk_prefix_bytes"]
        if name == "store-warmed":
            row["prefix_records_flushed"] = flushed
        rows.append(row)
        outs[name] = [r.generated for r in reqs]

    if check:
        swp = next(r for r in rows if r["engine"] == "swap-resume")
        rec = next(r for r in rows if r["engine"] == "recompute-resume")
        assert outs["swap-resume"] == outs["recompute-resume"], \
            "swap-resume tokens diverged from recompute-resume"
        assert swp["preemptions"] >= 1 and rec["preemptions"] >= 1, \
            "hierarchy trace never preempted"
        assert swp["swap_outs"] >= 1 and \
            swp["swap_ins"] == swp["swap_outs"] and \
            swp["swap_fallbacks"] == 0, \
            f"swap arm did not splice every swap-out back ({swp})"
        assert rec["swap_outs"] == 0 and rec["swap_ins"] == 0
        assert swp["prefill_chunks"] < rec["prefill_chunks"], \
            (f"swap resume should re-prefill fewer chunks: "
             f"{swp['prefill_chunks']} vs {rec['prefill_chunks']}")
        assert swp["host_swap_bytes"] == 0, \
            "swap records leaked past their resume"

        wrm = next(r for r in rows if r["engine"] == "store-warmed")
        cld = next(r for r in rows if r["engine"] == "store-cold")
        assert outs["store-warmed"] == outs["store-cold"], \
            "store-warmed tokens diverged from the cold engine"
        assert wrm["prefix_store_hits"] >= 1 and \
            wrm["prefix_store_tokens"] >= 16, \
            f"store warm start never hit the disk store ({wrm})"
        assert wrm["prefill_chunks"] < cld["prefill_chunks"], \
            (f"store warm start should prefill fewer chunks: "
             f"{wrm['prefill_chunks']} vs {cld['prefill_chunks']}")
        assert cld["prefix_store_hits"] == 0
    return rows


def _percentile(values, q):
    return float(np.percentile(np.asarray(values, np.float64), q)) \
        if values else None


def _reset_serving_state(eng):
    """Post-warmup reset: fresh pool, zeroed counters, EMPTY rid space —
    the async arms' bit-identity gate needs door-assigned rids to start
    at 0 exactly like the synchronous reference trace."""
    from repro.serving import KVBlockPool
    eng.pool = KVBlockPool(eng.layout.pool_blocks, eng.layout.page_size,
                           prefix_sharing=eng.scfg.prefix_sharing)
    eng.counters = {k: 0 for k in eng.counters}
    eng.requests.clear()
    eng._next_rid = 0
    eng.ticks = 0


def run_async(arch="stablelm-1.6b", impl="xla", alpha=0.6, n_requests=8,
              slots=4, seed=0, lens=(8, 24, 40), new_lo=8, new_hi=24,
              check=False):
    """Async front-door arms: the mixed trace served through
    ``AsyncFrontDoor`` streams, colocated (paged backend) and
    disaggregated (prefill engine -> transfer queue -> decode engine).

    Deterministic gated fields: streamed tokens must be bit-identical to
    the synchronous ``PagedEngine`` trace (``bit_identical``), the
    fairness scheduler's ``admission_order`` and the SLA mapper's
    ``deadline_ticks_mapped`` are exact, TTFT percentiles are reported in
    engine *ticks* (``ttft_ticks_*``), and the disaggregation arm's
    transfer-queue counters are exact.  Wall-clock TTFT/TPOT percentiles
    (``ttft_ms_*``/``tpot_ms_*``) ride along for humans and are never
    gated (scripts/check_bench.py skips wall-clock fields)."""
    import asyncio

    from repro.runtime import ManualClock
    from repro.serving.frontdoor import (AsyncFrontDoor, DisaggController,
                                         SlaMapper)

    cfg = reduced_config(arch).replace(
        attn_impl=impl, bitstopper=BitStopperConfig(alpha=alpha))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    max_len = max(lens) + new_hi + 8
    base = dict(max_len=max_len, prefill_bucket=8, page_size=8)

    rng = np.random.default_rng(seed)
    trace = make_trace(rng, cfg.vocab, n_requests, lens, new_lo, new_hi)
    # SLO classes cycle so fairness admission visibly reorders (rids are
    # pinned at arrival, so reordering is token-neutral — the gate).
    slos = [("besteffort", "strict", "standard")[i % 3]
            for i in range(n_requests)]
    # Every request carries a wall-clock deadline; the ManualClock never
    # advances, so the mapper keeps its default tick estimate and the
    # wall->tick mapping is a deterministic, gateable constant.
    sla = SlaMapper(granularity=1e-3, default_tick_s=1e-2)
    deadline_s = 2.0
    deadline_ticks = sla.ticks_for(deadline_s)

    ref = [Request(prompt=r.prompt.copy(),
                   max_new_tokens=r.max_new_tokens) for r in trace]
    PagedEngine(cfg, params,
                ServeConfig(max_slots=slots, **base)).generate(ref, seed=seed)
    ref_tokens = [r.generated for r in ref]
    # The door admits round-robin: one request per non-empty SLO class
    # per cycle, strict first.
    classed = {c: [i for i in range(n_requests) if slos[i] == c]
               for c in ("strict", "standard", "besteffort")}
    expected_admission = []
    while any(classed.values()):
        for c in ("strict", "standard", "besteffort"):
            if classed[c]:
                expected_admission.append(classed[c].pop(0))

    def drive(door):
        """Submit the trace, run the door, stream every request; returns
        (per-rid token lists, wall timings)."""
        async def go():
            t_sub = {}
            rids = []
            for r, slo in zip(trace, slos):
                rid = door.submit(r.prompt.copy(),
                                  max_new_tokens=r.max_new_tokens,
                                  slo=slo, deadline_s=deadline_s)
                t_sub[rid] = time.monotonic()
                rids.append(rid)
            task = asyncio.create_task(door.run())

            async def collect(rid):
                toks, stamps = [], []
                async for tok in door.stream(rid):
                    toks.append(tok)
                    stamps.append(time.monotonic())
                return rid, toks, stamps

            gathered = asyncio.gather(*(collect(r) for r in rids))
            door.shutdown("drain")
            results = await gathered
            await task
            return rids, results, t_sub

        t0 = time.monotonic()
        rids, results, t_sub = asyncio.run(go())
        dt = time.monotonic() - t0
        toks = {rid: t for rid, t, _ in results}
        ttft_ms = [1e3 * (s[0] - t_sub[rid]) for rid, t, s in results if s]
        tpot_ms = [1e3 * (s[-1] - s[0]) / (len(s) - 1)
                   for _, _, s in results if len(s) > 1]
        return rids, toks, dt, ttft_ms, tpot_ms

    def arm_row(name, door, backend_counters):
        rids, toks, dt, ttft_ms, tpot_ms = drive(door)
        n_tok = sum(len(t) for t in toks.values())
        ticks = sorted(door.first_token_tick[rid] for rid in rids)
        row = {"engine": name, "tokens": n_tok, "seconds": dt,
               "tok_per_s": n_tok / dt,
               "bit_identical": [toks[r] for r in rids] == ref_tokens,
               "admission_order": list(door.admission_log),
               "ticks_run": door.ticks_run,
               "deadline_ticks_mapped": deadline_ticks,
               "ttft_ticks_p50": _percentile(ticks, 50),
               "ttft_ticks_p95": _percentile(ticks, 95),
               "ttft_ms_p50": _percentile(ttft_ms, 50),
               "ttft_ms_p95": _percentile(ttft_ms, 95),
               "tpot_ms_p50": _percentile(tpot_ms, 50)}
        row.update(backend_counters())
        if check:
            assert row["bit_identical"], \
                f"{name}: streamed tokens diverged from the synchronous " \
                f"paged trace"
            assert row["admission_order"] == expected_admission, \
                f"{name}: admission order {row['admission_order']} != " \
                f"round-robin expectation {expected_admission}"
            sub = door.backend.requests[rids[0]]
            assert sub.deadline_ticks == deadline_ticks
        return row

    # --- colocated: one paged engine behind the door -------------------
    eng = PagedEngine(cfg, params, ServeConfig(max_slots=slots, **base))
    eng.generate([Request(prompt=r.prompt.copy(), max_new_tokens=2)
                  for r in trace], seed=seed)              # warm jit shapes
    _reset_serving_state(eng)
    door = AsyncFrontDoor(eng, clock=ManualClock(), sla=sla, seed=seed)
    door.start()
    rows = [arm_row("async-colocated", door, lambda: dict(eng.counters))]

    # --- disaggregated: prefill engine -> transfer queue -> decode -----
    pe = PagedEngine(cfg, params,
                     ServeConfig(max_slots=max(1, slots // 2), **base))
    de = PagedEngine(cfg, params, ServeConfig(max_slots=slots, **base))
    ctl = DisaggController(pe, de)
    ctl.generate([Request(prompt=r.prompt.copy(), max_new_tokens=2)
                  for r in trace], seed=seed)              # warm both engines
    for e in (pe, de):
        _reset_serving_state(e)
    ctl.requests.clear()
    ctl.queue.clear()
    ctl._next_rid = 0
    ctl.ticks = 0
    ctl.xfer.counters = {k: 0 for k in ctl.xfer.counters}
    sla2 = SlaMapper(granularity=1e-3, default_tick_s=1e-2)
    door2 = AsyncFrontDoor(ctl, clock=ManualClock(), sla=sla2, seed=seed)
    door2.start()

    def disagg_counters():
        out = dict(de.counters)
        out.update(ctl.xfer.counters)
        return out

    drow = arm_row("async-disagg", door2, disagg_counters)
    if check:
        assert drow["prefixes_transferred"] == n_requests, \
            "every request must cross the transfer queue exactly once"
        assert drow["payload_bytes"] > 0 and drow["blocks_transferred"] > 0
    rows.append(drow)
    return rows


def _print_rows(title, rows):
    print(f"\n[serve_throughput] {title}")
    for r in rows:
        extra = ""
        if "decode_steps" in r:
            extra += (f"  decode_steps={r['decode_steps']}"
                      f" prefill_tokens={r['prefill_tokens']}")
        if "prefix_hit_tokens" in r:
            extra += f" prefix_hits={r['prefix_hit_tokens']}"
        if "kv_bytes_resident" in r:
            extra += f" kv_resident={r['kv_bytes_resident'] / 1024:.1f}KiB"
        print(f"  {r['engine']:>15}: {r['tokens']:4d} tokens in "
              f"{r['seconds']:6.2f}s = {r['tok_per_s']:7.1f} tok/s{extra}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "bitstopper_xla"])
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefix-len", type=int, default=48,
                    help="system-prompt length for the shared-prefix trace")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI: fewer/shorter requests")
    ap.add_argument("--check", action="store_true",
                    help="assert the oversubscription gate: >=1 "
                         "preemption, tokens bit-identical to the "
                         "uncontended run, fewer decode ticks than "
                         "worst-case reservation (with --chaos, also the "
                         "chaos gate: fault-storm tokens bit-identical, "
                         "sheds/truncations exact)")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="add the async front-door section: the mixed "
                         "trace streamed through AsyncFrontDoor, "
                         "colocated and disaggregated (prefill/decode "
                         "two-instance) — TTFT/TPOT percentiles plus the "
                         "deterministic gate (streamed tokens "
                         "bit-identical to the synchronous engine, exact "
                         "admission order, exact transfer counters)")
    ap.add_argument("--chaos", action="store_true",
                    help="add the chaos section: the mixed trace under a "
                         "scripted fault plan (crashes + snapshot/restore, "
                         "drafter/kernel faults, forced preemptions) plus "
                         "a QoS trace with deadlines and load shedding "
                         "(docs/robustness.md)")
    ap.add_argument("--hierarchy", action="store_true",
                    help="add the memory-hierarchy section: an "
                         "oversubscribed trace resumed by host swap-in "
                         "vs recompute, plus a cross-restart prefix-store "
                         "warm start (docs/serving.md, tests/test_swap.py)"
                         " — with --check, the losslessness gate "
                         "(bit-identical tokens, every swap-out spliced, "
                         ">=1 store hit, fewer prefill chunks)")
    ap.add_argument("--out", default=None,
                    help="write all trace rows to this JSON path "
                         "(default: results/BENCH_serve.json)")
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="add a paged-sharded arm to the mixed trace: "
                         "serve over a (data, model) mesh and assert "
                         "tokens bit-identical to the single-device paged "
                         "arm.  Needs dp*tp devices (see --host-devices)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force this many host (CPU) devices via XLA_FLAGS "
                         "(parsed before jax import; enables --mesh on a "
                         "single-machine runner)")
    args = ap.parse_args()

    mesh = None
    if args.mesh is not None:
        dp, tp = (int(x) for x in args.mesh.split(","))
        if dp * tp > len(jax.devices()):
            ap.error(f"--mesh {dp},{tp} needs {dp * tp} devices, "
                     f"{len(jax.devices())} visible (use --host-devices)")
        mesh = jax.make_mesh((dp, tp), ("data", "model"))

    kw = dict(arch=args.arch, impl=args.impl, alpha=args.alpha,
              n_requests=args.requests, slots=args.slots, seed=args.seed)
    if args.smoke:
        kw.update(n_requests=3, slots=2)
        rows = run(**kw, lens=(5, 9), new_lo=3, new_hi=4, mesh=mesh)
        srows = run_shared_prefix(**kw, prefix_len=16, tail_lens=(3, 7),
                                  new_lo=3, new_hi=4)
        orows = run_oversubscribed(**dict(kw, n_requests=3, slots=3),
                                   lens=(10, 7, 9), new_short=4,
                                   new_long=16, long_every=1,
                                   pool_blocks=10, check=args.check)
    else:
        rows = run(**kw, mesh=mesh)
        srows = run_shared_prefix(**kw, prefix_len=args.prefix_len)
        orows = run_oversubscribed(**kw, check=args.check)
    crows = None
    if args.chaos:
        crows = run_chaos(arch=args.arch, impl=args.impl, alpha=args.alpha,
                          seed=args.seed, check=args.check)
    hrows = None
    if args.hierarchy:
        hrows = run_hierarchy(arch=args.arch, impl=args.impl,
                              alpha=args.alpha, seed=args.seed,
                              check=args.check)
    arows = None
    if args.async_:
        akw = dict(kw, check=args.check)
        if args.smoke:
            arows = run_async(**akw, lens=(5, 9), new_lo=3, new_hi=4)
        else:
            arows = run_async(**akw)

    _print_rows(f"mixed trace arch={args.arch} impl={args.impl} "
                f"requests={kw['n_requests']} slots={kw['slots']}", rows)
    static = next(r for r in rows if r["engine"] == "static-bucket")
    speedup = rows[0]["tok_per_s"] / static["tok_per_s"]
    print(f"  paged/static throughput ratio: {speedup:.2f}x")
    if mesh is not None:
        print(f"  paged-sharded arm (mesh {args.mesh}): tokens bit-identical"
              f" to single-device paged")

    _print_rows(f"shared-prefix trace prefix_len="
                f"{16 if args.smoke else args.prefix_len}", srows)
    shared = next(r for r in srows if r["engine"] == "paged-shared")
    unshared = next(r for r in srows if r["engine"] == "paged-unshared")
    contig = next(r for r in srows if r["engine"] == "contiguous")
    print(f"  KV resident: shared {shared['kv_bytes_resident'] / 1024:.1f}KiB"
          f" vs unshared {unshared['kv_bytes_resident'] / 1024:.1f}KiB"
          f" vs contiguous {contig['kv_bytes_resident'] / 1024:.1f}KiB")

    _print_rows("long-tail oversubscribed trace", orows)
    over = next(r for r in orows if r["engine"] == "oversubscribed")
    res = next(r for r in orows if r["engine"] == "reserved")
    print(f"  pool: {over['pool_blocks']} blocks vs "
          f"{over['worst_case_blocks']} worst-case-reserved; "
          f"oversubscribed served in {over['decode_steps']} decode ticks "
          f"({over['preemptions']} preemptions) vs {res['decode_steps']} "
          f"reserved — "
          f"{res['decode_steps'] / max(over['decode_steps'], 1):.2f}x "
          f"fewer ticks, peak {over['peak_live_blocks']} live blocks")
    if args.check:
        print("[serve_throughput] oversubscription gate OK: preemption "
              "observed, tokens lossless, fewer ticks than worst-case "
              "reservation")

    if crows is not None:
        _print_rows("chaos trace (scripted fault plan + QoS)", crows)
        cr = next(r for r in crows if r["engine"] == "chaos")
        qr = next(r for r in crows if r["engine"] == "qos")
        print(f"  fault storm: {cr['crashes']} crashes / {cr['restores']} "
              f"restores, {cr['snapshots_taken']} snapshots "
              f"({cr['snapshots_interrupted']} interrupted), "
              f"{cr['degradations']} kernel degradations, "
              f"{cr['drafter_failures']} drafter failures, "
              f"{cr['forced_preemptions']} forced preemptions — "
              f"bit_identical={cr['bit_identical']}")
        print(f"  qos: shed rids {qr['shed_rids']} "
              f"(watermark {qr['shed_watermark']}, deadline "
              f"{qr['shed_deadline']}), truncated rids "
              f"{qr['truncated_rids']}")
        if args.check:
            print("[serve_throughput] chaos gate OK: fault-storm tokens "
                  "bit-identical, sheds and truncations exact")

    if hrows is not None:
        _print_rows("memory-hierarchy trace (swap + prefix store)", hrows)
        swp = next(r for r in hrows if r["engine"] == "swap-resume")
        rec = next(r for r in hrows if r["engine"] == "recompute-resume")
        wrm = next(r for r in hrows if r["engine"] == "store-warmed")
        cld = next(r for r in hrows if r["engine"] == "store-cold")
        print(f"  resume: {swp['swap_outs']} swap-outs / "
              f"{swp['swap_ins']} swap-ins ({swp['swap_in_tokens']} tokens"
              f" spliced, {swp['swap_fallbacks']} fallbacks) -> "
              f"{swp['prefill_chunks']} prefill chunks vs "
              f"{rec['prefill_chunks']} recompute; host swap peak "
              f"{swp['host_swap_bytes_peak']} bytes")
        print(f"  warm start: {wrm['prefix_store_hits']} store hits "
              f"({wrm['prefix_store_tokens']} tokens) -> "
              f"{wrm['prefill_chunks']} prefill chunks vs "
              f"{cld['prefill_chunks']} cold; "
              f"{wrm['prefix_records_flushed']} records / "
              f"{wrm['disk_prefix_bytes']} bytes on disk")
        if args.check:
            print("[serve_throughput] hierarchy gate OK: swap resume and "
                  "store warm start bit-identical, fewer prefill chunks, "
                  "swap records fully consumed")

    if arows is not None:
        _print_rows("async front-door trace (streamed)", arows)
        colo = next(r for r in arows if r["engine"] == "async-colocated")
        dis = next(r for r in arows if r["engine"] == "async-disagg")
        print(f"  streamed-vs-sync bit_identical: colocated "
              f"{colo['bit_identical']}, disagg {dis['bit_identical']}; "
              f"admission order {colo['admission_order']} "
              f"(deadline {colo['deadline_ticks_mapped']} ticks)")
        print(f"  TTFT p50/p95: colocated {colo['ttft_ticks_p50']:.0f}/"
              f"{colo['ttft_ticks_p95']:.0f} ticks "
              f"({colo['ttft_ms_p50']:.0f}/{colo['ttft_ms_p95']:.0f} ms), "
              f"TPOT p50 {colo['tpot_ms_p50']:.1f} ms")
        print(f"  disagg vs colocated: {dis['tok_per_s']:.1f} vs "
              f"{colo['tok_per_s']:.1f} tok/s; transfers: "
              f"{dis['prefixes_transferred']} prefixes / "
              f"{dis['blocks_transferred']} blocks / "
              f"{dis['payload_bytes']} payload bytes")
        if args.check:
            print("[serve_throughput] async gate OK: streamed and "
                  "disaggregated tokens bit-identical to the synchronous "
                  "engine; admission and transfer sets exact")

    out = args.out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "BENCH_serve.json")
    payload = {
        "config": {"arch": args.arch, "impl": args.impl,
                   "alpha": args.alpha, "smoke": args.smoke,
                   "seed": args.seed},
        "mixed": rows,
        "shared_prefix": srows,
        "oversubscribed": orows,
    }
    if crows is not None:
        payload["chaos"] = crows
    if hrows is not None:
        payload["hierarchy"] = hrows
    if arows is not None:
        payload["async"] = arows
    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[serve_throughput] wrote {out}")


if __name__ == "__main__":
    main()
