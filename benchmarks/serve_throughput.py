"""Serving throughput: continuous batching vs static length bucketing.

Measures end-to-end tokens/sec on a mixed-length request trace — the
workload where static bucketing loses: it pads every batch to the bucket
length, cannot refill a finished row, and serializes buckets, while the
continuous batcher admits the next queued request into any freed slot and
keeps the decode batch full.

    PYTHONPATH=src python benchmarks/serve_throughput.py
    PYTHONPATH=src python benchmarks/serve_throughput.py --impl bitstopper_xla
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.serving import (
    ContinuousBatchingEngine,
    Request,
    ServeConfig,
    StaticBucketEngine,
)


def make_trace(rng, vocab, n_requests, lens, new_lo, new_hi):
    """Heterogeneous trace: prompt lengths cycle through `lens`, generation
    lengths vary — the shape that defeats static bucketing."""
    return [
        Request(prompt=rng.integers(0, vocab, int(lens[i % len(lens)]),
                                    dtype=np.int32),
                max_new_tokens=int(rng.integers(new_lo, new_hi + 1)))
        for i in range(n_requests)
    ]


def _timed(engine, trace, seed):
    # Warm-up on a full same-shaped copy of the trace (short generations):
    # every jit shape the engine will hit — per-bucket prefill and decode
    # batch shapes included — compiles outside the timed region.  The jit
    # caches live on the engine instance, so the SAME instance is measured.
    warm = [Request(prompt=r.prompt.copy(), max_new_tokens=2)
            for r in trace]
    engine.generate(warm, seed=seed)
    if hasattr(engine, "counters"):
        engine.counters = {k: 0 for k in engine.counters}

    reqs = [Request(prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens)
            for r in trace]
    t0 = time.monotonic()
    engine.generate(reqs, seed=seed)
    dt = time.monotonic() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    return n_tok, dt, engine


def run(arch="stablelm-1.6b", impl="xla", alpha=0.6, n_requests=8,
        slots=4, seed=0, lens=(8, 24, 40), new_lo=8, new_hi=24):
    cfg = reduced_config(arch).replace(
        attn_impl=impl, bitstopper=BitStopperConfig(alpha=alpha))
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    max_len = max(lens) + new_hi + 8
    scfg = ServeConfig(max_len=max_len, max_slots=slots, prefill_bucket=8)

    rng = np.random.default_rng(seed)
    trace = make_trace(rng, cfg.vocab, n_requests, lens, new_lo, new_hi)

    rows = []
    n_c, dt_c, eng_c = _timed(
        ContinuousBatchingEngine(cfg, params, scfg), trace, seed)
    rows.append({"engine": "continuous", "tokens": n_c, "seconds": dt_c,
                 "tok_per_s": n_c / dt_c, **eng_c.counters})
    n_s, dt_s, _ = _timed(
        StaticBucketEngine(cfg, params, scfg), trace, seed)
    rows.append({"engine": "static-bucket", "tokens": n_s, "seconds": dt_s,
                 "tok_per_s": n_s / dt_s})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "bitstopper_xla"])
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = run(arch=args.arch, impl=args.impl, alpha=args.alpha,
               n_requests=args.requests, slots=args.slots, seed=args.seed)
    print(f"\n[serve_throughput] arch={args.arch} impl={args.impl} "
          f"requests={args.requests} slots={args.slots}")
    for r in rows:
        extra = (f"  (decode_steps={r['decode_steps']}, "
                 f"prefill_tokens={r['prefill_tokens']})"
                 if "decode_steps" in r else "")
        print(f"  {r['engine']:>14}: {r['tokens']:4d} tokens in "
              f"{r['seconds']:6.2f}s = {r['tok_per_s']:7.1f} tok/s{extra}")
    speedup = rows[0]["tok_per_s"] / rows[1]["tok_per_s"]
    print(f"  continuous/static throughput ratio: {speedup:.2f}x")


if __name__ == "__main__":
    main()
