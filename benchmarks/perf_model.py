"""Analytical accelerator cost/energy model (the paper's cycle simulator,
reduced to closed form).

The paper's hardware constants (Table I + Section V-A):
  * 28 nm, 1 GHz; QK-PU = 32 bit-serial PE lanes, each consuming 64 bits of
    a Key vector per cycle (12-bit Q × 1-bit K plane ANDer tree).
  * V-PU = 64-way INT12 MAC array (64 MACs/cycle) + LUT softmax.
  * HBM2: 8 ch × 32 GB/s = 256 GB/s.
  * Energy/op at 28 nm (standard CACTI/Horowitz-style constants): DRAM
    ~20 pJ/byte, SRAM ~1 pJ/byte, INT12 MAC ~0.9 pJ, INT12×1b ANDer-tree
    term ~0.08 pJ, predictor INT4 MAC ~0.12 pJ.

Given measured sparsity traces (planes fetched per pair, survivor masks —
from core/besf.py stats or the serving engine) the model produces cycle
counts and energy for BitStopper and each baseline on identical footing;
this reproduces the *relative* numbers of Fig. 12/13b (speedup and energy
ratios), which is what the paper's claims are stated in.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class HWConfig:
    freq_hz: float = 1e9
    pe_lanes: int = 32
    lane_bits_per_cycle: int = 64       # K bits consumed per lane per cycle
    vpu_macs: int = 64
    hbm_gbps: float = 256.0
    # energy constants (pJ)
    e_dram_byte: float = 20.0
    e_sram_byte: float = 1.0
    e_mac12: float = 0.9
    e_bitmac: float = 0.08              # INT12 x 1-bit
    e_mac4: float = 0.12                # 4-bit predictor MAC
    e_mac4x12: float = 0.35             # 12-bit x 4-bit chunk MAC


@dataclasses.dataclass
class CostReport:
    cycles_compute: float
    cycles_memory: float
    dram_bytes: float
    energy_pj: float
    util: float = 0.0                   # compute-unit utilization

    @property
    def cycles(self) -> float:
        """Total cycles under perfect overlap (max) — BAP's ideal."""
        return max(self.cycles_compute, self.cycles_memory)

    @property
    def cycles_serial(self) -> float:
        """No overlap (sum) — the no-BAP lower bound on utilization."""
        return self.cycles_compute + self.cycles_memory


def _mem_cycles(bytes_, hw: HWConfig) -> float:
    return bytes_ / hw.hbm_gbps * (hw.freq_hz / 1e9)


def dense_cost(Sq, Sk, d, dv, hw: HWConfig = HWConfig(), bits=12,
               mode: str = "per_pair") -> CostReport:
    """Dense INT12 attention on the BitStopper substrate (paper 'Baseline')."""
    qk_macs = Sq * Sk * d
    sv_macs = Sq * Sk * dv
    # QK on the bit-serial lanes (12 planes, no skipping), SV on the V-PU.
    qk_cycles = Sq * Sk * d * bits / (hw.pe_lanes * hw.lane_bits_per_cycle)
    sv_cycles = sv_macs / hw.vpu_macs
    k_bytes = Sk * d * bits / 8
    v_bytes = Sk * dv * bits / 8
    passes = Sq if mode == "per_pair" else 1.0   # decode streams K per step
    dram = (k_bytes + v_bytes) * passes
    energy = (dram * hw.e_dram_byte + qk_macs * bits * hw.e_bitmac
              + sv_macs * hw.e_mac12 + (k_bytes + v_bytes) * hw.e_sram_byte)
    return CostReport(qk_cycles + sv_cycles, _mem_cycles(dram, hw), dram, energy)


def bitstopper_cost(planes_fetched: np.ndarray, survivors: np.ndarray,
                    d: int, dv: int, hw: HWConfig = HWConfig(),
                    bits: int = 12, bap: bool = True,
                    mode: str = "per_pair") -> CostReport:
    """From measured per-pair plane counts + survivor mask.

    mode="per_pair" is the paper's generative-decode setting: every decode
    step (query) streams its own K planes from DRAM.  mode="shared" models
    a prefill pass with perfect on-chip K reuse across queries."""
    pf = np.asarray(planes_fetched, np.float64)
    sv = np.asarray(survivors, bool)
    plane_rows = pf.sum()                      # (pair, plane) events
    qk_cycles = plane_rows * d / (hw.pe_lanes * hw.lane_bits_per_cycle)
    sv_macs = sv.sum() * dv
    sv_cycles = sv_macs / hw.vpu_macs
    if mode == "shared":
        max_r = pf.max(axis=tuple(range(pf.ndim - 1))) if pf.ndim > 1 else pf
        k_bytes = max_r.sum() * d / 8
        v_rows = (sv.any(axis=tuple(range(sv.ndim - 1))) if sv.ndim > 1
                  else sv)
        v_bytes = v_rows.sum() * dv * bits / 8
    else:
        k_bytes = plane_rows * d / 8
        v_bytes = sv.sum() * dv * bits / 8
    dram = k_bytes + v_bytes
    energy = (dram * hw.e_dram_byte + plane_rows * d * hw.e_bitmac
              + sv_macs * hw.e_mac12 + dram * hw.e_sram_byte)
    rep = CostReport(qk_cycles + sv_cycles, _mem_cycles(dram, hw), dram, energy)
    if not bap:
        # Without BAP the exposed DRAM latency serializes: utilization is
        # compute/(compute+memory) (paper Fig. 13b: 48% -> 83%).
        rep = CostReport(rep.cycles_serial, 0.0, dram, energy)
    return rep


def predictor_cost(kept: np.ndarray, Sq, Sk, d, dv, pred_bits,
                   hw: HWConfig = HWConfig(), bits=12,
                   log_domain: bool = False,
                   mode: str = "per_pair") -> CostReport:
    """Two-stage DS accelerators (Sanger 4-bit predictor / SOFA log-domain).

    The predictor must fetch and process the FULL K at pred_bits; the
    executor re-fetches survivors at 12-bit — the decoupling the paper
    attacks.
    """
    kept_arr = np.asarray(kept, bool)
    pred_macs = Sq * Sk * d
    e_pred = hw.e_mac4 * (0.5 if log_domain else 1.0)   # shifts are cheaper
    pred_cycles = pred_macs / (hw.pe_lanes * hw.lane_bits_per_cycle / pred_bits)
    exec_pairs = kept_arr.sum()
    exec_cycles = (exec_pairs * d * bits /
                   (hw.pe_lanes * hw.lane_bits_per_cycle))
    sv_macs = exec_pairs * dv
    sv_cycles = sv_macs / hw.vpu_macs
    if mode == "shared":
        k_pred_bytes = Sk * d * pred_bits / 8
        kept_cols = kept_arr.any(axis=tuple(range(kept_arr.ndim - 1)))
        k_exec_bytes = kept_cols.sum() * d * bits / 8
        v_bytes = kept_cols.sum() * dv * bits / 8
    else:
        # decode: EVERY step's predictor re-reads the full K at pred_bits
        k_pred_bytes = Sq * Sk * d * pred_bits / 8
        k_exec_bytes = kept_arr.sum() * d * bits / 8
        v_bytes = kept_arr.sum() * dv * bits / 8
    dram = k_pred_bytes + k_exec_bytes + v_bytes
    energy = (dram * hw.e_dram_byte + pred_macs * e_pred
              + exec_pairs * d * bits * hw.e_bitmac + sv_macs * hw.e_mac12
              + dram * hw.e_sram_byte)
    return CostReport(pred_cycles + exec_cycles + sv_cycles,
                      _mem_cycles(dram, hw), dram, energy)


def tokenpicker_cost(chunks_fetched: np.ndarray, survivors: np.ndarray,
                     d, dv, hw: HWConfig = HWConfig(), bits=12,
                     chunk_bits=4, mode: str = "per_pair") -> CostReport:
    """Progressive 4-bit chunks with partial reuse + post-exp decision."""
    cf = np.asarray(chunks_fetched, np.float64)
    sv = np.asarray(survivors, bool)
    chunk_rows = cf.sum()
    macs = chunk_rows * d
    qk_cycles = macs * chunk_bits * bits / 12 / (hw.pe_lanes *
                                                 hw.lane_bits_per_cycle)
    sv_macs = sv.sum() * dv
    sv_cycles = sv_macs / hw.vpu_macs
    if mode == "shared":
        max_c = cf.max(axis=tuple(range(cf.ndim - 1)))
        k_bytes = max_c.sum() * d * chunk_bits / 8
        v_bytes = sv.any(axis=tuple(range(sv.ndim - 1))).sum() * dv * bits / 8
    else:
        k_bytes = cf.sum() * d * chunk_bits / 8
        v_bytes = sv.sum() * dv * bits / 8
    dram = k_bytes + v_bytes
    # post-exp decision: one exp per surviving chunk-row (LUT) — pricier
    # decision logic than BitStopper's max-compare (paper section VI).
    energy = (dram * hw.e_dram_byte + macs * hw.e_mac4x12
              + sv_macs * hw.e_mac12 + dram * hw.e_sram_byte
              + chunk_rows * 2.0)
    return CostReport(qk_cycles + sv_cycles, _mem_cycles(dram, hw), dram, energy)
