"""Shared benchmark utilities: a small trained LM as the source of REAL
attention-score distributions (no pretrained checkpoints exist offline),
plus synthetic heavy-tail generators for controlled sweeps.

The tiny LM (4L, d=256) is trained once on the synthetic motif corpus and
cached under results/bench_lm/; every figure benchmark then derives its
Q/K/V tensors from the same model, so methods are compared on identical
distributions — mirroring the paper's protocol of evaluating all DS
methods on the same OPT/Llama activations.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig, uniform_segments

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
_LM_DIR = os.path.join(RESULTS_DIR, "bench_lm")

BENCH_LM = ModelConfig(
    name="bench-lm", family="dense", d_model=256, vocab=512,
    segments=uniform_segments(4), n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, tie_embeddings=True,
)
BENCH_DATA = DataConfig(vocab=512, seq_len=512, global_batch=8, seed=7)


def train_bench_lm(steps: int = 150, force: bool = False):
    """Train (or load cached) the benchmark LM.  Returns (params, cfg).
    A cached checkpoint is only reused if it trained at least ``steps``
    steps (a smoke run's short checkpoint never poisons a full run)."""
    from repro.checkpoint.store import latest_step
    params = T.init_model(jax.random.PRNGKey(7), BENCH_LM)
    if not force:
        try:
            cached = latest_step(_LM_DIR)
            if cached is not None and cached >= steps:
                params, _ = load_checkpoint(params, _LM_DIR)
                return params, BENCH_LM
        except (FileNotFoundError, KeyError):
            pass
    ds = SyntheticLMDataset(BENCH_DATA)
    from repro.train.train_step import TrainConfig, make_train_step, \
        init_train_state
    tcfg = TrainConfig(total_steps=steps, warmup_steps=10)
    state = init_train_state(jax.random.PRNGKey(7), BENCH_LM, tcfg)
    step_fn = jax.jit(make_train_step(BENCH_LM, tcfg))
    for s in range(steps):
        state, metrics = step_fn(state, jnp.asarray(ds.batch_at(s)))
        if s % 50 == 0:
            print(f"[bench_lm] step {s} loss {float(metrics['loss']):.3f}")
    os.makedirs(_LM_DIR, exist_ok=True)
    save_checkpoint(jax.tree_util.tree_map(np.asarray, state["params"]),
                    _LM_DIR, steps)
    return state["params"], BENCH_LM


def extract_qkv(params, cfg: ModelConfig, batch: int = 2, seq: int = 512,
                layer: int = 0, seed: int = 3):
    """Real Q/K/V from the trained LM.  Returns [B*H, S, d] arrays."""
    import dataclasses as _dc
    ds = SyntheticLMDataset(_dc.replace(BENCH_DATA, seq_len=seq))
    tokens = jnp.asarray(ds.batch_at(1000 + seed)[:batch, :seq])
    x = L.embed(params["embed"], tokens)
    acfg = cfg.attn_config(False)
    seg = params["seg0"]
    positions = jnp.arange(seq)
    # walk to the requested layer, collecting normed inputs
    for li in range(layer):
        p_unit = jax.tree_util.tree_map(lambda a: a[li], seg)
        x, _, _ = T.block_forward(p_unit["b0"], x, positions,
                                  cfg.segments[0][0][0], cfg)
    p_unit = jax.tree_util.tree_map(lambda a: a[layer], seg)
    h = L.norm(p_unit["b0"]["norm1"], x)
    pa = p_unit["b0"]["attn"]
    q = L.rope(L.linear(pa["wq"], h), positions[None], acfg.rope_theta)
    k = L.rope(L.linear(pa["wk"], h), positions[None], acfg.rope_theta)
    v = L.linear(pa["wv"], h)
    flat = lambda a: a.swapaxes(1, 2).reshape(-1, seq, a.shape[-1])
    return flat(q), flat(k), flat(v)


def synthetic_qkv(key, B, S, d, spikiness: float = 2.0):
    """Heavy-tailed synthetic distributions (controlled spikiness sweep)."""
    ks = jax.random.split(key, 4)
    u = jax.random.normal(ks[0], (B, 1, d))
    q = spikiness * u + jax.random.normal(ks[1], (B, S, d))
    k = spikiness * u + jax.random.normal(ks[2], (B, S, d))
    v = jax.random.normal(ks[3], (B, S, d))
    return q, k, v


def llm_like_qkv(seed: int, S: int, d: int = 64, n_clusters: int = 4,
                 zipf_a: float = 1.3, gap: float = 8.0, Sq: int | None = None,
                 noise: float = 0.3, gap_range: tuple | None = None):
    """Q/K/V calibrated to published LLM attention statistics: a Zipfian
    PER-CLUSTER token-importance profile (most K tokens matter to no query
    — function words) + per-query cluster focus.  Produces ~10-40 effective
    tokens per query out of S and max-median logit gaps of ~`gap`, matching
    the OPT/Llama regime the paper evaluates on (its Figs. 3/4 premise).
    """
    rng = np.random.default_rng(seed)
    Sq = Sq or S
    U = rng.normal(size=(n_clusters, d))
    U /= np.linalg.norm(U, axis=-1, keepdims=True)
    c_k = rng.integers(0, n_clusters, S)
    c_q = rng.integers(0, n_clusters, Sq)
    # importance = Zipf over the token's rank WITHIN its cluster
    w = np.empty(S)
    for c in range(n_clusters):
        idx = np.where(c_k == c)[0]
        order = rng.permutation(len(idx))
        w[idx] = (1.0 + order) ** (-zipf_a)
    scale = np.sqrt(gap * np.sqrt(d))           # logit(top) ~ gap
    k = (w[:, None] * scale) * U[c_k] + noise * rng.normal(size=(S, d))
    if gap_range is not None:
        # heterogeneous queries (paper Fig. 4: Dist A spiky / Dist B
        # diffuse): per-query logit gaps span gap_range.
        gaps = rng.uniform(*gap_range, size=Sq)
        qscale = (gaps / np.sqrt(gap)) * d ** 0.25
    else:
        qscale = np.full(Sq, scale)
    q = qscale[:, None] * U[c_q] + noise * rng.normal(size=(Sq, d))
    v = rng.normal(size=(S, d))
    return (jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32),
            jnp.asarray(v, jnp.float32))


def topk_mass_recall(probs_true: np.ndarray, kept: np.ndarray,
                     mass: float = 0.95) -> float:
    """Fraction of the true softmax mass captured by the kept set —
    the paper Fig. 3(b) 'accuracy' of a token-selection strategy."""
    captured = (probs_true * kept).sum(axis=-1)
    return float(np.mean(captured))
