"""Speculative-decoding benchmark: acceptance rate, tokens/sec and modeled
decode HBM traffic vs plain paged decode, across draft-k and temperature.

The trace is **repetitive text** (prompts tile a short motif, served by the
small trained bench LM, which continues repetition greedily) — the workload
prompt-lookup speculation exists for: the n-gram drafter proposes the
pattern continuation, one Sq=k+1 BitStopper verify forward scores the whole
draft block, and high acceptance turns k+1 queries into k+1 emitted tokens
per scheduler tick.  Speculation is lossless (tokens bit-identical to plain
decode; asserted here on every arm), so every measured difference is pure
throughput.

Reported per arm:

* ``tokens_per_sec`` — wall clock over the decode phase (the bench serves
  the same trace through plain and speculative engines back to back).
* ``acceptance_rate`` — accepted / proposed draft tokens.
* ``tokens_per_tick`` — emitted tokens per verify/decode forward; the
  scheduler-overhead amortization plain decode cannot have.
* ``modeled_kv_read_bytes_per_token`` — decode-phase KV bytes the engine's
  attention walked (sum of per-tick live context, from the engine's
  ``decode_kv_tokens`` counter) per emitted token.  The fused verify walks
  each page's planes once for the whole draft block, so a spec tick costs
  ~one decode tick of traffic but emits up to k+1 tokens.

    PYTHONPATH=src python benchmarks/spec_decode_bench.py
    PYTHONPATH=src python benchmarks/spec_decode_bench.py --smoke --check

Writes ``results/BENCH_spec.json`` — field-by-field reference (and what
the ``--smoke --check`` CI gate asserts): ``docs/benchmarks.md``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):                 # direct `python benchmarks/..`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from repro.core.besf import BitStopperConfig
from repro.serving import PagedEngine, Request, ServeConfig
from repro.serving.engine import _kv_bytes_per_token

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def make_repetitive_trace(vocab, n_requests, motif_len, motif_reps,
                          new_tokens, seed):
    """Every request's prompt tiles its own random motif — the pattern the
    n-gram drafter locks onto (and a trained LM tends to continue)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        motif = rng.integers(0, vocab, motif_len, dtype=np.int32)
        reqs.append(Request(prompt=np.tile(motif, motif_reps),
                            max_new_tokens=new_tokens))
    return reqs


def serve_arm(cfg, params, scfg, trace_fn, warmup_fn, seed=0):
    """Serve one engine arm; returns (engine, tokens, decode_seconds,
    measured counter deltas).

    The engine first serves an untimed warm-up trace: that compiles every
    jit path AND settles the pool-wide quant scales (``k_amax``/``v_amax``
    grow with headroom, so after a representative trace further growth —
    a whole-pool requant + a speculative bailout — is rare).  Cold-start
    scale growth is a property of the first seconds of a serve, not of
    steady-state throughput, which is what this bench compares."""
    eng = PagedEngine(cfg, params, scfg)
    eng.generate(warmup_fn(), seed=seed)
    c0 = dict(eng.counters)
    reqs = trace_fn()
    t0 = time.perf_counter()
    eng.generate(reqs, seed=seed)
    dt = time.perf_counter() - t0
    c = {key: eng.counters[key] - c0[key] for key in eng.counters}
    return eng, [r.generated for r in reqs], dt, c


def bench_arm(cfg, params, base_kw, spec, draft_k, temperature, trace_fn,
              warmup_fn, per_tok_bytes, seed=0):
    """One arm of the sweep; ``spec='off'`` is the plain-decode baseline
    (reported as arm='plain', draft_k=0)."""
    scfg = ServeConfig(temperature=temperature, speculative=spec,
                       draft_k=max(1, draft_k), **base_kw)
    eng, toks, dt, c = serve_arm(cfg, params, scfg, trace_fn, warmup_fn,
                                 seed)
    n_tok = c["decode_tokens"]
    row = dict(
        arm="plain" if spec == "off" else spec,
        draft_k=draft_k, temperature=temperature,
        tokens=n_tok, seconds=round(dt, 4),
        tokens_per_sec=round(n_tok / dt, 2),
        decode_ticks=c["decode_steps"],
        tokens_per_tick=round(n_tok / max(1, c["decode_steps"]), 3),
        acceptance_rate=round(
            c["spec_accepted"] / c["spec_proposed"], 4)
        if c["spec_proposed"] else None,
        spec_ticks=c["spec_ticks"], spec_bailouts=c["spec_bailouts"],
        modeled_kv_read_bytes_per_token=round(
            c["decode_kv_tokens"] * per_tok_bytes / max(1, n_tok)),
    )
    return row, toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace + short bench-LM training (CI)")
    ap.add_argument("--check", action="store_true",
                    help="assert losslessness, real acceptance, and an "
                         "acceptance-weighted tokens/sec win over plain "
                         "decode on the repetitive trace")
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--train-steps", type=int, default=None,
                    help="bench-LM training steps (default 150; smoke 60)")
    ap.add_argument("--timing-retries", type=int, default=1,
                    help="re-measure before a wall-clock assertion failure "
                         "is fatal (CPU runners jitter under contention)")
    ap.add_argument("--out", default=os.path.join(RESULTS_DIR,
                                                  "BENCH_spec.json"))
    args = ap.parse_args()

    from benchmarks.common import train_bench_lm
    steps = args.train_steps or (60 if args.smoke else 150)
    params, base_cfg = train_bench_lm(steps=steps)
    cfg = base_cfg.replace(attn_impl="bitstopper_xla",
                           bitstopper=BitStopperConfig(alpha=args.alpha))

    n_req, new_tokens = (3, 20) if args.smoke else (8, 48)
    motif_len, motif_reps = 6, 4
    base_kw = dict(max_len=motif_len * motif_reps + new_tokens + 16,
                   max_slots=2 if args.smoke else 4,
                   prefill_bucket=8, page_size=8)
    per_tok_bytes = _kv_bytes_per_token(cfg, np.float32)

    def trace_fn():
        return make_repetitive_trace(cfg.vocab, n_req, motif_len,
                                     motif_reps, new_tokens, seed=1)

    def warmup_fn():
        return make_repetitive_trace(cfg.vocab, n_req, motif_len,
                                     motif_reps, new_tokens, seed=99)

    ks = [4] if args.smoke else [2, 4, 8]
    temps = [0.0] if args.smoke else [0.0, 1.0]

    def run_sweep():
        rows, traces = [], {}
        for temperature in temps:
            for spec, arm_ks in (("off", [0]), ("ngram", ks),
                                 ("draft", ks)):
                for k in arm_ks:
                    row, toks = bench_arm(cfg, params, base_kw, spec, k,
                                          temperature, trace_fn,
                                          warmup_fn, per_tok_bytes)
                    rows.append(row)
                    traces[(row["arm"], k, temperature)] = toks
                    acc = row["acceptance_rate"]
                    print(f"[spec] {row['arm']:5s} k={k:2d} "
                          f"t={temperature:3.1f} "
                          f"{row['tokens_per_sec']:8.1f} tok/s "
                          f"({row['tokens_per_tick']:.2f} tok/tick, "
                          f"accept={acc if acc is not None else 0:.0%}, "
                          f"bailouts={row['spec_bailouts']})")
        return rows, traces

    rows, traces = run_sweep()

    def write_report(rows_now):
        report = {
            "config": dict(model="bench-lm", train_steps=steps,
                           alpha=args.alpha, n_requests=n_req,
                           new_tokens=new_tokens, motif_len=motif_len,
                           motif_reps=motif_reps, smoke=args.smoke,
                           page_size=base_kw["page_size"],
                           max_slots=base_kw["max_slots"]),
            "note": ("Repetitive-text trace (tiled motifs), steady "
                     "state: every arm warms its engine (jit + "
                     "quant-scale headroom) on an untimed trace first. "
                     "Speculation is lossless — every arm's token traces "
                     "equal plain decode (asserted under --check); "
                     "tokens_per_sec differences are pure "
                     "scheduling/traffic wins. draft arm self-drafts "
                     "with the target model: acceptance ~1.0 but each "
                     "drafted token costs a full extra forward, so it "
                     "anchors the acceptance ceiling, not the wall-clock "
                     "win."),
            "rows": rows_now,
        }
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[spec] wrote {args.out}")

    write_report(rows)

    if args.check:
        # Losslessness at EVERY point of the sweep: deterministic, no
        # retry — this is the acceptance criterion that must never bend.
        for (arm, k, temperature), toks in traces.items():
            if arm == "plain":
                continue
            assert toks == traces[("plain", 0, temperature)], \
                f"{arm} k={k} t={temperature} trace differs from plain!"
        # Throughput/traffic claims are made on the GREEDY repetitive
        # trace (t=0.0) — that is the workload speculation targets.  The
        # temperature sweep stays in the report: sampling de-repeats the
        # text, acceptance drops, and (on this compute-bound CPU verify)
        # the wall clock can legitimately fall below plain decode — a
        # finding, not a failure.
        assert temps[0] == 0.0
        by = {(r["arm"], r["draft_k"], r["temperature"]): r for r in rows}
        plain = by[("plain", 0, 0.0)]
        ng = [by[("ngram", k, 0.0)] for k in ks]
        assert any(r["acceptance_rate"] and r["acceptance_rate"] > 0.5
                   for r in ng), \
            f"n-gram acceptance collapsed on a repetitive trace: " \
            f"{[r['acceptance_rate'] for r in ng]}"
        assert any(r["tokens_per_tick"] > 1.5 * plain["tokens_per_tick"]
                   for r in ng), "speculation barely raised tokens/tick"
        assert any(r["modeled_kv_read_bytes_per_token"]
                   < 0.8 * plain["modeled_kv_read_bytes_per_token"]
                   for r in ng), "no modeled traffic win"

        def timing_ok(rows_now):
            by_now = {(r["arm"], r["draft_k"], r["temperature"]): r
                      for r in rows_now}
            p = by_now[("plain", 0, 0.0)]["tokens_per_sec"]
            best = max(by_now[("ngram", k, 0.0)]["tokens_per_sec"]
                       for k in ks)
            assert best > p, \
                f"acceptance-weighted tokens/sec did not beat plain " \
                f"decode on the greedy repetitive trace: {best} <= {p}"

        for attempt in range(args.timing_retries + 1):
            try:
                timing_ok(rows)
                break
            except AssertionError as e:
                if attempt == args.timing_retries:
                    raise
                print(f"[spec] timing check failed ({e}); re-measuring "
                      f"(attempt {attempt + 2}/{args.timing_retries + 1})")
                rows, traces = run_sweep()
                # the artifact must hold the rows the check passed on,
                # not the jittered sweep the retry rejected
                write_report(rows)
        print("[spec] checks passed: lossless everywhere; on the greedy "
              "repetitive trace n-gram acceptance > 50% with tokens/sec, "
              "tokens/tick and modeled traffic wins over plain decode")


if __name__ == "__main__":
    main()
