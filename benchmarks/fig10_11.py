"""Fig. 10 + Fig. 11: normalized complexity (compute + memory) and DRAM
access across DS methods, at matched quality and across sequence lengths.

All methods run on the SAME real attention distributions (bench LM) and
are normalized to the dense INT12 baseline.  Quality matching follows the
paper's protocol: each method's selection keeps ≥ `mass_target` of the
true softmax mass (≈ the paper's "+0.1 PPL" budget); thresholds/k are the
loosest settings that reach it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import extract_qkv, topk_mass_recall, train_bench_lm
from repro.core import stats as stats_lib
from repro.core.baselines import (
    sanger_attention, sofa_attention, tokenpicker_attention,
)
from repro.core.besf import BitStopperConfig, besf_attention


def _true_probs(q, k):
    d = q.shape[-1]
    return np.asarray(jax.nn.softmax(
        jnp.asarray(q @ k.T / d ** 0.5), axis=-1))


def _tune(fn, quality_check, candidates):
    """Loosest candidate meeting the quality target."""
    for c in candidates:                 # ordered aggressive -> conservative
        res = fn(c)
        if quality_check(res):
            return c, res
    return candidates[-1], fn(candidates[-1])


def run_methods(q, k, v, err_target: float = 0.02):
    """One [S,d] problem → complexity per method at matched quality.

    Quality = relative L2 error of the attention OUTPUT vs exact dense
    attention (the end-effect the paper's "+0.1 PPL" budget measures;
    captured-mass alone over-penalizes dropping a flat negligible tail).
    """
    Sq, d = q.shape
    Sk, dv = v.shape
    probs = _true_probs(q, k)
    dense_out = probs @ np.asarray(v, np.float64)

    def rel_err(o):
        o = np.asarray(o, np.float64)
        return float(np.linalg.norm(o - dense_out)
                     / (np.linalg.norm(dense_out) + 1e-12))

    qj, kj, vj = jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)

    out = {}
    dense = stats_lib.dense_complexity(Sq, Sk, d, dv)
    out["dense"] = {"complexity": dense, "kept": 1.0, "quality": 1.0,
                    "rel_err": 0.0, "stats": None}

    # BitStopper (alpha from aggressive to conservative)
    def bs(alpha):
        return besf_attention(qj, kj, vj, cfg=BitStopperConfig(alpha=alpha))
    alpha, res = _tune(
        bs, lambda r: rel_err(r.out) <= err_target,
        [0.2, 0.4, 0.6, 0.8, 1.0])
    out["bitstopper"] = {
        "complexity": stats_lib.besf_complexity(
            np.asarray(res.stats.planes_fetched),
            np.asarray(res.stats.survivors), d, dv, mode="per_pair"),
        "kept": float(np.asarray(res.stats.survivors).mean()),
        "quality": topk_mass_recall(probs, np.asarray(res.stats.survivors)),
        "rel_err": rel_err(res.out),
        "param": alpha,
        "stats": {"planes_fetched": np.asarray(res.stats.planes_fetched),
                  "survivors": np.asarray(res.stats.survivors)},
    }

    # Sanger-style (static post-softmax threshold, 4-bit predictor)
    def sg(thr):
        return sanger_attention(qj, kj, vj, threshold=thr)
    thr, (o, info) = _tune(
        sg, lambda r: rel_err(r[0]) <= err_target,
        [3e-3, 1e-3, 3e-4, 1e-4, 3e-5])
    out["sanger"] = {
        "complexity": stats_lib.predictor_complexity(
            Sq, Sk, d, dv, np.asarray(info["kept"]), pred_bits=4,
            mode="per_pair"),
        "kept": float(np.asarray(info["kept"]).mean()),
        "quality": topk_mass_recall(probs, np.asarray(info["kept"])),
        "rel_err": rel_err(o),
        "param": thr,
        "stats": {"kept": np.asarray(info["kept"])},
    }

    # SOFA-style (log-domain predictor + top-k)
    def sf(kr):
        return sofa_attention(qj, kj, vj, k_ratio=kr)
    kr, (o, info) = _tune(
        sf, lambda r: rel_err(r[0]) <= err_target,
        [0.0625, 0.125, 0.25, 0.5, 0.75])
    out["sofa"] = {
        "complexity": stats_lib.predictor_complexity(
            Sq, Sk, d, dv, np.asarray(info["kept"]), pred_bits=4,
            mode="per_pair"),
        "kept": float(np.asarray(info["kept"]).mean()),
        "quality": topk_mass_recall(probs, np.asarray(info["kept"])),
        "rel_err": rel_err(o),
        "param": kr,
        "stats": {"kept": np.asarray(info["kept"])},
    }

    # TokenPicker-style (4-bit progressive chunks, post-exp rule)
    def tp(pt):
        return tokenpicker_attention(qj, kj, vj, prob_threshold=pt)
    pt, (o, info) = _tune(
        tp, lambda r: rel_err(r[0]) <= err_target,
        [3e-3, 1e-3, 3e-4, 1e-4, 3e-5])
    out["tokenpicker"] = {
        "complexity": stats_lib.chunk_progressive_complexity(
            np.asarray(info["chunks_fetched"]), np.asarray(info["kept"]),
            d, dv, mode="per_pair"),
        "kept": float(np.asarray(info["kept"]).mean()),
        "quality": topk_mass_recall(probs, np.asarray(info["kept"])),
        "rel_err": rel_err(o),
        "param": pt,
        "stats": {"kept": np.asarray(info["kept"]),
                  "chunks_fetched": np.asarray(info["chunks_fetched"])},
    }
    return out


def _sources(params, cfg, S):
    """Two distribution sources: the trained LM (mild) and the
    LLM-calibrated synthetic (the paper's spiky OPT/Llama regime)."""
    from benchmarks.common import llm_like_qkv
    # Decode-shaped cells (the paper's generative-inference setting):
    # the LAST 8 positions act as 8 consecutive decode queries against the
    # full K/V context.
    q, k, v = extract_qkv(params, cfg, batch=1, seq=S, layer=2)
    yield "lm", (np.asarray(q[0][-8:]), np.asarray(k[0]), np.asarray(v[0]))
    q, k, v = llm_like_qkv(S, S, Sq=8)
    yield "llm_like", (np.asarray(q), np.asarray(k), np.asarray(v))


def run(seq_lens=(256, 512, 1024), err_target: float = 0.02):
    params, cfg = train_bench_lm()
    rows = []
    for S in seq_lens:
        for source, (q, k, v) in _sources(params, cfg, S):
            methods = run_methods(q, k, v, err_target)
            dense = methods["dense"]["complexity"]
            for name, m in methods.items():
                c = m["complexity"]
                norm = c.normalized_to(dense)
                rows.append({
                    "seq_len": S, "source": source, "method": name,
                    "norm_compute": norm["compute"],
                    "norm_mem": norm["mem"],
                    "dram_bytes": c.total_bytes,
                    "kept_frac": m["kept"],
                    "quality": m["quality"],
                    "rel_err": m["rel_err"],
                    "param": m.get("param", ""),
                })
    return rows
