"""Benchmark driver: one module per paper table/figure → CSVs in results/.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig12

Artifact/field reference for every results/ output: ``docs/benchmarks.md``.
"""

from __future__ import annotations

import argparse
import csv
import json
import os
import statistics
import time

from benchmarks.common import RESULTS_DIR


def _write_csv(rows, path):
    if not rows:
        return
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # Union of keys in first-seen order: summary rows (e.g. serve_traffic's
    # aggregate) may carry columns the per-item rows don't.
    fields = list(dict.fromkeys(k for r in rows for k in r))
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    print(f"[bench] wrote {path} ({len(rows)} rows)")


def _paper_claims():
    """Relative numbers from the Fig. 12 analogue vs the paper's claims."""
    path = os.path.join(RESULTS_DIR, "fig12.csv")
    with open(path) as f:
        rows = list(csv.DictReader(f))
    by = {}
    for r in rows:
        by.setdefault(r["accelerator"], []).append(r)
    claims = {}
    for acc in ("sanger", "sofa", "bitstopper"):
        sp = statistics.mean(float(r["speedup_vs_dense"]) for r in by[acc])
        ee = statistics.mean(float(r["energy_eff_vs_dense"]) for r in by[acc])
        claims[acc] = {"speedup_vs_dense": round(sp, 2),
                       "energy_eff_vs_dense": round(ee, 2)}
    bs, sg, sf = claims["bitstopper"], claims["sanger"], claims["sofa"]
    claims["bitstopper_vs_sanger_speedup"] = round(
        bs["speedup_vs_dense"] / sg["speedup_vs_dense"], 2)
    claims["bitstopper_vs_sofa_speedup"] = round(
        bs["speedup_vs_dense"] / sf["speedup_vs_dense"], 2)
    claims["bitstopper_vs_sanger_energy"] = round(
        bs["energy_eff_vs_dense"] / sg["energy_eff_vs_dense"], 2)
    claims["bitstopper_vs_sofa_energy"] = round(
        bs["energy_eff_vs_dense"] / sf["energy_eff_vs_dense"], 2)
    claims["paper_targets"] = {
        "speedup_vs_dense": 3.2, "vs_sanger_speedup": 2.03,
        "vs_sofa_speedup": 1.89, "vs_sanger_energy": 2.4,
        "vs_sofa_energy": 2.1,
    }
    out = os.path.join(RESULTS_DIR, "paper_claims.json")
    with open(out, "w") as f:
        json.dump(claims, f, indent=1)
    print("[bench] paper-claim summary:")
    print(json.dumps(claims, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="fig3b | fig10_11 | fig12 | fig13a | fig13b | "
                         "serve_traffic")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized serve_traffic (tiny trace, short "
                         "training); requires --only serve_traffic")
    args = ap.parse_args()
    if args.smoke and args.only != "serve_traffic":
        ap.error("--smoke only scales serve_traffic; "
                 "pass --only serve_traffic with it")

    from benchmarks import fig3b, fig10_11, fig12_13
    serve_traffic = fig12_13.run_serve_traffic
    if args.smoke:
        def serve_traffic():
            return fig12_13.run_serve_traffic(
                n_requests=3, lens=(24, 40), new_tokens=3, slots=2,
                train_steps=30)
    jobs = {
        "fig3b": fig3b.run,
        "fig10_11": fig10_11.run,
        "fig12": fig12_13.run_fig12,
        "fig13a": fig12_13.run_fig13a,
        "fig13b": fig12_13.run_fig13b,
        "serve_traffic": serve_traffic,
    }
    if args.only:
        jobs = {args.only: jobs[args.only]}

    summary = []
    for name, fn in jobs.items():
        t0 = time.time()
        print(f"[bench] running {name} ...")
        rows = fn()
        _write_csv(rows, os.path.join(RESULTS_DIR, f"{name}.csv"))
        summary.append((name, len(rows), time.time() - t0))

    print("\n[bench] summary:")
    for name, n, dt in summary:
        print(f"  {name:<10} {n:>4} rows  {dt:6.1f}s")

    if args.only in (None, "fig12"):
        _paper_claims()


if __name__ == "__main__":
    main()
