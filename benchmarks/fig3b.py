"""Fig. 3(b): token-selection accuracy of adaptive (LATS) vs static
threshold vs fixed top-k, as the number of distinct queries grows.

Protocol (budget-matched, unlike a naive comparison):
* static threshold and top-k are tuned ONCE on the first 8 queries and
  then FROZEN (the paper's point: they cannot adapt to shifting
  distributions);
* all methods are compared at (approximately) the SAME total keep budget —
  the budget the frozen static setting implies;
* accuracy = captured true softmax mass of the kept set.

Two sources: the trained bench LM (mild distribution drift) and the
LLM-calibrated synthetic (strong per-query diversity — the regime of the
paper's Fig. 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (extract_qkv, llm_like_qkv, topk_mass_recall,
                               train_bench_lm)
from repro.core.besf import BitStopperConfig, besf_attention


def _probs(q, k):
    d = q.shape[-1]
    return np.asarray(jax.nn.softmax(jnp.asarray(q @ k.T / d ** 0.5), -1))


def _eval(q, k, v, n_queries_list, alpha):
    probs_all = _probs(q, k)
    rows = []
    # ---- tune static strategies on the FIRST 8 queries only
    p_tune = probs_all[:8]
    res0 = besf_attention(jnp.asarray(q[:8]), jnp.asarray(k),
                          jnp.asarray(v), cfg=BitStopperConfig(alpha=alpha))
    budget = float(np.asarray(res0.stats.survivors).mean())   # keep frac
    # static threshold giving that budget on the tuning queries
    thr = float(np.quantile(p_tune, 1.0 - budget))
    k_fix = max(int(round(budget * k.shape[0])), 1)

    for nq in n_queries_list:
        qs = q[:nq]
        probs = probs_all[:nq]
        res = besf_attention(jnp.asarray(qs), jnp.asarray(k), jnp.asarray(v),
                             cfg=BitStopperConfig(alpha=alpha))
        lats_kept = np.asarray(res.stats.survivors)
        static_kept = probs >= thr
        idx = np.argsort(-probs, axis=-1)[:, :k_fix]
        topk_kept = np.zeros_like(probs, dtype=bool)
        np.put_along_axis(topk_kept, idx, True, axis=-1)
        rows.append({
            "n_queries": nq,
            "lats_acc": topk_mass_recall(probs, lats_kept),
            "static_threshold_acc": topk_mass_recall(probs, static_kept),
            "topk_acc": topk_mass_recall(probs, topk_kept),
            "lats_keep_frac": float(lats_kept.mean()),
            "static_keep_frac": float(static_kept.mean()),
            "topk_keep_frac": float(topk_kept.mean()),
        })
    return rows


def run(n_queries_list=(8, 16, 32, 64, 128), alpha: float = 0.6):
    params, cfg = train_bench_lm()
    q, k, v = extract_qkv(params, cfg, batch=2, seq=256, layer=2)
    rows = []
    for r in _eval(np.asarray(q[0]), np.asarray(k[0]), np.asarray(v[0]),
                   n_queries_list, alpha):
        rows.append({"source": "lm", **r})
    q, k, v = llm_like_qkv(11, 256, Sq=max(n_queries_list),
                           gap_range=(2.0, 10.0))
    for r in _eval(np.asarray(q), np.asarray(k), np.asarray(v),
                   n_queries_list, alpha):
        rows.append({"source": "llm_like", **r})
    return rows
