"""Fig. 12: speedup + energy across accelerators (analytical model driven
by measured sparsity traces).  Fig. 13(a): quality/complexity vs alpha.
Fig. 13(b): BESF / +BAP / +LATS speedup & utilization breakdown.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import perf_model as pm
from benchmarks.common import extract_qkv, topk_mass_recall, train_bench_lm
from benchmarks.fig10_11 import _true_probs
from repro.core.baselines import sanger_attention, sofa_attention
from repro.core.besf import BitStopperConfig, besf_attention


def run_fig12(seq_lens=(256, 512, 1024), err_target: float = 0.02):
    """Cycle/energy comparison: Baseline(dense) / Sanger / SOFA /
    TokenPicker / BitStopper — at MATCHED output quality (the paper's
    comparable-PPL protocol; an unmatched comparison would let a sloppy
    top-k look fast by silently dropping accuracy)."""
    from benchmarks.fig10_11 import run_methods, _sources
    params, cfg = train_bench_lm()
    rows = []
    for S in seq_lens:
      for source, (q, k, v) in _sources(params, cfg, S):
        Sq, d = q.shape
        dv = v.shape[-1]
        methods = run_methods(q, k, v, err_target)

        dense = pm.dense_cost(Sq, S, d, dv)   # per-step K/V streaming
        st = methods["bitstopper"]["stats"]
        bs = pm.bitstopper_cost(st["planes_fetched"], st["survivors"], d, dv)
        sg = pm.predictor_cost(methods["sanger"]["stats"]["kept"],
                               Sq, S, d, dv, 4)
        sf = pm.predictor_cost(methods["sofa"]["stats"]["kept"],
                               Sq, S, d, dv, 4, log_domain=True)
        tp = pm.tokenpicker_cost(
            methods["tokenpicker"]["stats"]["chunks_fetched"],
            methods["tokenpicker"]["stats"]["kept"], d, dv)
        for name, rep in [("baseline", dense), ("sanger", sg),
                          ("sofa", sf), ("tokenpicker", tp),
                          ("bitstopper", bs)]:
            rows.append({
                "seq_len": S, "source": source, "accelerator": name,
                "cycles": rep.cycles, "energy_pj": rep.energy_pj,
                "dram_bytes": rep.dram_bytes,
                "speedup_vs_dense": dense.cycles / rep.cycles,
                "energy_eff_vs_dense": dense.energy_pj / rep.energy_pj,
                "rel_err": methods.get(name, methods["dense"])["rel_err"],
            })
    return rows


def run_serve_traffic(n_requests: int = 6, alpha: float = 0.5,
                      lens=(64, 128, 192), new_tokens: int = 8,
                      slots: int = 2, seed: int = 0,
                      train_steps: int = 150):
    """Served-traffic numbers: the trained bench LM behind the paged
    continuous-batching engine, a mixed-length request trace, and the
    engine's **per-request** plane-fetch / survivor accounting — measured
    on real served prompts rather than synthetic Q/K/V."""
    from repro.serving import Request, ServeConfig, ServingEngine

    params, cfg = train_bench_lm(steps=train_steps)
    cfg = cfg.replace(attn_impl="bitstopper_xla",
                      bitstopper=BitStopperConfig(alpha=alpha))
    scfg = ServeConfig(max_len=max(lens) + new_tokens + 8, max_slots=slots,
                       prefill_bucket=16)
    engine = ServingEngine(cfg, params, scfg)

    rng = np.random.default_rng(seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        int(lens[i % len(lens)]),
                                        dtype=np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n_requests)]
    import time
    t0 = time.monotonic()
    engine.generate(reqs, seed=seed)
    dt = time.monotonic() - t0
    rep = engine.sparsity_report([r.prompt for r in reqs])

    rows = []
    for r, pr in zip(reqs, rep["per_request"]):
        rows.append({
            "request": r.rid, "prompt_len": pr["prompt_len"],
            "new_tokens": len(r.generated),
            "plane_fraction": pr["plane_fraction"],
            "block_alive_fraction": pr["block_alive_fraction"],
            "survivor_fraction": pr["survivor_fraction"],
            "traffic_reduction": 1.0 - pr["plane_fraction"],
        })
    rows.append({
        "request": "aggregate", "prompt_len": int(np.mean(
            [len(r.prompt) for r in reqs])),
        "new_tokens": sum(len(r.generated) for r in reqs),
        "plane_fraction": rep["plane_fraction"],
        "block_alive_fraction": rep["block_alive_fraction"],
        "survivor_fraction": rep["survivor_fraction"],
        "traffic_reduction": 1.0 - rep["plane_fraction"],
        "tok_per_s": sum(len(r.generated) for r in reqs) / dt,
    })
    return rows


def run_fig13a(alphas=(0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8), seq: int = 512,
               n_steps: int = 8):
    """Quality (captured-mass + output error: the PPL proxy) and complexity
    reduction vs the pruning parameter alpha — decode semantics (each of
    n_steps queries streams its own K planes; the dense baseline streams
    the full INT12 K+V per step)."""
    from benchmarks.common import llm_like_qkv
    q, k, v = llm_like_qkv(5, seq, Sq=n_steps, gap_range=(2.0, 8.0))
    probs = _true_probs(np.asarray(q), np.asarray(k))
    dense_out = np.asarray(probs @ np.asarray(v))

    from repro.core import stats as stats_lib
    Sq, d = q.shape
    dv = v.shape[1]
    dense_c = stats_lib.Complexity(
        k_bytes=Sq * seq * d * 12 / 8,
        v_bytes=Sq * seq * dv * 12 / 8,
        compute_bitmacs=Sq * seq * (d + dv) * 144,
    )
    rows = []
    for a in alphas:
        res = besf_attention(q, k, v, cfg=BitStopperConfig(alpha=a))
        c = stats_lib.besf_complexity(
            np.asarray(res.stats.planes_fetched),
            np.asarray(res.stats.survivors), q.shape[1], v.shape[1],
            mode="per_pair")
        err = float(np.mean(np.abs(np.asarray(res.out) - dense_out))
                    / (np.mean(np.abs(dense_out)) + 1e-9))
        rows.append({
            "alpha": a,
            "quality_mass": topk_mass_recall(
                probs, np.asarray(res.stats.survivors)),
            "rel_output_err": err,
            "complexity_reduction": 1.0 - (
                c.compute_bitmacs / dense_c.compute_bitmacs),
            "mem_reduction": 1.0 - c.total_bytes / dense_c.total_bytes,
            "kept_frac": float(np.asarray(res.stats.survivors).mean()),
        })
    return rows


def run_fig13b(seq: int = 512, alpha: float = 0.6, n_steps: int = 8):
    """Speedup/utilization breakdown: dense -> +BESF -> +BAP -> +LATS
    (paper Fig. 13b), in the decode regime (each step streams K planes).

    * dense   — all 12 planes, overlapped prefetch (regular access pattern)
    * +BESF   — stage fusion w/ conservative pruning (alpha=1) but strictly
                sequential on-demand plane fetches: exposed DRAM latency
                serializes compute+memory (paper: util 48%, 1.25x)
    * +BAP    — same pruning, asynchronous fetches overlap compute
                (paper: util 83%, +1.63x)
    * +LATS   — adaptive alpha threshold on top (paper: +1.57x)
    """
    from benchmarks.common import llm_like_qkv
    q, k, v = llm_like_qkv(7, seq, Sq=n_steps)   # n_steps decode queries
    d, dv = q.shape[1], v.shape[1]
    Sq = q.shape[0]

    dense = pm.dense_cost(Sq, seq, d, dv)

    res_cons = besf_attention(q, k, v, cfg=BitStopperConfig(alpha=1.0))
    cons = pm.bitstopper_cost(
        np.asarray(res_cons.stats.planes_fetched),
        np.asarray(res_cons.stats.survivors), d, dv)
    res_lats = besf_attention(q, k, v, cfg=BitStopperConfig(alpha=alpha))
    full = pm.bitstopper_cost(
        np.asarray(res_lats.stats.planes_fetched),
        np.asarray(res_lats.stats.survivors), d, dv)

    def row(name, comp, mem, dram, overlap):
        cycles = max(comp, mem) if overlap else comp + mem
        util = comp / max(cycles, 1e-9)
        return {"config": name, "cycles": cycles,
                "speedup_vs_dense": max(dense.cycles_compute,
                                        dense.cycles_memory) / cycles,
                "utilization": util, "dram_bytes": dram}

    return [
        row("dense", dense.cycles_compute, dense.cycles_memory,
            dense.dram_bytes, overlap=True),
        row("+BESF", cons.cycles_compute, cons.cycles_memory,
            cons.dram_bytes, overlap=False),
        row("+BAP", cons.cycles_compute, cons.cycles_memory,
            cons.dram_bytes, overlap=True),
        row("+LATS(full)", full.cycles_compute, full.cycles_memory,
            full.dram_bytes, overlap=True),
    ]
