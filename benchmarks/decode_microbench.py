"""Per-decode-step microbenchmark: fused paged BESF decode vs the dense
gather path vs a flash baseline, across fill levels and pool sizes.

The serving decode hot path used to gather each slot's dense logical view
``[B, max_blocks_per_req * page_size, H, D]`` per layer per token and
re-derive K bit planes from scratch — O(table width) HBM traffic and
compute regardless of how full a row actually is.  The fused paged path
walks physical pages through the block table, stops at each row's fill
level, and early-terminates plane/V traffic per page via LATS.  This
benchmark quantifies both effects:

* **wall-clock per decode step** — ``gather`` (full-view gather +
  ``besf_attention_decode``), ``paged`` (``besf_attention_decode_paged``,
  the kernel's semantic model and the serving fallback), ``paged-kernel``
  (the Pallas kernel; interpret mode off-TPU, timed for completeness but
  only representative when compiled), and ``flash`` (dense f32 attention
  over the gathered view — the no-BitStopper baseline).
* **modeled HBM bytes per step** — dense paths move the full padded
  K+V view; the paged path moves ``rounds[b,page] * page_size/8 * Hkv * D``
  plane bytes plus V only for pages with survivors (measured from the
  oracle's stats, so early termination shows up in the bytes).

    PYTHONPATH=src python benchmarks/decode_microbench.py
    PYTHONPATH=src python benchmarks/decode_microbench.py --smoke --check

Writes ``results/BENCH_decode.json`` — field-by-field reference (and what
the ``--smoke --check`` CI gate asserts): ``docs/benchmarks.md``.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

if __package__ in (None, ""):                 # direct `python benchmarks/..`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

if "--host-devices" in sys.argv:
    # Must land in XLA_FLAGS before jax is imported: forces N host (CPU)
    # devices so --mesh runs on a single-machine CI runner.
    _n = int(sys.argv[sys.argv.index("--host-devices") + 1])
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count"
                                 f"={_n}")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.besf import BitStopperConfig, besf_attention_decode, \
    besf_attention_decode_paged
from repro.kernels.paged_decode import paged_bitstopper_decode
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.models.attention import gather_paged_view

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def roofline_fields(fn, q, modeled_bytes):
    """Roofline fields for one decode-step callable (launch/roofline.py
    constants + launch/hlo_cost.py HLO accounting).

    ``hlo_flops``/``hlo_bytes`` come from the compiled module — per-device
    when the step was compiled under a mesh (SPMD modules are per-device).
    ``roofline_fraction`` is modeled-minimal HBM time over the compiled
    program's bound time, max(t_compute, t_hbm): 1.0 means the program
    moves exactly the modeled intrinsic bytes and nothing else dominates;
    the shortfall is XLA-side overhead traffic (gather materialization,
    layout copies) the fused path exists to eliminate."""
    try:
        txt = jax.jit(fn).lower(q).compile().as_text()
    except Exception as e:                       # interpret-mode edge cases
        return {"roofline_note": f"hlo unavailable: {type(e).__name__}"}
    cost = analyze_hlo(txt)
    t_comp = cost.flops / PEAK_FLOPS
    t_hbm = cost.bytes / HBM_BW
    bound = max(t_comp, t_hbm)
    return {
        "hlo_flops": cost.flops,
        "hlo_bytes": cost.bytes,
        "t_compute_s": t_comp,
        "t_hbm_s": t_hbm,
        "bound": "hbm" if t_hbm >= t_comp else "compute",
        "roofline_fraction": (modeled_bytes / HBM_BW) / bound if bound
                             else 0.0,
    }


def build_pool_state(B, MB, bs, Hkv, D, seed=0):
    """Fully-written block pool with LLM-like per-row content (Zipfian
    token importance, clustered keys) so LATS termination is realistic.
    Row b owns physical pages 1 + b*MB .. 1 + (b+1)*MB - 1; fill levels
    are swept via ``lengths`` against this fixed content."""
    from benchmarks.common import llm_like_qkv
    P = 1 + B * MB
    S = MB * bs
    k_pool = np.zeros((P, bs, Hkv, D), np.float32)
    v_pool = np.zeros((P, bs, Hkv, D), np.float32)
    q = np.zeros((B, Hkv, D), np.float32)
    for b in range(B):
        for h in range(Hkv):
            qh, kh, vh = llm_like_qkv(seed * 131 + b * 17 + h, S, d=D, Sq=1)
            blocks = np.asarray(kh).reshape(MB, bs, D)
            k_pool[1 + b * MB: 1 + (b + 1) * MB, :, h] = blocks
            v_pool[1 + b * MB: 1 + (b + 1) * MB, :, h] = \
                np.asarray(vh).reshape(MB, bs, D)
            q[b, h] = np.asarray(qh)[0]
    table = 1 + np.arange(B * MB, dtype=np.int32).reshape(B, MB)
    k_pool, v_pool = jnp.asarray(k_pool), jnp.asarray(v_pool)
    return dict(
        q=jnp.asarray(q), k_pool=k_pool, v_pool=v_pool,
        table=jnp.asarray(table),
        k_amax=jnp.max(jnp.abs(k_pool), axis=(0, 1, 3)),
        v_amax=jnp.max(jnp.abs(v_pool), axis=(0, 1, 3)),
    )


def _timeit(fn, *args, reps=5, warmup=2):
    """Median-free mean wall clock after ``warmup`` untimed calls (the
    first triggers compilation; the second settles allocator/cache
    state — timing never includes JIT work)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def _pack_pool(k_pool, k_amax, bits):
    from repro.core.quantization import pack_pool_planes
    return pack_pool_planes(k_pool, k_amax, bits)


def bench_config(state, bs, fill, cfg, reps, run_kernel):
    """One (pool, fill) point: times + modeled bytes for every impl.

    Two strict phases per config: first every impl is built, compiled and
    warmed (including the oracle stats pass the bytes model needs), THEN
    the timing loops run back to back — no timing window ever overlaps
    another impl's JIT compilation, which is what made the wall-clock
    asserts contention-flaky on shared CI runners."""
    q, k_pool, v_pool = state["q"], state["k_pool"], state["v_pool"]
    table = state["table"]
    B, MB = table.shape
    Hkv, D = k_pool.shape[2], k_pool.shape[3]
    Tv = MB * bs
    itemsize = k_pool.dtype.itemsize
    n_live = max(1, round(MB * fill))
    lengths = jnp.full((B,), n_live * bs, jnp.int32)
    q_pos = lengths - 1

    dense_bytes = B * Tv * Hkv * D * itemsize * 2          # K + V view

    # -- gather: dense view + besf_attention_decode (the old decode path)
    cache = {"k": k_pool, "v": v_pool,
             "pos": jnp.zeros(k_pool.shape[:2], jnp.int32),
             "table": table, "length": lengths}

    @jax.jit
    def gather_step(q):
        k_view, v_view, _ = gather_paged_view(cache)
        kr = k_view.swapaxes(1, 2)                         # G == 1
        vr = v_view.swapaxes(1, 2)
        mask = (jnp.arange(Tv)[None] < lengths[:, None])[:, None, None, :]
        return besf_attention_decode(q[:, :, None], kr, vr, cfg=cfg,
                                     mask=mask).out

    # -- flash baseline: dense f32 attention over the same gathered view
    @jax.jit
    def flash_step(q):
        k_view, v_view, _ = gather_paged_view(cache)
        mask = jnp.arange(Tv)[None] < lengths[:, None]
        logits = jnp.einsum("bhd,bthd->bht", q, k_view) / D ** 0.5
        logits = jnp.where(mask[:, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bht,bthd->bhd", p, v_view)

    # -- paged: pure-JAX paged walk over the FULL-width table, exactly as
    # the serving fallback receives it — dead pages are skipped at runtime
    # (lax.cond in the oracle, pl.when in the kernel), which is where the
    # fill-proportional wall clock comes from.
    def paged_step(q):
        return besf_attention_decode_paged(
            q, k_pool, v_pool, table, lengths, q_pos,
            state["k_amax"], state["v_amax"], cfg=cfg)

    # Phase 1: bytes model (also compiles/warms the oracle) + impl table.
    stats = paged_step(q)
    rounds = np.asarray(stats.rounds)
    v_fetched = np.asarray(stats.v_fetched)
    plane_bytes = int(rounds.sum()) * (bs // 8) * Hkv * D
    v_bytes = int(v_fetched.sum()) * bs * Hkv * D * itemsize
    paged_bytes = plane_bytes + v_bytes

    steps = [
        ("gather", gather_step, reps, dense_bytes, {}),
        ("flash", flash_step, reps, dense_bytes, {}),
        ("paged", lambda q: paged_step(q).out, reps, paged_bytes, {}),
    ]
    if run_kernel:
        kq_pool = _pack_pool(k_pool, state["k_amax"], cfg.bits)
        interp = jax.default_backend() != "tpu"

        def kernel_step(q):
            return paged_bitstopper_decode(
                q, kq_pool, v_pool, table, lengths, q_pos,
                state["k_amax"], state["v_amax"], cfg=cfg,
                stats=False).out

        # interpret off-TPU: timing is NOT representative there, the
        # bytes model is identical to `paged`
        steps.append(("paged-kernel", kernel_step, max(1, reps // 5),
                      paged_bytes, {"interpret": interp}))

    for _, fn, _, _, _ in steps:
        jax.block_until_ready(fn(q))          # compile everything up front

    # Phase 2: serial timing, nothing left to compile.
    rows = []
    for impl, fn, r, bts, extra in steps:
        rows.append(dict(impl=impl, ms_per_step=_timeit(fn, q, reps=r),
                         modeled_hbm_bytes_per_step=bts, **extra,
                         **roofline_fields(fn, q, bts)))

    for r in rows:
        r.update(fill=fill, pool_blocks=int(1 + B * MB),
                 max_blocks_per_req=int(MB), batch=int(B),
                 page_size=int(bs), view_tokens=int(Tv),
                 live_tokens=int(n_live * bs))
    return rows


def _by_impl(all_rows):
    by = {}
    for r in all_rows:
        by.setdefault((r["impl"], r["max_blocks_per_req"]),
                      {})[r["fill"]] = r
    return by


def check_bytes(all_rows):
    """Deterministic traffic-model asserts (never retried: the bytes are
    measured from the oracle's stats, not from the clock)."""
    by = _by_impl(all_rows)
    for (impl, MB), pts in by.items():
        fl = sorted(pts)
        if impl == "gather":
            assert len({pts[f]["modeled_hbm_bytes_per_step"]
                        for f in fl}) == 1, \
                "gather bytes should not depend on fill"
        if impl == "paged":
            bts = [pts[f]["modeled_hbm_bytes_per_step"] for f in fl]
            assert all(a < b for a, b in zip(bts, bts[1:])), \
                f"paged bytes must grow with fill: {bts}"
            # bytes depend on fill (unlike the fill-blind gather); the
            # growth is sub-linear because LATS terminates the extra
            # pages early — that's the point, so only the direction
            # and a real dependence are asserted.
            assert bts[0] < 0.85 * bts[-1], \
                f"paged bytes barely depend on fill: {bts}"


def check_timing(all_rows):
    """Wall-clock acceptance: paged beats gather where the structural
    margin is large (>= 50% fill the gather path still pays the whole
    padded view).  Raises AssertionError on the first violation."""
    by = _by_impl(all_rows)
    for (impl, MB), pts in by.items():
        if impl != "paged":
            continue
        for f in sorted(pts):
            if f < 0.5:
                continue
            g = by[("gather", MB)][f]["ms_per_step"]
            p = pts[f]["ms_per_step"]
            # strict-ish win at half fill (large structural margin, but
            # a shared CPU runner still jitters — allow 10%); generous
            # slack near full fill so a real ~1x point can't flake.
            bound = g * (1.1 if f <= 0.5 else 1.5)
            raise_if = p >= bound
            assert not raise_if, \
                f"paged not faster at fill={f}: {p:.2f}ms vs {g:.2f}ms " \
                f"(bound {bound:.2f}ms)"


def run_sweep(args, cfg, bs, B, Hkv, D, mbs, fills, reps):
    all_rows = []
    for mb_i, MB in enumerate(mbs):
        state = build_pool_state(B, MB, bs, Hkv, D, seed=mb_i)
        for fill in fills:
            run_kernel = args.kernel or (mb_i == 0 and fill == fills[0]) \
                or args.smoke
            rows = bench_config(state, bs, fill, cfg, reps, run_kernel)
            all_rows.extend(rows)
            line = " ".join(
                f"{r['impl']}={r['ms_per_step']:8.2f}ms/"
                f"{r['modeled_hbm_bytes_per_step'] / 1024:.0f}KiB"
                for r in rows)
            print(f"[decode] MB={MB:4d} fill={fill:4.2f} {line}")
    return all_rows


def run_sharded(args, cfg, bs, B, Hkv, D, MB, fills, reps, mesh):
    """Sharded decode rows: the serving shard_map (KV heads over "model",
    slots over "data") around the paged oracle, timed on the mesh, with
    *per-device* modeled bytes from per-shard oracle stats.

    Each shard fetches plane/V traffic only for its ``Hkv/tp`` heads, and
    its per-page LATS round count is the max over *fewer* heads — so
    per-device bytes are <= single-device/tp by construction; the rows
    quantify how close the split comes to the ideal 1/tp.  Output is
    asserted equal to the single-device oracle at every fill (up to XLA
    per-shape reduction-order ulps; see the inline note)."""
    from repro.models.attention import _shard_paged_attention
    from repro.sharding.rules import make_serve_rules

    tp = mesh.shape["model"]
    rules = make_serve_rules(mesh)
    state = build_pool_state(B, MB, bs, Hkv, D, seed=0)
    q, k_pool, v_pool = state["q"], state["k_pool"], state["v_pool"]
    table = state["table"]
    itemsize = k_pool.dtype.itemsize
    Hl = Hkv // tp
    rows = []
    for fill in fills:
        n_live = max(1, round(MB * fill))
        lengths = jnp.full((B,), n_live * bs, jnp.int32)
        q_pos = lengths - 1

        def model_bytes(st, heads):
            plane = int(np.asarray(st.rounds).sum()) * (bs // 8) * heads * D
            v = int(np.asarray(st.v_fetched).sum()) * bs * heads * D * itemsize
            return plane + v

        # Single-device reference: the bit-identity target + global bytes.
        ref = besf_attention_decode_paged(
            q, k_pool, v_pool, table, lengths, q_pos,
            state["k_amax"], state["v_amax"], cfg=cfg)
        global_bytes = model_bytes(ref, Hkv)

        # Per-device modeled bytes: the oracle at each shard's local
        # geometry (exactly what that device's shard_map body computes).
        per_dev = []
        for s in range(tp):
            hs = slice(s * Hl, (s + 1) * Hl)
            st = besf_attention_decode_paged(
                q[:, hs], k_pool[:, :, hs], v_pool[:, :, hs], table,
                lengths, q_pos, state["k_amax"][hs], state["v_amax"][hs],
                cfg=cfg)
            per_dev.append(model_bytes(st, Hl))

        call = functools.partial(besf_attention_decode_paged, cfg=cfg)

        @jax.jit
        def sharded_step(q, lengths=lengths, q_pos=q_pos):
            return _shard_paged_attention(
                call, rules, q, k_pool, v_pool, table, lengths, q_pos,
                state["k_amax"], state["v_amax"])

        out = jax.block_until_ready(sharded_step(q))
        # Survivor sets are identical per head (pruning is per-head; the
        # page's shared round counter only keeps feeding already-dead
        # heads), so the only sharded-vs-single difference is XLA
        # reassociating reductions when it compiles the smaller per-shard
        # shapes — ulp-level.  Bit-identity of full serving *traces*
        # (the invariant that matters) is asserted token-for-token in
        # tests/test_serving_sharded.py and serve_throughput.py.
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref.out),
                                   rtol=0, atol=1e-6)
        row = dict(impl="paged-sharded",
                   ms_per_step=_timeit(sharded_step, q, reps=reps),
                   modeled_hbm_bytes_per_step=max(per_dev),
                   modeled_hbm_bytes_per_device=per_dev,
                   single_device_bytes=global_bytes,
                   mesh=dict(zip(mesh.axis_names, mesh.devices.shape)),
                   fill=fill, pool_blocks=int(1 + B * MB),
                   max_blocks_per_req=int(MB), batch=int(B),
                   page_size=int(bs), view_tokens=int(MB * bs),
                   live_tokens=int(n_live * bs),
                   **roofline_fields(sharded_step, q, max(per_dev)))
        rows.append(row)
        print(f"[decode] MB={MB:4d} fill={fill:4.2f} paged-sharded="
              f"{row['ms_per_step']:8.2f}ms/"
              f"{max(per_dev) / 1024:.0f}KiB per device "
              f"(ideal 1/tp = {global_bytes / tp / 1024:.0f}KiB)")
    return rows


def check_sharded(all_rows):
    """Deterministic sharded asserts: per-device modeled bytes <= the
    single-device row's bytes / tp (per-shard LATS terminates no later
    over fewer heads) and within 2x of that ideal split (the KV heads
    share the plane/V traffic roughly evenly)."""
    seen = 0
    for r in all_rows:
        if r["impl"] != "paged-sharded":
            continue
        seen += 1
        tp = r["mesh"]["model"]
        ideal = r["single_device_bytes"] / tp
        got = r["modeled_hbm_bytes_per_step"]
        assert got <= ideal, \
            f"per-device bytes exceed the 1/tp split: {got} > {ideal}"
        assert got >= 0.5 * ideal, \
            f"per-device bytes implausibly far below 1/tp: {got} vs {ideal}"
    assert seen, "no paged-sharded rows to check"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few reps (CI)")
    ap.add_argument("--check", action="store_true",
                    help="assert fill-scaling + wall-clock acceptance")
    ap.add_argument("--kernel", action="store_true",
                    help="also time the Pallas kernel on every config "
                         "(slow in interpret mode; by default only the "
                         "smallest config runs it)")
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="also run the sharded serving path (shard_map: KV "
                         "heads over 'model', batch over 'data') on the "
                         "smallest pool: emits paged-sharded rows with "
                         "per-device modeled bytes and asserts "
                         "bit-identity vs single-device")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N host (CPU) devices via XLA_FLAGS so "
                         "--mesh runs on a single machine (consumed "
                         "before jax import)")
    ap.add_argument("--timing-retries", type=int, default=1,
                    help="re-measure the sweep this many times before a "
                         "wall-clock assertion failure is fatal (CPU CI "
                         "runners jitter 3-5x under contention; the bytes "
                         "asserts are deterministic and never retried)")
    ap.add_argument("--out", default=os.path.join(RESULTS_DIR,
                                                  "BENCH_decode.json"))
    args = ap.parse_args()

    cfg = BitStopperConfig(alpha=args.alpha)
    bs = 16
    # smoke keeps the view big enough (Tv=512) that the asymptotics the
    # check asserts are visible; only reps and the sweep shrink.
    B, Hkv, D = (2, 2, 32) if args.smoke else (4, 4, 64)
    mbs = [32] if args.smoke else [32, 128]
    fills = [0.5, 1.0] if args.smoke else [0.25, 0.5, 0.75, 1.0]
    reps = 2 if args.smoke else 5

    mesh = None
    if args.mesh is not None:
        try:
            dp, tp = (int(x) for x in args.mesh.split(","))
        except ValueError:
            ap.error(f"--mesh expects 'dp,tp' (got {args.mesh!r})")
        n_dev = len(jax.devices())
        if dp * tp > n_dev:
            ap.error(f"--mesh {dp},{tp} needs {dp * tp} devices, "
                     f"{n_dev} visible (use --host-devices on CPU)")
        if Hkv % tp != 0:
            ap.error(f"--mesh tp={tp} must divide n_kv_heads={Hkv}")
        mesh = jax.make_mesh((dp, tp), ("data", "model"))

    def measure():
        rows = run_sweep(args, cfg, bs, B, Hkv, D, mbs, fills, reps)
        if mesh is not None:
            rows += run_sharded(args, cfg, bs, B, Hkv, D, mbs[0], fills,
                                reps, mesh)
        return rows

    all_rows = measure()

    def write_report(rows):
        report = {
            "config": dict(batch=B, n_kv_heads=Hkv, head_dim=D,
                           page_size=bs, alpha=args.alpha, bits=cfg.bits,
                           backend=jax.default_backend(),
                           smoke=args.smoke),
            "note": ("modeled_hbm_bytes_per_step: dense impls move the "
                     "full padded K+V view; paged impls move measured "
                     "plane bytes (rounds * page_size/8 * Hkv * D) + V "
                     "pages with survivors. paged-kernel timing is "
                     "interpret-mode (not representative) unless backend "
                     "== tpu."),
            "rows": rows,
        }
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[decode] wrote {args.out}")

    write_report(all_rows)

    if args.check:
        check_bytes(all_rows)
        if mesh is not None:
            check_sharded(all_rows)
        for attempt in range(args.timing_retries + 1):
            try:
                check_timing(all_rows)
                break
            except AssertionError as e:
                if attempt == args.timing_retries:
                    raise
                print(f"[decode] timing check failed ({e}); re-measuring "
                      f"serially (attempt {attempt + 2}/"
                      f"{args.timing_retries + 1})")
                all_rows = measure()
                # the artifact must hold the rows the check passed on,
                # not the jittered sweep the retry rejected
                write_report(all_rows)
        print("[decode] checks passed: paged bytes scale with fill; "
              "paged beats gather wall-clock at >=50% fill")


if __name__ == "__main__":
    main()
