#!/usr/bin/env python
"""Shim: run the static analyzers without setting PYTHONPATH.

Equivalent to ``PYTHONPATH=src python -m repro.analysis ...`` from the
repo root; all flags pass through (see ``repro/analysis/__main__.py``).
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
