#!/usr/bin/env python
"""Perf-trajectory CI gate: compare a fresh ``--smoke --check`` benchmark
run against its committed baseline.

Every CI run re-executes the three benchmark smokes (decode_microbench,
spec_decode_bench, serve_throughput) into ``/tmp``; this script then
joins the fresh rows against the committed ``results/BENCH_*_smoke.json``
baselines and asserts the *deterministic* fields stayed within a ratio
tolerance.  Wall-clock fields (``seconds``, ``*_per_sec``, ``ms_*``) are
never compared — CI runners jitter — but the modeled-traffic and
scheduler-counter fields are reproducible on any machine, so a regression
in them means the perf model or the scheduler actually changed:

* decode:  modeled HBM bytes per step (the early-termination traffic
  model) per (impl, pool size, fill).
* spec:    decode ticks, tokens/tick, acceptance rate, modeled KV read
  bytes per token, per (arm, draft_k, temperature).
* serve:   token/prefill/decode/preemption counters and resident KV
  bytes per engine arm, per scenario section.

Rows present only in the fresh run (for example sharded arms on a runner
with forced host devices) are ignored; every **baseline** row must still
be matched, so arms can be added without blessing but not silently lost.

    python scripts/check_bench.py decode /tmp/BENCH_decode_smoke.json
    python scripts/check_bench.py serve  /tmp/BENCH_serve_smoke.json
    python scripts/check_bench.py spec   /tmp/BENCH_spec_smoke.json

Regenerate a baseline intentionally with ``--bless`` (copies the fresh
report over the committed one; commit the diff):

    python scripts/check_bench.py decode /tmp/BENCH_decode_smoke.json --bless
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Per-bench schema: where the row lists live inside the report, which
# fields identify a row, and which deterministic fields are gated.
BENCHES = {
    "decode": {
        "baseline": "results/BENCH_decode_smoke.json",
        "sections": [("rows", ("impl", "max_blocks_per_req", "fill"))],
        "fields": ("modeled_hbm_bytes_per_step", "live_tokens",
                   "view_tokens", "pool_blocks", "batch", "page_size"),
    },
    "spec": {
        "baseline": "results/BENCH_spec_smoke.json",
        "sections": [("rows", ("arm", "draft_k", "temperature"))],
        "fields": ("tokens", "decode_ticks", "tokens_per_tick",
                   "acceptance_rate", "spec_ticks", "spec_bailouts",
                   "modeled_kv_read_bytes_per_token"),
    },
    "serve": {
        "baseline": "results/BENCH_serve_smoke.json",
        "sections": [("mixed", ("engine",)),
                     ("shared_prefix", ("engine",)),
                     ("oversubscribed", ("engine",)),
                     ("chaos", ("engine",)),
                     ("async", ("engine",)),
                     ("hierarchy", ("engine",))],
        "fields": ("tokens", "prefill_tokens", "prefix_hit_tokens",
                   "decode_tokens", "decode_steps", "decode_kv_tokens",
                   "requests_finished", "preemptions",
                   "preempt_freed_blocks", "kv_bytes_resident",
                   "pool_blocks", "peak_live_blocks",
                   # chaos section (all deterministic: scripted fault
                   # plan + tick-indexed decisions, docs/robustness.md)
                   "bit_identical", "crashes", "restores",
                   "snapshots_taken", "snapshots_interrupted",
                   "staging_reclaimed", "degradations",
                   "drafter_failures", "forced_preemptions",
                   "requests_shed", "shed_watermark", "shed_deadline",
                   "deadline_truncated", "shed_rids", "truncated_rids",
                   # async front-door section (tick-indexed or exact by
                   # construction; wall-clock ttft_ms_*/tpot_ms_* fields
                   # are deliberately NOT listed)
                   "admission_order", "ticks_run",
                   "deadline_ticks_mapped", "ttft_ticks_p50",
                   "ttft_ticks_p95", "prefixes_transferred",
                   "blocks_transferred", "payload_bytes",
                   "prefixes_inserted", "prefix_transfers",
                   # memory-hierarchy section (counter-deterministic:
                   # swap/splice schedule is a pure function of the
                   # trace lengths; byte fields are exact record sizes —
                   # docs/serving.md "Memory hierarchy")
                   "swap_outs", "swap_ins", "swap_fallbacks",
                   "swap_in_tokens", "prefix_spills",
                   "prefix_store_hits", "prefix_store_tokens",
                   "prefix_store_interrupts", "host_swap_bytes",
                   "host_swap_bytes_peak", "disk_prefix_bytes",
                   "prefix_records_flushed"),
    },
}


def _rows(report, section):
    node = report.get(section)
    if node is None:
        return None
    return node if isinstance(node, list) else node.get("rows")


def _key(row, key_fields):
    return tuple(row.get(k) for k in key_fields)


def _within(base, fresh, rtol):
    if base is None or fresh is None:
        return base is None and fresh is None
    if isinstance(base, bool) or isinstance(fresh, bool) \
            or not isinstance(base, (int, float)) \
            or not isinstance(fresh, (int, float)):
        return base == fresh
    if base == fresh:
        return True
    if base == 0:
        return fresh == 0
    return abs(fresh - base) <= rtol * abs(base)


def compare(bench, fresh_path, baseline_path, rtol):
    spec = BENCHES[bench]
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        base = json.load(f)

    problems = []
    checked = 0
    for section, key_fields in spec["sections"]:
        brows, frows = _rows(base, section), _rows(fresh, section)
        if brows is None:
            continue                       # section absent from baseline
        if frows is None:
            problems.append(f"[{section}] missing from the fresh report")
            continue
        fresh_by_key = {_key(r, key_fields): r for r in frows}
        for brow in brows:
            key = _key(brow, key_fields)
            frow = fresh_by_key.get(key)
            where = f"[{section}] {dict(zip(key_fields, key))}"
            if frow is None:
                problems.append(f"{where}: baseline row missing from the "
                                f"fresh run")
                continue
            for field in spec["fields"]:
                if field not in brow:
                    continue
                bval, fval = brow[field], frow.get(field)
                checked += 1
                if not _within(bval, fval, rtol):
                    problems.append(f"{where}.{field}: baseline {bval!r} "
                                    f"vs fresh {fval!r} "
                                    f"(tolerance {rtol:.0%})")
    return checked, problems


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bench", choices=sorted(BENCHES))
    ap.add_argument("fresh", help="fresh --smoke --check report (JSON)")
    ap.add_argument("--baseline", default=None,
                    help="override the committed baseline path")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="relative tolerance on numeric fields "
                         "(default 0.25)")
    ap.add_argument("--bless", action="store_true",
                    help="copy the fresh report over the baseline instead "
                         "of comparing (then commit the diff)")
    args = ap.parse_args()

    baseline = args.baseline or os.path.join(REPO,
                                             BENCHES[args.bench]["baseline"])
    if args.bless:
        os.makedirs(os.path.dirname(baseline), exist_ok=True)
        shutil.copyfile(args.fresh, baseline)
        print(f"[check_bench] blessed {args.fresh} -> {baseline}")
        return 0
    if not os.path.exists(baseline):
        print(f"[check_bench] FAIL: no baseline at {baseline}\n"
              f"  generate one: python scripts/check_bench.py "
              f"{args.bench} {args.fresh} --bless")
        return 1

    checked, problems = compare(args.bench, args.fresh, baseline, args.rtol)
    for p in problems:
        print(f"[check_bench] {args.bench}: {p}")
    if problems:
        print(f"[check_bench] FAIL: {args.bench} drifted from "
              f"{os.path.relpath(baseline, REPO)} "
              f"({len(problems)} field(s); intentional? re-bless with "
              f"--bless and commit)")
        return 1
    print(f"[check_bench] OK: {args.bench} matches "
          f"{os.path.relpath(baseline, REPO)} "
          f"({checked} deterministic fields within {args.rtol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
