#!/usr/bin/env bash
# Tier-1 gate: runs the full test suite on CPU.  A collection error (such
# as a hard import of an uninstalled dependency) fails this script, which
# is exactly the failure mode this gate exists to catch.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
