#!/usr/bin/env bash
# Tier-1 gate: runs the full test suite on CPU.  A collection error (such
# as a hard import of an uninstalled dependency) fails this script, which
# is exactly the failure mode this gate exists to catch.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# Static analysis first (docs/analysis.md): Pallas kernel contracts for
# every entry point, KV-pool sanitizer self-check, repo-rule lint.  Runs
# in seconds and fails fast on structural violations — before the long
# suite ever compiles a kernel.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.analysis --check --out results/ANALYSIS.json

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

# Pool-lifecycle tests again under the shadow-ledger sanitizer + freed-
# page poisoning (docs/analysis.md): every alloc/decref/rollback/preempt
# in the serving tests is replayed and audited, and stale-page reads
# become loud.  Scoped to the suites that construct pools — the env var
# only changes pool construction, so the rest of the suite is identical.
# (The ci workflow's `sanitize` job runs the FULL suite this way.)
REPRO_SANITIZE=1 PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q tests/test_pool_sanitizer.py tests/test_kv_pool.py \
        tests/test_serving.py tests/test_speculative.py tests/test_swap.py

# Docs gate: every internal link / file reference in README.md and
# docs/*.md must resolve — stale docs fail the build.
python scripts/check_docs.py

# Serving-benchmark smoke: tiny configs, a handful of steps.  Keeps the
# paged/contiguous/static throughput harness and the served-traffic
# accounting runnable — benchmarks can't silently rot.  --check asserts
# the oversubscription gate: >= 1 preemption on the long-tail trace,
# tokens bit-identical to the uncontended run, fewer decode ticks than
# worst-case reservation (all deterministic counters, no wall clock).
# --chaos adds the chaos section (docs/robustness.md): the mixed trace
# under a scripted fault plan (host crashes + snapshot/restore, drafter
# fault, forced preemption, interrupted snapshot write) must serve
# bit-identical tokens, and the QoS trace's shed/truncation sets must be
# exact — all gated against the committed baseline below.  --async adds
# the front-door section: the mixed trace streamed through
# AsyncFrontDoor, colocated and disaggregated (prefill/decode handoff
# over the transfer queue) — streamed tokens must be bit-identical to
# the synchronous engine and the admission/transfer sets exact.
# --hierarchy adds the memory-hierarchy section (docs/serving.md): swap
# resume vs recompute on an oversubscribed trace, plus a cross-restart
# prefix-store warm start — bit-identical tokens, every swap-out
# spliced, >= 1 store hit, strictly fewer prefill chunks.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/serve_throughput.py --smoke --check --chaos --async \
        --hierarchy --out /tmp/BENCH_serve_smoke.json
# Perf-trajectory gate: fresh deterministic counters vs the committed
# baseline (results/BENCH_serve_smoke.json) — scheduler/traffic drift
# fails CI; bless intentional changes (scripts/check_bench.py --bless).
python scripts/check_bench.py serve /tmp/BENCH_serve_smoke.json
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --requests 2 --slots 2 \
        --min-prompt 4 --max-prompt 8 --new-tokens 3 --shared-prefix 8 \
        --page-size 8

# Oversubscribed-serve smoke: admission on prompt-sized reservations with
# victim preemption + lossless resume, end to end through the launcher.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --requests 4 --slots 3 \
        --min-prompt 6 --max-prompt 12 --new-tokens 16 --page-size 8 \
        --pool-blocks 10 --oversubscribe

# Chaos smoke: a canned fault plan end to end through the launcher — a
# host crash recovered from an atomically-promoted snapshot, plus an
# interrupted snapshot write whose staging orphan is reclaimed
# (docs/robustness.md; tests/test_chaos.py pins the bit-identity).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --requests 3 --slots 2 \
        --min-prompt 6 --max-prompt 10 --new-tokens 6 --page-size 8 \
        --snapshot-dir "$(mktemp -d /tmp/ci_chaos_snap.XXXXXX)" \
        --snapshot-every 2 \
        --fault-plan '[["crash", 3], ["checkpoint_interrupt", 4]]'

# Fused paged-decode smoke: times gather vs paged vs the Pallas kernel
# (interpret mode on CPU runners) and asserts the traffic model scales
# with fill level + the paged path's wall-clock win — the decode kernel
# can't rot on CPU-only CI.  (Timing asserts get one serial re-measure
# before failing; CPU runners jitter under contention.)
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/decode_microbench.py --smoke --check \
        --out /tmp/BENCH_decode_smoke.json
# Perf-trajectory gate: the modeled early-termination traffic per
# (impl, pool, fill) must match results/BENCH_decode_smoke.json.
python scripts/check_bench.py decode /tmp/BENCH_decode_smoke.json

# Speculative-serve smoke: the n-gram drafter through BOTH verify paths
# (fused Sq-tiled kernel in interpret mode, then the pure-JAX fallback) —
# the draft-verify-rollback loop can't rot on CPU-only CI.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --requests 2 --slots 2 \
        --min-prompt 4 --max-prompt 8 --new-tokens 3 --page-size 8 \
        --speculative ngram --draft-k 3 --fused-decode on
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --requests 2 --slots 2 \
        --min-prompt 4 --max-prompt 8 --new-tokens 3 --page-size 8 \
        --speculative ngram --draft-k 3 --fused-decode off

# Speculative-decode bench smoke: repetitive-text trace through the
# trained bench LM; asserts losslessness, real n-gram acceptance, and an
# acceptance-weighted tokens/sec + modeled-traffic win over plain decode.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/spec_decode_bench.py --smoke --check \
        --out /tmp/BENCH_spec_smoke.json
# Perf-trajectory gate: acceptance rate, ticks and modeled KV traffic
# per speculative arm must match results/BENCH_spec_smoke.json.
python scripts/check_bench.py spec /tmp/BENCH_spec_smoke.json
