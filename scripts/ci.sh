#!/usr/bin/env bash
# Tier-1 gate: runs the full test suite on CPU.  A collection error (such
# as a hard import of an uninstalled dependency) fails this script, which
# is exactly the failure mode this gate exists to catch.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

# Serving-benchmark smoke: tiny configs, a handful of steps.  Keeps the
# paged/contiguous/static throughput harness and the served-traffic
# accounting runnable — benchmarks can't silently rot.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/serve_throughput.py --smoke
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --requests 2 --slots 2 \
        --min-prompt 4 --max-prompt 8 --new-tokens 3 --shared-prefix 8 \
        --page-size 8

# Fused paged-decode smoke: times gather vs paged vs the Pallas kernel
# (interpret mode on CPU runners) and asserts the traffic model scales
# with fill level + the paged path's wall-clock win — the decode kernel
# can't rot on CPU-only CI.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/decode_microbench.py --smoke --check \
        --out /tmp/BENCH_decode_smoke.json
