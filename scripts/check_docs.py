#!/usr/bin/env python
"""Docs link/reference checker — the CI gate that keeps docs honest.

Scans ``README.md`` and every ``docs/*.md`` for

* markdown links ``[text](target)`` with relative targets, and
* inline-code file references like ``src/repro/serving/engine.py`` or
  ``results/bench_lm/`` (anything backticked that contains a path
  separator and a known file extension, or ends with ``/``),

and fails (exit 1) listing every reference that does not resolve against
the repository root or the referencing file's directory.  Optional
``path:anchor`` suffixes (``file.py:123``, ``file.md#section``) are
stripped before resolution; external (``http``/``mailto``) and
wildcard/code-expression backticks are ignored.

    python scripts/check_docs.py
"""

from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Extensions a backticked token must end with to count as a file reference.
EXTS = (".py", ".md", ".sh", ".yml", ".yaml", ".json", ".toml", ".csv",
        ".txt", ".cfg", ".ini")

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`([^`\n]+)`")
# A path-looking token: portable filename characters only (no spaces,
# parens, wildcards, shell operators — those are code, not paths).
PATHY = re.compile(r"^[A-Za-z0-9_.\-/]+$")


def _strip_anchor(ref: str) -> str:
    ref = ref.split("#", 1)[0]
    # file.py:123 / file.py:symbol anchors
    if ":" in ref:
        head, _ = ref.split(":", 1)
        if head.endswith(EXTS):
            ref = head
    return ref


def _resolves(ref: str, base_dir: str) -> bool:
    for root in (base_dir, ROOT):
        p = os.path.normpath(os.path.join(root, ref))
        if ref.endswith("/"):
            if os.path.isdir(p):
                return True
        elif os.path.exists(p):
            return True
    return False


def _refs_in(path: str):
    text = open(path, encoding="utf-8").read()
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield m.group(0), _strip_anchor(target)
    for m in CODE_SPAN.finditer(text):
        tok = m.group(1)
        if not PATHY.match(tok.rstrip("/") if tok.endswith("/") else tok):
            continue
        is_dir = tok.endswith("/") and "/" in tok.rstrip("/")
        is_file = tok.endswith(EXTS) and ("/" in tok or tok.startswith("."))
        if not (is_dir or is_file):
            continue
        yield f"`{tok}`", _strip_anchor(tok)


def main() -> int:
    doc_files = [os.path.join(ROOT, "README.md")]
    docs_dir = os.path.join(ROOT, "docs")
    if os.path.isdir(docs_dir):
        doc_files += sorted(
            os.path.join(docs_dir, f) for f in os.listdir(docs_dir)
            if f.endswith(".md"))
    stale = []
    n_refs = 0
    for path in doc_files:
        if not os.path.exists(path):
            stale.append((path, "(missing doc file)", ""))
            continue
        base = os.path.dirname(path)
        for shown, ref in _refs_in(path):
            n_refs += 1
            if not _resolves(ref, base):
                stale.append((os.path.relpath(path, ROOT), shown, ref))
    if stale:
        print(f"[check_docs] {len(stale)} stale reference(s):")
        for doc, shown, ref in stale:
            print(f"  {doc}: {shown} -> {ref or shown} does not resolve")
        return 1
    print(f"[check_docs] OK: {n_refs} references across "
          f"{len(doc_files)} docs all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
