"""Bit Margin Generator (paper Fig. 9(c), Section III-B).

For a query vector ``Q_i`` (full INT12 precision) dotted with a key whose bit
planes 0..r have been processed, the contribution of the remaining planes
r+1..bits-1 is bounded:

    remaining weight  W_r = sum_{t=r+1}^{bits-1} 2^{bits-1-t} = 2^{bits-1-r} - 1

Each unknown key bit multiplies Q_id by a non-negative plane weight, so

    M_i^{r,max} = W_r * sum_d max(Q_id, 0)      (unknown bits -> 1 where Q>0)
    M_i^{r,min} = W_r * sum_d min(Q_id, 0)      (unknown bits -> 1 where Q<0)

and  A^r_ij + M_i^{r,min}  <=  A_ij  <=  A^r_ij + M_i^{r,max}   exactly.

The twelve (min, max) pairs per query depend only on Q_i — the hardware
stores them in a LUT; we return them as ``[bits, ...]`` arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import DEFAULT_BITS


def remaining_weight(bits: int = DEFAULT_BITS) -> jax.Array:
    """W_r for r = 0..bits-1 (after processing plane r). Shape [bits], int32."""
    r = jnp.arange(bits)
    return (2 ** (bits - 1 - r) - 1).astype(jnp.int32)


def bit_margins(q_int: jax.Array, bits: int = DEFAULT_BITS):
    """Margin pairs for every round.

    Args:
      q_int: integer query values, shape [..., d] (int32).

    Returns:
      (m_min, m_max): each of shape [bits, ...] (float32): the margin after
      having processed planes 0..r inclusive.
    """
    pos = jnp.sum(jnp.maximum(q_int, 0), axis=-1).astype(jnp.float32)  # [...]
    neg = jnp.sum(jnp.minimum(q_int, 0), axis=-1).astype(jnp.float32)  # [...]
    w = remaining_weight(bits).astype(jnp.float32)  # [bits]
    shape = (bits,) + (1,) * pos.ndim
    w = w.reshape(shape)
    return w * neg[None, ...], w * pos[None, ...]
