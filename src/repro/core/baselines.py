"""Prior-art dynamic-sparsity baselines the paper compares against.

These re-implement the *mechanisms* (not the full systems) of:

* **Dense** — INT12 attention without sparsity (the paper's "Baseline").
* **Sanger-style** [MICRO'21] — a separate 4-bit predictor computes an
  approximate QK^T; pairs whose approximate post-softmax probability exceeds
  a *static* threshold survive; the executor recomputes survivors at 12-bit.
* **SOFA-style** [MICRO'24] — a low-bit (log-domain flavored) predictor
  followed by per-query *top-k* selection; executor recomputes at 12-bit.
* **TokenPicker-style** [DAC'24] — predictor-free progressive 4-bit chunks
  with partial-sum reuse and a post-exp probability stopping rule.

Every function returns (output, info-dict) where info carries the masks /
fetch counters that ``repro.core.stats`` converts into traffic numbers.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import quantization as qlib
from repro.core.lats import NEG_INF


def _maybe_causal_mask(Sq, Sk, causal, mask):
    if causal:
        cmask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        mask = cmask if mask is None else (mask & cmask)
    return mask


def _masked_softmax(logits, mask):
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    return p


@partial(jax.jit, static_argnames=("bits", "causal"))
def dense_attention(q, k, v, bits: int = 12, causal: bool = False, mask=None):
    """INT12-quantized dense attention (paper accuracy baseline)."""
    d = q.shape[-1]
    mask = _maybe_causal_mask(q.shape[-2], k.shape[-2], causal, mask)
    q_int, qp = qlib.quantize(q, bits)
    k_int, kp = qlib.quantize(k, bits)
    v_int, vp = qlib.quantize(v, bits)
    scores = jnp.einsum("...qd,...kd->...qk", q_int.astype(jnp.float32),
                        k_int.astype(jnp.float32))
    logits = scores * (qp.scale * kp.scale / d ** 0.5)
    p = _masked_softmax(logits, mask)
    out = p @ qlib.dequantize(v_int, vp)
    return out, {"probs": p, "logits": logits, "mask": mask}


@partial(jax.jit, static_argnames=("pred_bits", "exec_bits", "causal"))
def sanger_attention(
    q, k, v,
    threshold: float = 2e-3,
    pred_bits: int = 4,
    exec_bits: int = 12,
    causal: bool = False,
    mask=None,
):
    """Sanger-style: 4-bit predictor + static post-softmax threshold."""
    d = q.shape[-1]
    mask = _maybe_causal_mask(q.shape[-2], k.shape[-2], causal, mask)
    # Prediction stage (low precision, full K fetch).
    q4, qp4 = qlib.quantize(q, pred_bits)
    k4, kp4 = qlib.quantize(k, pred_bits)
    approx = jnp.einsum("...qd,...kd->...qk", q4.astype(jnp.float32),
                        k4.astype(jnp.float32))
    approx_logits = approx * (qp4.scale * kp4.scale / d ** 0.5)
    approx_p = _masked_softmax(approx_logits, mask)
    kept = approx_p > threshold
    if mask is not None:
        kept = kept & mask
    # Formal stage (high precision on survivors).
    out, info = dense_attention(q, k, v, exec_bits, causal=False, mask=kept)
    info = dict(info, kept=kept, valid=mask)
    return out, info


@partial(jax.jit, static_argnames=("k_ratio", "pred_bits", "exec_bits", "causal"))
def sofa_attention(
    q, k, v,
    k_ratio: float = 0.25,
    pred_bits: int = 4,
    exec_bits: int = 12,
    causal: bool = False,
    mask=None,
):
    """SOFA-style: log-domain low-bit predictor + per-query top-k."""
    d = q.shape[-1]
    Sq, Sk = q.shape[-2], k.shape[-2]
    mask = _maybe_causal_mask(Sq, Sk, causal, mask)
    # Log-domain predictor: power-of-two magnitudes (cheap shifts in HW).
    def log_quant(x, bits):
        sign = jnp.sign(x)
        mag = jnp.abs(x)
        amax = jnp.maximum(jnp.max(mag), 1e-12)
        e = jnp.clip(jnp.round(jnp.log2(mag / amax + 1e-20)), -(2 ** bits - 1), 0)
        return sign * amax * 2.0 ** e
    approx = jnp.einsum("...qd,...kd->...qk", log_quant(q, pred_bits),
                        log_quant(k, pred_bits)) / d ** 0.5
    if mask is not None:
        approx = jnp.where(mask, approx, NEG_INF)
    topk = max(int(k_ratio * Sk), 1)
    thresh = jnp.sort(approx, axis=-1)[..., Sk - topk]
    kept = approx >= thresh[..., None]
    if mask is not None:
        kept = kept & mask
    out, info = dense_attention(q, k, v, exec_bits, causal=False, mask=kept)
    info = dict(info, kept=kept, valid=mask)
    return out, info


@partial(jax.jit, static_argnames=("chunk_bits", "bits", "causal"))
def tokenpicker_attention(
    q, k, v,
    prob_threshold: float = 1e-3,
    chunk_bits: int = 4,
    bits: int = 12,
    causal: bool = False,
    mask=None,
):
    """TokenPicker-style: progressive 4-bit chunks, post-exp probability rule.

    A 12-bit key is consumed as three 4-bit chunks (MSB chunk first).  After
    chunk c the score interval is [partial + m_min_c, partial + m_max_c]; a
    token is dropped when the *upper bound* of its softmax probability
    (relative to the running max lower bound) falls below ``prob_threshold``.
    Chunk partial sums are reused (no re-fetch), like BESF but 4x coarser.
    """
    d = q.shape[-1]
    Sq, Sk = q.shape[-2], k.shape[-2]
    mask = _maybe_causal_mask(Sq, Sk, causal, mask)
    n_chunks = bits // chunk_bits

    q_int, qp = qlib.quantize(q, bits)
    k_int, kp = qlib.quantize(k, bits)
    scale_total = qp.scale * kp.scale / d ** 0.5

    planes = qlib.to_bitplanes(k_int, bits)          # [bits, ..., Sk, d]
    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)

    # Chunk contribution c: planes 4c..4c+3 combined.
    def chunk_score(c):
        acc = jnp.zeros(q_int.shape[:-1] + (Sk,), jnp.int32)
        for r_off in range(chunk_bits):
            r = c * chunk_bits + r_off
            acc = acc + w[r] * jnp.einsum(
                "...qd,...kd->...qk", q_int, planes[r].astype(jnp.int32)
            )
        return acc

    pos = jnp.sum(jnp.maximum(q_int, 0), axis=-1).astype(jnp.float32)
    neg = jnp.sum(jnp.minimum(q_int, 0), axis=-1).astype(jnp.float32)

    valid = jnp.ones(q_int.shape[:-2] + (Sq, Sk), bool) if mask is None else \
        jnp.broadcast_to(mask, q_int.shape[:-2] + (Sq, Sk))

    partial = jnp.zeros(q_int.shape[:-2] + (Sq, Sk), jnp.int32)
    alive = valid
    fetched = jnp.zeros_like(partial)
    for c in range(n_chunks):
        fetched = fetched + alive.astype(jnp.int32)
        partial = partial + jnp.where(alive, chunk_score(c), 0)
        rem = float(2 ** (bits - (c + 1) * chunk_bits) - 1)
        lower = partial.astype(jnp.float32) + rem * neg[..., None]
        upper = partial.astype(jnp.float32) + rem * pos[..., None]
        m_low = jnp.max(jnp.where(alive, lower, NEG_INF), axis=-1)
        # Post-exp probability upper bound vs running max.
        prob_ub = jnp.exp((upper - m_low[..., None]) * scale_total)
        alive = alive & (prob_ub > prob_threshold)

    logits = jnp.where(alive, partial.astype(jnp.float32) * scale_total, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(alive, p, 0.0)
    v_int, vp = qlib.quantize(v, bits)
    out = p @ qlib.dequantize(v_int, vp)
    return out, {
        "probs": p, "kept": alive, "chunks_fetched": fetched, "valid": valid,
    }
