"""BitStopper core algorithms (the paper's contribution, in JAX)."""

from repro.core.besf import (
    BESFOutput,
    BESFStats,
    BitStopperConfig,
    besf_attention,
    besf_attention_decode,
)
from repro.core.block_adaptation import (
    BlockBESFOutput,
    BlockStats,
    block_bitstopper_attention,
)
from repro.core.baselines import (
    dense_attention,
    sanger_attention,
    sofa_attention,
    tokenpicker_attention,
)

__all__ = [
    "BESFOutput",
    "BESFStats",
    "BitStopperConfig",
    "besf_attention",
    "besf_attention_decode",
    "BlockBESFOutput",
    "BlockStats",
    "block_bitstopper_attention",
    "dense_attention",
    "sanger_attention",
    "sofa_attention",
    "tokenpicker_attention",
]
