"""INT12 post-training quantization and bit-plane decomposition.

The paper quantizes Q, K, V to 12-bit integers (per-tensor, symmetric, 2's
complement) and decomposes each Key vector into twelve 1-bit planes, most
significant (sign) plane first.  For an N-bit 2's-complement integer
``c_{N-1} c_{N-2} ... c_0`` the value is

    x = -c_{N-1} 2^{N-1} + sum_{i=0}^{N-2} c_i 2^i            (paper Eq. 4)

so *plane r* (r = 0 is the MSB) has weight  w_0 = -2^{N-1}  and
w_r = 2^{N-1-r}  for r >= 1.  Every bit except the sign bit contributes a
non-negative amount, which is what makes the bit-level uncertainty margin
(margins.py) a valid interval bound.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_BITS = 12


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Symmetric per-tensor quantization parameters."""

    scale: jax.Array  # scalar, float32:  x_float ~= x_int * scale
    bits: int = DEFAULT_BITS

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))


def plane_weights(bits: int = DEFAULT_BITS, dtype=jnp.float32) -> jax.Array:
    """Weight of each bit plane, MSB (sign) first: [-2^(b-1), 2^(b-2), ..., 1]."""
    w = 2.0 ** jnp.arange(bits - 1, -1, -1)
    return (w * jnp.where(jnp.arange(bits) == 0, -1.0, 1.0)).astype(dtype)


def quantize(x: jax.Array, bits: int = DEFAULT_BITS) -> tuple[jax.Array, QuantParams]:
    """Symmetric per-tensor PTQ.  Returns (int32 values, params)."""
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -(qmax + 1), qmax).astype(jnp.int32)
    return q, QuantParams(scale=scale.astype(jnp.float32), bits=bits)


def dequantize(q: jax.Array, params: QuantParams) -> jax.Array:
    return q.astype(jnp.float32) * params.scale


def scale_from_amax(amax: jax.Array, bits: int = DEFAULT_BITS) -> jax.Array:
    """Symmetric quant scale for a given max-abs value (same epsilon floor
    as :func:`quantize`, so ``quantize_with_scale(x, max|x|)`` is
    bit-identical to ``quantize(x)``)."""
    qmax = 2 ** (bits - 1) - 1
    return jnp.maximum(amax, 1e-12).astype(jnp.float32) / qmax


def quantize_with_scale(x: jax.Array, scale: jax.Array,
                        bits: int = DEFAULT_BITS) -> jax.Array:
    """Quantize under an externally-maintained scale (broadcast against
    ``x``).  The paged serving cache uses this with a *pool-wide running*
    max-abs per KV head: every request quantizes against the same scale, so
    bit planes stored in the shared block pool are valid for every block
    table that maps them (per-request scales would make a prefix-shared
    block's planes wrong for all but one owner)."""
    qmax = 2 ** (bits - 1) - 1
    return jnp.clip(jnp.round(x / scale), -(qmax + 1), qmax).astype(jnp.int32)


def to_bitplanes(q: jax.Array, bits: int = DEFAULT_BITS) -> jax.Array:
    """Decompose int32 2's-complement values into bit planes.

    Returns uint8 array of shape ``(bits,) + q.shape`` with plane 0 = MSB
    (sign).  ``q`` must lie in [-2^(bits-1), 2^(bits-1)-1].
    """
    # Reinterpret as unsigned 'bits'-wide field: x mod 2^bits.
    u = jnp.where(q < 0, q + (1 << bits), q).astype(jnp.uint32)
    shifts = jnp.arange(bits - 1, -1, -1, dtype=jnp.uint32)  # MSB first
    planes = (u[None, ...] >> shifts.reshape((bits,) + (1,) * q.ndim)) & 1
    return planes.astype(jnp.uint8)


def from_bitplanes(planes: jax.Array) -> jax.Array:
    """Inverse of :func:`to_bitplanes` → int32 values."""
    bits = planes.shape[0]
    w = plane_weights(bits, dtype=jnp.int32 if bits < 31 else jnp.int64)
    # int32 weights: plane 0 weight is -2^(bits-1).
    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)
    return jnp.tensordot(w, planes.astype(jnp.int32), axes=1)


def partial_value(planes: jax.Array, r: int) -> jax.Array:
    """Value reconstructed from planes 0..r inclusive (remaining bits = 0)."""
    bits = planes.shape[0]
    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)
    mask = (jnp.arange(bits) <= r).astype(jnp.int32)
    return jnp.tensordot(w * mask, planes.astype(jnp.int32), axes=1)


# ---------------------------------------------------------------------------
# Sequence-axis bit packing (TPU kernel storage layout).
#
# Plane r of K (shape [S, d]) is stored packed 8-tokens-per-byte along the
# sequence axis: uint8[S//8, d].  Token s's bit lives in byte s//8 at bit
# position (s % 8) (LSB-first within the byte).  The d axis stays minor so a
# d=128 lane dimension tiles perfectly in VMEM.
# ---------------------------------------------------------------------------


def pack_planes_seq(planes: jax.Array) -> jax.Array:
    """Pack ``uint8[bits, S, d]`` planes → ``uint8[bits, S//8, d]`` (S % 8 == 0)."""
    bits, S, d = planes.shape
    assert S % 8 == 0, f"sequence length {S} not a multiple of 8"
    p = planes.reshape(bits, S // 8, 8, d).astype(jnp.uint32)
    weights = (1 << jnp.arange(8, dtype=jnp.uint32)).reshape(1, 1, 8, 1)
    return jnp.sum(p * weights, axis=2).astype(jnp.uint8)


def unpack_planes_seq(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_planes_seq` → ``uint8[bits, S, d]``."""
    bits, S8, d = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint32).reshape(1, 1, 8, 1)
    u = (packed.astype(jnp.uint32)[:, :, None, :] >> shifts) & 1
    return u.reshape(bits, S8 * 8, d).astype(jnp.uint8)


def pack_pool_planes(pool: jax.Array, amax: jax.Array,
                     bits: int = DEFAULT_BITS) -> jax.Array:
    """Quantize + bit-plane-pack a whole paged K pool in one shot.

    ``pool`` f32 ``[P, page_size, H, D]`` (page_size % 8 == 0), ``amax``
    ``[H]`` pool-wide running max-abs → ``uint8[P, bits, page_size//8, H,
    D]`` with token t of a page owning bit ``t % 8`` of byte ``t // 8``
    (LSB-first, the :func:`pack_planes_seq` layout).  This is the canonical
    definition the incremental write path, the paged decode kernel, and
    the benchmarks all share — the rescale-on-demand requant rebuilds the
    serving plane pool with exactly this function."""
    P, bs, H, D = pool.shape
    assert bs % 8 == 0, f"page size {bs} not a multiple of 8"
    scale = scale_from_amax(amax, bits)
    q = quantize_with_scale(pool, scale[None, None, :, None], bits)
    planes = to_bitplanes(q, bits)                  # [bits, P, bs, H, D]
    pk = planes.reshape(bits, P, bs // 8, 8, H, D).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(8, dtype=jnp.uint32))
    packed = jnp.sum(pk * weights.reshape(1, 1, 1, 8, 1, 1), axis=3)
    return packed.astype(jnp.uint8).transpose(1, 0, 2, 3, 4)


@partial(jax.jit, static_argnames=("bits",))
def quantize_and_pack(k: jax.Array, bits: int = DEFAULT_BITS):
    """Convenience: float K [S, d] → (packed planes uint8[bits, S//8, d], scale)."""
    q, params = quantize(k, bits)
    planes = to_bitplanes(q, bits)
    return pack_planes_seq(planes), params.scale
