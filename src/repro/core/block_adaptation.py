"""TPU block-granular adaptation of BESF — the Pallas kernel's semantic model.

The ASIC terminates *per token*; a TPU terminates *per (q-tile, kv-block)*:
a kv block stops fetching bit planes once **no** (query, key) pair in the
tile x block can still beat its query's LATS threshold.  Token-level quality
is preserved by masking individually-pruned tokens out of the softmax; only
the *traffic* decision is block-granular.

Because the kernel streams kv blocks (flash-attention style) it cannot see
the global max lower bound of round r.  It uses the *running prefix max*
(updated every round from every block it has touched), which is always <=
the global max, hence thresholds are conservative: the streaming variant
keeps a superset of the per-token reference's survivors.  That containment
is a property test.

This module is pure jnp — it is the oracle (`ref`) the Pallas kernel in
``repro/kernels/bitstopper_qk.py`` is validated against, and the model the
benchmarks use for block-level traffic accounting.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import margins as margins_lib
from repro.core import quantization as qlib
from repro.core.besf import BitStopperConfig
from repro.core.lats import NEG_INF


class BlockStats(NamedTuple):
    rounds_per_block: jax.Array   # [n_qt, n_kb] int32 — bit planes fetched
    block_alive: jax.Array        # [n_qt, n_kb] bool  — V fetched for block
    survivors: jax.Array          # [Sq, Sk] bool      — token-level keep mask


class BlockBESFOutput(NamedTuple):
    out: jax.Array                # [Sq, dv]
    scores: jax.Array             # [Sq, Sk] final logits (NEG_INF if pruned)
    stats: BlockStats


def _block_single(q, k, v, mask, cfg: BitStopperConfig, block_q: int, block_k: int):
    Sq, d = q.shape
    Sk, dv = v.shape
    bits = cfg.bits
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_qt, n_kb = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / (d ** 0.5)

    q_int, q_params = qlib.quantize(q, bits)
    k_int, k_params = qlib.quantize(k, bits)
    planes = qlib.to_bitplanes(k_int, bits)                      # [bits, Sk, d]
    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)
    m_min, m_max = margins_lib.bit_margins(q_int, bits)          # [bits, Sq]

    scale_total = q_params.scale * k_params.scale * sm_scale
    radius_int = cfg.radius / scale_total

    valid = jnp.ones((Sq, Sk), bool) if mask is None else mask.astype(bool)

    if cfg.quantize_v:
        v_int, v_params = qlib.quantize(v, bits)
        v_eff = qlib.dequantize(v_int, v_params)
    else:
        v_eff = v

    planes_b = planes.reshape(bits, n_kb, block_k, d)
    valid_b = valid.reshape(n_qt, block_q, n_kb, block_k)
    q_tiles = q_int.reshape(n_qt, block_q, d)
    mmin_tiles = m_min.reshape(bits, n_qt, block_q).swapaxes(0, 1)  # [n_qt, bits, Bq]
    mmax_tiles = m_max.reshape(bits, n_qt, block_q).swapaxes(0, 1)

    def q_tile_body(qi, mmin_t, mmax_t, vmask_tile):
        # qi [Bq, d]; mmin_t/mmax_t [bits, Bq]; vmask_tile [Bq, n_kb, Bk]

        def kv_block_body(carry, kb):
            m_low, m_run, l_run, acc = carry
            vmask = vmask_tile[:, kb, :]                         # [Bq, Bk]

            def round_body(rc, r):
                partial, tok_alive, blk_alive, rounds, m_low_in = rc
                do = blk_alive & (r < bits)
                rounds = rounds + do.astype(jnp.int32)
                delta = w[r] * (qi @ planes_b[r, kb].T.astype(jnp.int32))
                partial = jnp.where(do, partial + delta, partial)
                lower = partial.astype(jnp.float32) + mmin_t[r][:, None]
                upper = partial.astype(jnp.float32) + mmax_t[r][:, None]
                # Prefix-max lower bound (per query row), using valid tokens.
                low_here = jnp.max(
                    jnp.where(vmask & tok_alive, lower, NEG_INF), axis=-1
                )
                m_low_new = jnp.where(do, jnp.maximum(m_low_in, low_here), m_low_in)
                eta = m_low_new - cfg.alpha * radius_int
                keep = tok_alive & (upper >= eta[:, None]) & vmask
                keep = jnp.where(r < cfg.min_rounds - 1, tok_alive & vmask, keep)
                keep = jnp.where(do, keep, tok_alive)
                blk_alive_new = jnp.where(do, jnp.any(keep), blk_alive)
                return (partial, keep, blk_alive_new, rounds, m_low_new), None

            partial0 = jnp.zeros((block_q, block_k), jnp.int32)
            tok0 = vmask
            blk0 = jnp.any(vmask)
            (partial, tok_alive, blk_done_alive, rounds, m_low_new), _ = jax.lax.scan(
                round_body,
                (partial0, tok0, blk0, jnp.zeros((), jnp.int32), m_low),
                jnp.arange(bits),
            )
            # Survivors of a fully-processed block hold exact logits.
            full = rounds == bits
            survived = tok_alive & full
            logits = jnp.where(
                survived, partial.astype(jnp.float32) * scale_total, NEG_INF
            )
            # Online softmax update (flash-style).
            blk_max = jnp.max(logits, axis=-1)
            m_new = jnp.maximum(m_run, blk_max)
            # Guard fully-pruned prefixes: keep NEG_INF until a real value.
            p = jnp.exp(logits - m_new[:, None])
            p = jnp.where(survived, p, 0.0)
            corr = jnp.where(m_run == NEG_INF, 0.0, jnp.exp(m_run - m_new))
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            v_blk = jax.lax.dynamic_slice_in_dim(v_eff, kb * block_k, block_k, 0)
            acc_new = acc * corr[:, None] + p @ v_blk
            carry = (m_low_new, m_new, l_new, acc_new)
            return carry, (rounds, jnp.any(survived), survived, logits)

        init = (
            jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, dv), jnp.float32),
        )
        (m_low, m_run, l_run, acc), (rounds, blk_alive, survived, logits) = (
            jax.lax.scan(kv_block_body, init, jnp.arange(n_kb))
        )
        out = acc / jnp.maximum(l_run, 1e-30)[:, None]
        # [n_kb, Bq, Bk] -> [Bq, Sk]
        survived = jnp.moveaxis(survived, 0, 1).reshape(block_q, Sk)
        logits = jnp.moveaxis(logits, 0, 1).reshape(block_q, Sk)
        return out, rounds, blk_alive, survived, logits

    outs, rounds, blk_alive, survived, logits = jax.vmap(q_tile_body)(
        q_tiles, mmin_tiles, mmax_tiles, valid_b
    )
    return BlockBESFOutput(
        out=outs.reshape(Sq, dv),
        scores=logits.reshape(Sq, Sk),
        stats=BlockStats(
            rounds_per_block=rounds,
            block_alive=blk_alive,
            survivors=survived.reshape(Sq, Sk),
        ),
    )


@partial(jax.jit, static_argnames=("cfg", "block_q", "block_k", "causal"))
def block_bitstopper_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: BitStopperConfig = BitStopperConfig(),
    block_q: int = 64,
    block_k: int = 64,
    causal: bool = False,
    mask: jax.Array | None = None,
) -> BlockBESFOutput:
    """Block-granular streaming BitStopper (TPU kernel oracle).

    q [..., Sq, d], k [..., Sk, d], v [..., Sk, dv].
    """
    Sq, Sk = q.shape[-2], k.shape[-2]
    if causal:
        cmask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        mask = cmask if mask is None else (mask & cmask)

    if q.ndim == 2:
        return _block_single(q, k, v, mask, cfg, block_q, block_k)

    flat_q = q.reshape((-1,) + q.shape[-2:])
    flat_k = k.reshape((-1,) + k.shape[-2:])
    flat_v = v.reshape((-1,) + v.shape[-2:])
    res = jax.vmap(lambda a, b, c: _block_single(a, b, c, mask, cfg, block_q, block_k))(
        flat_q, flat_k, flat_v
    )
    shape = q.shape[:-2]
    return jax.tree_util.tree_map(lambda x: x.reshape(shape + x.shape[1:]), res)
