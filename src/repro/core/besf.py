"""Bit-serial Enabled Stage Fusion (BESF) — the paper's core algorithm.

Faithful, per-token reference implementation (paper Fig. 5 + Section III).
Keys are INT12-quantized and consumed one bit plane at a time (MSB first).
After each round the LATS rule prunes candidates whose score interval can no
longer reach the adaptive threshold; pruned candidates stop fetching planes
(early termination).  Survivors of all rounds carry their *exact* INT12
scores — the prediction work IS the execution work (stage fusion) — and the
final output is softmax over survivors times V.

Integer partial scores are accumulated in int32 (exact: |A| <= 2048*2048*d),
so the interval property  lower <= exact <= upper  holds bit-for-bit; this is
what the hypothesis property tests check.

Complexity accounting (planes fetched per (i, j) pair, survivor counts) is
returned in a :class:`BESFStats` so benchmarks can derive traffic/compute
numbers without re-running the algorithm.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import margins as margins_lib
from repro.core import quantization as qlib
from repro.core.lats import NEG_INF, lats_keep, lats_threshold


@dataclasses.dataclass(frozen=True)
class BitStopperConfig:
    """Algorithm hyper-parameters (paper defaults)."""

    bits: int = 12
    alpha: float = 0.6
    radius: float = 5.0
    quantize_v: bool = True     # paper: S x V at 12-bit
    min_rounds: int = 1         # never prune before this many planes are seen

    def replace(self, **kw) -> "BitStopperConfig":
        return dataclasses.replace(self, **kw)


class BESFStats(NamedTuple):
    planes_fetched: jax.Array   # [Sq, Sk] int32 — bit planes consumed per pair
    survivors: jax.Array        # [Sq, Sk] bool  — alive after the last round
    valid: jax.Array            # [Sq, Sk] bool  — attention-mask validity


class BESFOutput(NamedTuple):
    out: jax.Array              # [Sq, dv]
    probs: jax.Array            # [Sq, Sk] — softmax over survivors (0 for pruned)
    scores: jax.Array           # [Sq, Sk] — final logits (NEG_INF for pruned)
    stats: BESFStats


def _besf_single(
    q: jax.Array,               # [Sq, d] float
    k: jax.Array,               # [Sk, d] float
    v: jax.Array,               # [Sk, dv] float
    mask: jax.Array | None,     # [Sq, Sk] bool or None
    cfg: BitStopperConfig,
) -> BESFOutput:
    Sq, d = q.shape
    Sk = k.shape[0]
    bits = cfg.bits
    sm_scale = 1.0 / (d ** 0.5)

    q_int, q_params = qlib.quantize(q, bits)
    k_int, k_params = qlib.quantize(k, bits)
    planes = qlib.to_bitplanes(k_int, bits)                     # [bits, Sk, d]
    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)             # [bits]

    # Bit Margin Generator: [bits, Sq] margin pairs (int domain).
    m_min, m_max = margins_lib.bit_margins(q_int, bits)

    # alpha*radius expressed in the integer score domain.
    scale_total = q_params.scale * k_params.scale * sm_scale
    radius_int = cfg.radius / scale_total

    valid = jnp.ones((Sq, Sk), bool) if mask is None else mask.astype(bool)

    # Per-plane integer contributions: delta[r] = w_r * (q_int @ plane_r^T).
    # (Computed densely here for clarity; "fetch" accounting below records
    # what the accelerator would actually have loaded/computed.)
    def plane_score(r):
        return w[r] * (q_int @ planes[r].T.astype(jnp.int32))   # [Sq, Sk] int32

    def round_body(carry, r):
        partial, alive, fetched = carry
        # Every candidate alive entering round r fetches/computes plane r.
        fetched = fetched + alive.astype(jnp.int32)
        delta = plane_score(r)
        partial = partial + jnp.where(alive, delta, 0)

        lower = partial.astype(jnp.float32) + m_min[r][:, None]
        upper = partial.astype(jnp.float32) + m_max[r][:, None]
        eta = lats_threshold(lower, alive, cfg.alpha, radius_int)
        keep = lats_keep(upper, eta, alive)
        keep = jnp.where(r < cfg.min_rounds - 1, alive, keep)
        return (partial, keep, fetched), None

    partial0 = jnp.zeros((Sq, Sk), jnp.int32)
    fetched0 = jnp.zeros((Sq, Sk), jnp.int32)
    (partial, alive, fetched), _ = jax.lax.scan(
        round_body, (partial0, valid, fetched0), jnp.arange(bits)
    )

    # Formal stage epilogue: exact scores for survivors, softmax, S x V.
    logits = jnp.where(alive, partial.astype(jnp.float32) * scale_total, NEG_INF)
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(alive & valid, probs, 0.0)

    if cfg.quantize_v:
        v_int, v_params = qlib.quantize(v, bits)
        v_eff = qlib.dequantize(v_int, v_params)
    else:
        v_eff = v
    out = probs @ v_eff

    return BESFOutput(
        out=out,
        probs=probs,
        scores=logits,
        stats=BESFStats(planes_fetched=fetched, survivors=alive, valid=valid),
    )


def _besf_decode_single(
    q: jax.Array,               # [1, d] float — single decode query
    k: jax.Array,               # [Sk, d]
    v: jax.Array,               # [Sk, dv]
    mask: jax.Array | None,     # [1, Sk] bool or None
    cfg: BitStopperConfig,
) -> BESFOutput:
    """Sq=1 fast path: identical results to :func:`_besf_single`, different
    schedule.

    The reference issues one int matmul per bit round inside the LATS scan —
    the right shape for prefill, but at decode (one query) each round is a
    tiny matvec and the per-round setup dominates.  Here ALL plane
    contributions are computed in one fused integer contraction up front
    ([bits, Sk, d] x [d] -> [bits, Sk]) and prefix-summed; the remaining
    per-round scan is pure elementwise threshold logic.

    Bit-exactness vs the reference: a candidate alive at round r has, by
    definition, accumulated every plane 0..r — so its gated partial equals
    the ungated prefix sum.  Pruned candidates' partials diverge, but they
    contribute neither to eta (masked by `alive`) nor to the output
    (NEG_INF logits), so every observable — survivors, planes_fetched,
    scores, probs, out — matches the reference bit for bit.
    """
    _, d = q.shape
    Sk = k.shape[0]
    bits = cfg.bits
    sm_scale = 1.0 / (d ** 0.5)

    q_int, q_params = qlib.quantize(q, bits)
    k_int, k_params = qlib.quantize(k, bits)
    planes = qlib.to_bitplanes(k_int, bits)                     # [bits, Sk, d]
    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)

    m_min, m_max = margins_lib.bit_margins(q_int, bits)         # [bits, 1]

    scale_total = q_params.scale * k_params.scale * sm_scale
    radius_int = cfg.radius / scale_total

    valid = jnp.ones((1, Sk), bool) if mask is None else mask.astype(bool)

    # One fused plane contraction + prefix sum replaces bits separate
    # matvecs: deltas[r] = w_r * (q_int @ plane_r^T), partials[r] = sum<=r.
    deltas = w[:, None, None] * jnp.einsum(
        "rkd,qd->rqk", planes.astype(jnp.int32), q_int)         # [bits, 1, Sk]
    partials = jnp.cumsum(deltas, axis=0)

    def round_body(carry, inp):
        alive, fetched = carry
        part, mn, mx, r = inp
        fetched = fetched + alive.astype(jnp.int32)
        lower = part.astype(jnp.float32) + mn[:, None]
        upper = part.astype(jnp.float32) + mx[:, None]
        eta = lats_threshold(lower, alive, cfg.alpha, radius_int)
        keep = lats_keep(upper, eta, alive)
        keep = jnp.where(r < cfg.min_rounds - 1, alive, keep)
        return (keep, fetched), None

    fetched0 = jnp.zeros((1, Sk), jnp.int32)
    (alive, fetched), _ = jax.lax.scan(
        round_body, (valid, fetched0),
        (partials, m_min, m_max, jnp.arange(bits)))

    final = partials[-1]
    logits = jnp.where(alive, final.astype(jnp.float32) * scale_total, NEG_INF)
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(alive & valid, probs, 0.0)

    if cfg.quantize_v:
        v_int, v_params = qlib.quantize(v, bits)
        v_eff = qlib.dequantize(v_int, v_params)
    else:
        v_eff = v
    out = probs @ v_eff

    return BESFOutput(
        out=out,
        probs=probs,
        scores=logits,
        stats=BESFStats(planes_fetched=fetched, survivors=alive, valid=valid),
    )


@partial(jax.jit, static_argnames=("cfg",))
def besf_attention_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: BitStopperConfig = BitStopperConfig(),
    mask: jax.Array | None = None,
) -> BESFOutput:
    """Decode-specialized BitStopper attention (Sq == 1 per leading index).

    q [..., 1, d], k [..., Sk, d], v [..., Sk, dv]; ``mask`` broadcastable
    to q.shape[:-2] + (1, Sk) — per-example masks (e.g. per serving slot)
    are supported, unlike the prefill entry point.
    """
    assert q.shape[-2] == 1, "decode path is single-query; use besf_attention"
    Sk = k.shape[-2]

    if q.ndim == 2:
        return _besf_decode_single(q, k, v, mask, cfg)

    flat_q = q.reshape((-1,) + q.shape[-2:])
    flat_k = k.reshape((-1,) + k.shape[-2:])
    flat_v = v.reshape((-1,) + v.shape[-2:])
    if mask is not None:
        flat_m = jnp.broadcast_to(mask, q.shape[:-2] + (1, Sk))
        flat_m = flat_m.reshape((-1, 1, Sk))
        res = jax.vmap(lambda a, b, c, m: _besf_decode_single(a, b, c, m, cfg))(
            flat_q, flat_k, flat_v, flat_m
        )
    else:
        res = jax.vmap(lambda a, b, c: _besf_decode_single(a, b, c, None, cfg))(
            flat_q, flat_k, flat_v
        )
    shape = q.shape[:-2]
    return jax.tree_util.tree_map(
        lambda x: x.reshape(shape + x.shape[1:]), res)


@partial(jax.jit, static_argnames=("cfg", "causal"))
def besf_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: BitStopperConfig = BitStopperConfig(),
    mask: jax.Array | None = None,
    causal: bool = False,
) -> BESFOutput:
    """BitStopper attention, faithful per-token reference.

    Supports arbitrary leading batch/head dims: q [..., Sq, d], k/v [..., Sk, *].
    """
    Sq, Sk = q.shape[-2], k.shape[-2]
    if causal:
        cmask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        mask = cmask if mask is None else (mask & cmask)

    if q.ndim == 2:
        return _besf_single(q, k, v, mask, cfg)

    flat_q = q.reshape((-1,) + q.shape[-2:])
    flat_k = k.reshape((-1,) + k.shape[-2:])
    flat_v = v.reshape((-1,) + v.shape[-2:])
    if mask is not None and mask.ndim > 2:
        flat_m = jnp.broadcast_to(mask, q.shape[:-2] + (Sq, Sk))
        flat_m = flat_m.reshape((-1, Sq, Sk))
        res = jax.vmap(lambda a, b, c, m: _besf_single(a, b, c, m, cfg))(
            flat_q, flat_k, flat_v, flat_m
        )
    else:
        res = jax.vmap(lambda a, b, c: _besf_single(a, b, c, mask, cfg))(
            flat_q, flat_k, flat_v
        )
    shape = q.shape[:-2]

    def unflat(x):
        return x.reshape(shape + x.shape[1:])

    return jax.tree_util.tree_map(unflat, res)
