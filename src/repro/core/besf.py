"""Bit-serial Enabled Stage Fusion (BESF) — the paper's core algorithm.

Faithful, per-token reference implementation (paper Fig. 5 + Section III).
Keys are INT12-quantized and consumed one bit plane at a time (MSB first).
After each round the LATS rule prunes candidates whose score interval can no
longer reach the adaptive threshold; pruned candidates stop fetching planes
(early termination).  Survivors of all rounds carry their *exact* INT12
scores — the prediction work IS the execution work (stage fusion) — and the
final output is softmax over survivors times V.

Integer partial scores are accumulated in int32 (exact: |A| <= 2048*2048*d),
so the interval property  lower <= exact <= upper  holds bit-for-bit; this is
what the hypothesis property tests check.

Complexity accounting (planes fetched per (i, j) pair, survivor counts) is
returned in a :class:`BESFStats` so benchmarks can derive traffic/compute
numbers without re-running the algorithm.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import margins as margins_lib
from repro.core import quantization as qlib
from repro.core.lats import NEG_INF, lats_keep, lats_threshold


@dataclasses.dataclass(frozen=True)
class BitStopperConfig:
    """Algorithm hyper-parameters (paper defaults)."""

    bits: int = 12
    alpha: float = 0.6
    radius: float = 5.0
    quantize_v: bool = True     # paper: S x V at 12-bit
    min_rounds: int = 1         # never prune before this many planes are seen

    def replace(self, **kw) -> "BitStopperConfig":
        return dataclasses.replace(self, **kw)


class BESFStats(NamedTuple):
    planes_fetched: jax.Array   # [Sq, Sk] int32 — bit planes consumed per pair
    survivors: jax.Array        # [Sq, Sk] bool  — alive after the last round
    valid: jax.Array            # [Sq, Sk] bool  — attention-mask validity


class BESFOutput(NamedTuple):
    out: jax.Array              # [Sq, dv]
    probs: jax.Array            # [Sq, Sk] — softmax over survivors (0 for pruned)
    scores: jax.Array           # [Sq, Sk] — final logits (NEG_INF for pruned)
    stats: BESFStats


def _besf_single(
    q: jax.Array,               # [Sq, d] float
    k: jax.Array,               # [Sk, d] float
    v: jax.Array,               # [Sk, dv] float
    mask: jax.Array | None,     # [Sq, Sk] bool or None
    cfg: BitStopperConfig,
) -> BESFOutput:
    Sq, d = q.shape
    Sk = k.shape[0]
    bits = cfg.bits
    sm_scale = 1.0 / (d ** 0.5)

    q_int, q_params = qlib.quantize(q, bits)
    k_int, k_params = qlib.quantize(k, bits)
    planes = qlib.to_bitplanes(k_int, bits)                     # [bits, Sk, d]
    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)             # [bits]

    # Bit Margin Generator: [bits, Sq] margin pairs (int domain).
    m_min, m_max = margins_lib.bit_margins(q_int, bits)

    # alpha*radius expressed in the integer score domain.
    scale_total = q_params.scale * k_params.scale * sm_scale
    radius_int = cfg.radius / scale_total

    valid = jnp.ones((Sq, Sk), bool) if mask is None else mask.astype(bool)

    # Per-plane integer contributions: delta[r] = w_r * (q_int @ plane_r^T).
    # (Computed densely here for clarity; "fetch" accounting below records
    # what the accelerator would actually have loaded/computed.)
    def plane_score(r):
        return w[r] * (q_int @ planes[r].T.astype(jnp.int32))   # [Sq, Sk] int32

    def round_body(carry, r):
        partial, alive, fetched = carry
        # Every candidate alive entering round r fetches/computes plane r.
        fetched = fetched + alive.astype(jnp.int32)
        delta = plane_score(r)
        partial = partial + jnp.where(alive, delta, 0)

        lower = partial.astype(jnp.float32) + m_min[r][:, None]
        upper = partial.astype(jnp.float32) + m_max[r][:, None]
        eta = lats_threshold(lower, alive, cfg.alpha, radius_int)
        keep = lats_keep(upper, eta, alive)
        keep = jnp.where(r < cfg.min_rounds - 1, alive, keep)
        return (partial, keep, fetched), None

    partial0 = jnp.zeros((Sq, Sk), jnp.int32)
    fetched0 = jnp.zeros((Sq, Sk), jnp.int32)
    (partial, alive, fetched), _ = jax.lax.scan(
        round_body, (partial0, valid, fetched0), jnp.arange(bits)
    )

    # Formal stage epilogue: exact scores for survivors, softmax, S x V.
    logits = jnp.where(alive, partial.astype(jnp.float32) * scale_total, NEG_INF)
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(alive & valid, probs, 0.0)

    if cfg.quantize_v:
        v_int, v_params = qlib.quantize(v, bits)
        v_eff = qlib.dequantize(v_int, v_params)
    else:
        v_eff = v
    out = probs @ v_eff

    return BESFOutput(
        out=out,
        probs=probs,
        scores=logits,
        stats=BESFStats(planes_fetched=fetched, survivors=alive, valid=valid),
    )


def _besf_decode_single(
    q: jax.Array,               # [1, d] float — single decode query
    k: jax.Array,               # [Sk, d]
    v: jax.Array,               # [Sk, dv]
    mask: jax.Array | None,     # [1, Sk] bool or None
    cfg: BitStopperConfig,
) -> BESFOutput:
    """Sq=1 fast path: identical results to :func:`_besf_single`, different
    schedule.

    The reference issues one int matmul per bit round inside the LATS scan —
    the right shape for prefill, but at decode (one query) each round is a
    tiny matvec and the per-round setup dominates.  Here ALL plane
    contributions are computed in one fused integer contraction up front
    ([bits, Sk, d] x [d] -> [bits, Sk]) and prefix-summed; the remaining
    per-round scan is pure elementwise threshold logic.

    Bit-exactness vs the reference: a candidate alive at round r has, by
    definition, accumulated every plane 0..r — so its gated partial equals
    the ungated prefix sum.  Pruned candidates' partials diverge, but they
    contribute neither to eta (masked by `alive`) nor to the output
    (NEG_INF logits), so every observable — survivors, planes_fetched,
    scores, probs, out — matches the reference bit for bit.
    """
    _, d = q.shape
    Sk = k.shape[0]
    bits = cfg.bits
    sm_scale = 1.0 / (d ** 0.5)

    q_int, q_params = qlib.quantize(q, bits)
    k_int, k_params = qlib.quantize(k, bits)
    planes = qlib.to_bitplanes(k_int, bits)                     # [bits, Sk, d]
    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)

    m_min, m_max = margins_lib.bit_margins(q_int, bits)         # [bits, 1]

    scale_total = q_params.scale * k_params.scale * sm_scale
    radius_int = cfg.radius / scale_total

    valid = jnp.ones((1, Sk), bool) if mask is None else mask.astype(bool)

    # One fused plane contraction + prefix sum replaces bits separate
    # matvecs: deltas[r] = w_r * (q_int @ plane_r^T), partials[r] = sum<=r.
    deltas = w[:, None, None] * jnp.einsum(
        "rkd,qd->rqk", planes.astype(jnp.int32), q_int)         # [bits, 1, Sk]
    partials = jnp.cumsum(deltas, axis=0)

    def round_body(carry, inp):
        alive, fetched = carry
        part, mn, mx, r = inp
        fetched = fetched + alive.astype(jnp.int32)
        lower = part.astype(jnp.float32) + mn[:, None]
        upper = part.astype(jnp.float32) + mx[:, None]
        eta = lats_threshold(lower, alive, cfg.alpha, radius_int)
        keep = lats_keep(upper, eta, alive)
        keep = jnp.where(r < cfg.min_rounds - 1, alive, keep)
        return (keep, fetched), None

    fetched0 = jnp.zeros((1, Sk), jnp.int32)
    (alive, fetched), _ = jax.lax.scan(
        round_body, (valid, fetched0),
        (partials, m_min, m_max, jnp.arange(bits)))

    final = partials[-1]
    logits = jnp.where(alive, final.astype(jnp.float32) * scale_total, NEG_INF)
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(alive & valid, probs, 0.0)

    if cfg.quantize_v:
        v_int, v_params = qlib.quantize(v, bits)
        v_eff = qlib.dequantize(v_int, v_params)
    else:
        v_eff = v
    out = probs @ v_eff

    return BESFOutput(
        out=out,
        probs=probs,
        scores=logits,
        stats=BESFStats(planes_fetched=fetched, survivors=alive, valid=valid),
    )


@partial(jax.jit, static_argnames=("cfg",))
def besf_attention_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: BitStopperConfig = BitStopperConfig(),
    mask: jax.Array | None = None,
) -> BESFOutput:
    """Decode-specialized BitStopper attention (Sq == 1 per leading index).

    q [..., 1, d], k [..., Sk, d], v [..., Sk, dv]; ``mask`` broadcastable
    to q.shape[:-2] + (1, Sk) — per-example masks (e.g. per serving slot)
    are supported, unlike the prefill entry point.
    """
    assert q.shape[-2] == 1, "decode path is single-query; use besf_attention"
    Sk = k.shape[-2]

    if q.ndim == 2:
        return _besf_decode_single(q, k, v, mask, cfg)

    flat_q = q.reshape((-1,) + q.shape[-2:])
    flat_k = k.reshape((-1,) + k.shape[-2:])
    flat_v = v.reshape((-1,) + v.shape[-2:])
    if mask is not None:
        flat_m = jnp.broadcast_to(mask, q.shape[:-2] + (1, Sk))
        flat_m = flat_m.reshape((-1, 1, Sk))
        res = jax.vmap(lambda a, b, c, m: _besf_decode_single(a, b, c, m, cfg))(
            flat_q, flat_k, flat_v, flat_m
        )
    else:
        res = jax.vmap(lambda a, b, c: _besf_decode_single(a, b, c, None, cfg))(
            flat_q, flat_k, flat_v
        )
    shape = q.shape[:-2]
    return jax.tree_util.tree_map(
        lambda x: x.reshape(shape + x.shape[1:]), res)


class PagedDecodeOutput(NamedTuple):
    out: jax.Array          # [B, Hq, dv] attention output
    rounds: jax.Array       # [B, n_blocks] int32 — bit planes fetched per page
    survivors: jax.Array    # [B, Hq, n_blocks*page_size] bool
    v_fetched: jax.Array    # [B, n_blocks] bool — V page actually read


def paged_decode_prep(q, k_amax, v_amax, n_kv_heads: int,
                      cfg: BitStopperConfig):
    """Shared host-side prep of the paged decode paths (oracle AND kernel —
    both must see bit-identical operands).

    q [B, Hq, d] (one decode query per serving slot, head-major);
    ``k_amax``/``v_amax`` [Hkv] are the pool-wide running max-abs per KV
    head maintained by the cache write path.  Returns
    ``(q_int, m_min, m_max, scale_total, alpha_radius, k_scale, v_scale)``
    with per-(slot, head) q quantization — identical to the dense decode
    path — but K/V scales shared by every slot, which is what makes one
    physical bit-plane pool valid under every block table."""
    B, Hq, d = q.shape
    bits = cfg.bits
    sm_scale = 1.0 / (d ** 0.5)
    G = Hq // n_kv_heads
    flat = q.reshape(B * Hq, d)
    q_scale = qlib.scale_from_amax(jnp.max(jnp.abs(flat), axis=1), bits)
    q_int = qlib.quantize_with_scale(flat, q_scale[:, None], bits)
    q_int = q_int.reshape(B, Hq, d)
    m_min, m_max = margins_lib.bit_margins(q_int, bits)       # [bits, B, Hq]
    k_scale = qlib.scale_from_amax(k_amax, bits)              # [Hkv]
    v_scale = qlib.scale_from_amax(v_amax, bits)
    k_scale_h = jnp.repeat(k_scale, G)                        # [Hq]
    scale_total = q_scale.reshape(B, Hq) * k_scale_h[None] * sm_scale
    alpha_radius = cfg.alpha * (cfg.radius / scale_total)
    return q_int, m_min, m_max, scale_total, alpha_radius, k_scale, v_scale


def _paged_decode_row(
    q_int,                  # [Hq, d] int32
    m_min, m_max,           # [bits, Hq] f32
    scale_total,            # [Hq] f32
    alpha_radius,           # [Hq] f32
    table,                  # [MB] int32 — logical block -> physical block
    length,                 # int32 — row fill level (tokens cached)
    q_pos,                  # int32 — absolute position of the query
    k_pool,                 # [P, bs, Hkv, d] f32
    v_pool,                 # [P, bs, Hkv, dv] f32
    k_scale, v_scale,       # [Hkv] f32
    cfg: BitStopperConfig,
    window: int | None,
):
    """One slot's paged BESF decode — the semantic model of the fused
    kernel, walked in the exact same order so every observable matches.

    Pages are processed sequentially (logical block order).  LATS uses the
    **prefix max lower bound** across the pages seen so far (same
    conservative superset as the prefill kernel, ``block_adaptation.py``);
    a page whose every (head, token) candidate is pruned stops consuming
    planes, and its V page is counted un-fetched unless a token survives
    all rounds.  The softmax is the flash-style online rescale in page
    order — mirroring the kernel's epilogue op for op."""
    Hq, d = q_int.shape
    P, bs, Hkv, dv = v_pool.shape
    MB = table.shape[0]
    bits = cfg.bits
    G = Hq // Hkv

    w = (2 ** jnp.arange(bits - 1, -1, -1)).astype(jnp.int32)
    w = w * jnp.where(jnp.arange(bits) == 0, -1, 1)
    qg = q_int.reshape(Hkv, G, d)

    def block_body(carry, j):
        t_pos = j * bs + jnp.arange(bs, dtype=jnp.int32)
        valid = (t_pos <= q_pos) & (t_pos < length)
        if window is not None:
            valid &= t_pos > q_pos - window
        # Runtime page gate (the oracle-side analogue of the kernel's
        # "no DMA past the fill level"): a page with no valid token costs
        # nothing — lax.cond stays a real branch because rows are mapped
        # sequentially (lax.map), not vmapped into a select.
        return jax.lax.cond(jnp.any(valid), _live_page, _dead_page,
                            carry, j, valid)

    def _dead_page(carry, j, valid):
        return carry, (jnp.zeros((), jnp.int32),
                       jnp.zeros((Hq, bs), bool), jnp.zeros((), bool))

    def _live_page(carry, j, valid):
        mlow, m_run, l_run, acc = carry
        phys = table[j]
        k_int = qlib.quantize_with_scale(
            k_pool[phys], k_scale[None, :, None], bits)       # [bs, Hkv, d]
        planes = qlib.to_bitplanes(k_int, bits)               # [bits,bs,Hkv,d]
        valid_b = jnp.broadcast_to(valid[None], (Hq, bs))

        def round_body(rc, r):
            partial, tok_alive, blk_live, rounds, mlow_in = rc
            rounds = rounds + blk_live.astype(jnp.int32)
            delta = w[r] * jnp.einsum(
                "kgd,tkd->kgt", qg, planes[r].astype(jnp.int32)
            ).reshape(Hq, bs)
            partial = jnp.where(blk_live, partial + delta, partial)
            lower = partial.astype(jnp.float32) + m_min[r][:, None]
            upper = partial.astype(jnp.float32) + m_max[r][:, None]
            low_here = jnp.max(
                jnp.where(valid_b & tok_alive, lower, NEG_INF), axis=-1)
            mlow_new = jnp.where(blk_live, jnp.maximum(mlow_in, low_here),
                                 mlow_in)
            eta = mlow_new - alpha_radius
            keep = tok_alive & (upper >= eta[:, None]) & valid_b
            keep = jnp.where(r < cfg.min_rounds - 1, tok_alive & valid_b,
                             keep)
            keep = jnp.where(blk_live, keep, tok_alive)
            blk_new = jnp.where(blk_live, jnp.any(keep), blk_live)
            return (partial, keep, blk_new, rounds, mlow_new), None

        init = (jnp.zeros((Hq, bs), jnp.int32), valid_b, jnp.any(valid),
                jnp.zeros((), jnp.int32), mlow)
        (partial, tok_alive, _, rounds, mlow), _ = jax.lax.scan(
            round_body, init, jnp.arange(bits))

        survived = tok_alive & (rounds == bits)
        any_surv = jnp.any(survived)
        logits = jnp.where(
            survived, partial.astype(jnp.float32) * scale_total[:, None],
            NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
        p = jnp.where(survived, jnp.exp(logits - m_new[:, None]), 0.0)
        corr = jnp.where(m_run == NEG_INF, 0.0, jnp.exp(m_run - m_new))
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        vblk = v_pool[phys]                                   # [bs, Hkv, dv]
        if cfg.quantize_v:
            v_eff = (qlib.quantize_with_scale(
                vblk, v_scale[None, :, None], bits).astype(jnp.float32)
                * v_scale[None, :, None])
        else:
            v_eff = vblk.astype(jnp.float32)
        upd = jnp.einsum("kgt,tkd->kgd", p.reshape(Hkv, G, bs), v_eff)
        acc_new = acc * corr[:, None] + upd.reshape(Hq, dv)
        # The kernel's whole epilogue (including the V DMA) is predicated
        # on any_surv; a page with no survivor leaves the state untouched.
        m_run = jnp.where(any_surv, m_new, m_run)
        l_run = jnp.where(any_surv, l_new, l_run)
        acc = jnp.where(any_surv, acc_new, acc)
        return (mlow, m_run, l_run, acc), (rounds, survived, any_surv)

    init = (
        jnp.full((Hq,), NEG_INF, jnp.float32),
        jnp.full((Hq,), NEG_INF, jnp.float32),
        jnp.zeros((Hq,), jnp.float32),
        jnp.zeros((Hq, dv), jnp.float32),
    )
    (_, _, l_run, acc), (rounds, survived, v_fetched) = jax.lax.scan(
        block_body, init, jnp.arange(MB))
    out = acc / jnp.maximum(l_run, 1e-30)[:, None]
    survivors = jnp.moveaxis(survived, 0, 1).reshape(Hq, MB * bs)
    return out, rounds, survivors, v_fetched


@partial(jax.jit, static_argnames=("cfg", "window"))
def besf_attention_decode_paged(
    q: jax.Array,            # [B, Hq, d] — one decode query per slot
    k_pool: jax.Array,       # [P, page_size, Hkv, d] f32 pool
    v_pool: jax.Array,       # [P, page_size, Hkv, dv] f32 pool
    table: jax.Array,        # [B, MB] int32 block tables
    lengths: jax.Array,      # [B] int32 fill levels
    q_positions: jax.Array,  # [B] int32 absolute query positions
    k_amax: jax.Array,       # [Hkv] pool-wide running max|K|
    v_amax: jax.Array,       # [Hkv] pool-wide running max|V|
    cfg: BitStopperConfig = BitStopperConfig(),
    window: int | None = None,
) -> PagedDecodeOutput:
    """Paged BESF decode oracle: pure-JAX, gathers physical pages through
    the block table (this IS the retained gather fallback) while computing
    the exact page-sequential semantics of the fused Pallas kernel in
    ``repro/kernels/paged_decode.py`` — survivors, per-page plane counts,
    V-fetch decisions, and the online-softmax output all match the kernel
    bit for bit (tested).

    Quantization uses the cache's **pool-wide** running max-abs scales
    (``k_amax``/``v_amax``), not per-row view scales: a physical page
    shared by several block tables (prefix sharing) or recycled across
    requests must mean the same integers to every reader."""
    Hkv = k_pool.shape[2]
    prep = paged_decode_prep(q, k_amax, v_amax, Hkv, cfg)
    q_int, m_min, m_max, scale_total, alpha_radius, k_scale, v_scale = prep
    # lax.map (sequential over rows), NOT vmap: vmap would batch the
    # per-page lax.cond into a select that executes the dead-page work
    # anyway, and the whole point of the paged walk is that per-step cost
    # scales with each row's actual fill level.
    out, rounds, survivors, v_fetched = jax.lax.map(
        lambda xs: _paged_decode_row(
            xs[0], xs[1], xs[2], xs[3], xs[4], xs[5], xs[6], xs[7],
            k_pool, v_pool, k_scale, v_scale, cfg, window),
        (q_int, jnp.moveaxis(m_min, 1, 0), jnp.moveaxis(m_max, 1, 0),
         scale_total, alpha_radius, table, lengths, q_positions))
    return PagedDecodeOutput(out=out, rounds=rounds, survivors=survivors,
                             v_fetched=v_fetched)


class PagedVerifyOutput(NamedTuple):
    out: jax.Array          # [B, Sq, Hq, dv] attention output per draft query
    rounds: jax.Array       # [B, Sq, n_blocks] int32 planes fetched per page
    survivors: jax.Array    # [B, Sq, Hq, n_blocks*page_size] bool
    v_fetched: jax.Array    # [B, Sq, n_blocks] bool — V page read per query


@partial(jax.jit, static_argnames=("cfg", "window"))
def besf_attention_verify_paged(
    q: jax.Array,            # [B, Sq, Hq, d] — the draft block per slot
    k_pool: jax.Array,       # [P, page_size, Hkv, d] f32 pool
    v_pool: jax.Array,       # [P, page_size, Hkv, dv] f32 pool
    table: jax.Array,        # [B, MB] int32 block tables (shared by queries)
    lengths: jax.Array,      # [B, Sq] int32 per-QUERY fill levels
    q_positions: jax.Array,  # [B, Sq] int32 absolute query positions
    k_amax: jax.Array,       # [Hkv] pool-wide running max|K|
    v_amax: jax.Array,       # [Hkv] pool-wide running max|V|
    cfg: BitStopperConfig = BitStopperConfig(),
    window: int | None = None,
) -> PagedVerifyOutput:
    """Multi-query paged BESF verify oracle (speculative decoding).

    Scores an Sq-token draft block against a slot's paged KV in one pass.
    Every (slot, query) pair is treated as an independent row of the Sq=1
    paged decode: its own absolute position, its own fill level (causal
    intra-draft masking — query i at position p sees cached tokens
    ``t_pos <= p``, i.e. earlier draft tokens but not later ones), its own
    per-(query, head) INT quantization and LATS thresholds.  The rows are
    literally routed through :func:`_paged_decode_row`, so a real draft
    query is **bit-identical** to the Sq=1 decode the non-speculative
    engine would have run at that position — this is what makes
    speculative acceptance lossless.

    ``lengths`` is per query (normally ``q_positions + 1``); a padding
    query (slot proposed fewer than Sq drafts) is disabled with length 0 —
    every page is dead for it, it fetches nothing and costs nothing.

    This oracle is the gather fallback AND the semantic model of the fused
    Sq-tiled kernel ``repro/kernels/paged_verify.py``, which amortizes
    each page's plane DMAs across the whole draft block (fetched once if
    ANY query still needs them) while keeping per-query liveness for every
    observable."""
    B, Sq, Hq, d = q.shape
    Hkv = k_pool.shape[2]
    MB = table.shape[1]
    flat_q = q.reshape(B * Sq, Hq, d)
    prep = paged_decode_prep(flat_q, k_amax, v_amax, Hkv, cfg)
    q_int, m_min, m_max, scale_total, alpha_radius, k_scale, v_scale = prep
    # Each query row addresses the pool through its slot's table.
    flat_table = jnp.broadcast_to(table[:, None], (B, Sq, MB))
    out, rounds, survivors, v_fetched = jax.lax.map(
        lambda xs: _paged_decode_row(
            xs[0], xs[1], xs[2], xs[3], xs[4], xs[5], xs[6], xs[7],
            k_pool, v_pool, k_scale, v_scale, cfg, window),
        (q_int, jnp.moveaxis(m_min, 1, 0), jnp.moveaxis(m_max, 1, 0),
         scale_total, alpha_radius, flat_table.reshape(B * Sq, MB),
         lengths.reshape(B * Sq), q_positions.reshape(B * Sq)))
    return PagedVerifyOutput(
        out=out.reshape(B, Sq, Hq, -1),
        rounds=rounds.reshape(B, Sq, MB),
        survivors=survivors.reshape(B, Sq, Hq, -1),
        v_fetched=v_fetched.reshape(B, Sq, MB))


@partial(jax.jit, static_argnames=("cfg", "causal"))
def besf_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: BitStopperConfig = BitStopperConfig(),
    mask: jax.Array | None = None,
    causal: bool = False,
) -> BESFOutput:
    """BitStopper attention, faithful per-token reference.

    Supports arbitrary leading batch/head dims: q [..., Sq, d], k/v [..., Sk, *].
    """
    Sq, Sk = q.shape[-2], k.shape[-2]
    if causal:
        cmask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        mask = cmask if mask is None else (mask & cmask)

    if q.ndim == 2:
        return _besf_single(q, k, v, mask, cfg)

    flat_q = q.reshape((-1,) + q.shape[-2:])
    flat_k = k.reshape((-1,) + k.shape[-2:])
    flat_v = v.reshape((-1,) + v.shape[-2:])
    if mask is not None and mask.ndim > 2:
        flat_m = jnp.broadcast_to(mask, q.shape[:-2] + (Sq, Sk))
        flat_m = flat_m.reshape((-1, Sq, Sk))
        res = jax.vmap(lambda a, b, c, m: _besf_single(a, b, c, m, cfg))(
            flat_q, flat_k, flat_v, flat_m
        )
    else:
        res = jax.vmap(lambda a, b, c: _besf_single(a, b, c, mask, cfg))(
            flat_q, flat_k, flat_v
        )
    shape = q.shape[:-2]

    def unflat(x):
        return x.reshape(shape + x.shape[1:])

    return jax.tree_util.tree_map(unflat, res)
