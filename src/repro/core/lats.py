"""Lightweight Adaptive Token Selection (LATS) — paper Section III-B, Eq. (3).

Per query i and bit-round r the pruning threshold is derived from the *lower*
bounds of the still-alive candidates:

    eta_i = max_j ( A^r_ij + M_i^{r,min} ) - alpha * radius

and a candidate j survives iff its *upper* bound can still beat it:

    keep_ij = ( A^r_ij + M_i^{r,max} ) > eta_i

``radius`` is expressed in softmax-logit units (default 5: e^-5 ≈ 0.7% mass),
so when the comparison is carried out in the integer score domain the radius
must be divided by the total dequantization scale (q_scale * k_scale *
softmax_scale).  The arg-max candidate always survives: its upper bound is at
least its lower bound, which exceeds eta_i by alpha*radius > 0.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class LATSConfig:
    alpha: float = 0.6          # pruning aggressiveness (paper sweeps 0.2..0.8)
    radius: float = 5.0         # logit-domain radius (paper default)
    bits: int = 12              # quantization width


def lats_threshold(
    lower: jax.Array,        # [..., Sk] lower bounds (any consistent domain)
    valid: jax.Array,        # [..., Sk] bool — candidates still in play
    alpha: float,
    radius_in_domain,        # scalar: alpha-scaled radius in `lower`'s domain
) -> jax.Array:
    """eta per query row: max over valid lower bounds minus alpha*radius."""
    masked = jnp.where(valid, lower, NEG_INF)
    return jnp.max(masked, axis=-1) - alpha * radius_in_domain


def lats_keep(
    upper: jax.Array,        # [..., Sk] upper bounds
    eta: jax.Array,          # [...]
    valid: jax.Array,        # [..., Sk]
) -> jax.Array:
    """Survival mask for this round (subset of `valid`).

    Note: ``>=`` (not the paper's strict ``>``) so the alpha=0 boundary is
    well-defined: at the final round the argmax's collapsed interval equals
    eta exactly and must survive.  For alpha > 0 the two are equivalent.
    """
    return valid & (upper >= eta[..., None])
