"""Complexity accounting shared by all benchmarks (paper Figs. 10-12).

Two accounting modes, both reported for every method so comparisons stay
apples-to-apples:

* ``per_pair``  — every (query, key) interaction fetches its own K data
  (no cross-query reuse).  Matches the paper's PE-lane view where each lane
  walks one query row.
* ``shared``    — a K bit plane / vector is fetched once if *any* query needs
  it (perfect on-chip reuse within the attention pass).

Units: bytes for memory traffic, bit-MACs for compute (one b1 x b2 multiply-
accumulate counts b1*b2 bit-MACs, so an INT12xINT12 MAC = 144 and an
INT12x1-bit MAC = 12).  These normalize bit-serial vs full-precision work.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Complexity:
    k_bytes: float          # key traffic
    v_bytes: float          # value traffic
    compute_bitmacs: float  # QK^T + SV work in bit-MACs

    @property
    def total_bytes(self) -> float:
        return self.k_bytes + self.v_bytes

    def normalized_to(self, other: "Complexity") -> dict:
        return {
            "mem": self.total_bytes / max(other.total_bytes, 1e-9),
            "compute": self.compute_bitmacs / max(other.compute_bitmacs, 1e-9),
        }


def dense_complexity(Sq: int, Sk: int, d: int, dv: int, bits: int = 12) -> Complexity:
    """Dense INT12 attention: full K and V fetched, full QK^T and SV."""
    k_bytes = Sk * d * bits / 8
    v_bytes = Sk * dv * bits / 8
    qk = Sq * Sk * d * bits * bits
    sv = Sq * Sk * dv * bits * bits
    return Complexity(k_bytes, v_bytes, qk + sv)


def besf_complexity(
    planes_fetched: np.ndarray,   # [.., Sq, Sk] int
    survivors: np.ndarray,        # [.., Sq, Sk] bool
    d: int,
    dv: int,
    bits: int = 12,
    mode: str = "per_pair",
) -> Complexity:
    """Traffic/compute of the faithful BESF run from its stats."""
    pf = np.asarray(planes_fetched, dtype=np.float64)
    sv_mask = np.asarray(survivors)
    if mode == "per_pair":
        plane_fetches = pf.sum()                       # (pair, plane) count
        v_rows = sv_mask.sum()
    elif mode == "shared":
        # Plane (j, r) fetched iff any query reached round r for key j.
        max_r = pf.max(axis=-2)                        # [.., Sk]
        plane_fetches = max_r.sum()
        v_rows = sv_mask.any(axis=-2).sum()
    else:
        raise ValueError(mode)
    k_bytes = plane_fetches * d / 8                    # 1 bit x d per plane
    v_bytes = v_rows * dv * bits / 8
    qk = pf.sum() * d * bits * 1                       # INT12 x 1-bit MACs
    sv = sv_mask.sum() * dv * bits * bits
    return Complexity(float(k_bytes), float(v_bytes), float(qk + sv))


def block_besf_complexity(
    rounds_per_block: np.ndarray,  # [.., n_qt, n_kb]
    block_alive: np.ndarray,       # [.., n_qt, n_kb] bool
    survivors: np.ndarray,         # [.., Sq, Sk] bool
    block_q: int,
    block_k: int,
    d: int,
    dv: int,
    bits: int = 12,
) -> Complexity:
    """Traffic of the TPU block-granular variant (DMA = block x plane)."""
    r = np.asarray(rounds_per_block, dtype=np.float64)
    k_bytes = r.sum() * block_k * d / 8
    v_bytes = np.asarray(block_alive).sum() * block_k * dv * bits / 8
    qk = r.sum() * block_q * block_k * d * bits
    sv = np.asarray(survivors).sum() * dv * bits * bits
    return Complexity(float(k_bytes), float(v_bytes), float(qk + sv))


def predictor_complexity(
    Sq: int,
    Sk: int,
    d: int,
    dv: int,
    kept: np.ndarray,             # [.., Sq, Sk] bool — pairs kept by predictor
    pred_bits: int,
    exec_bits: int = 12,
    mode: str = "per_pair",
    batch: int = 1,
) -> Complexity:
    """Two-stage DS accelerators (Sanger/SOFA-style): predictor fetches the
    *full* K at pred_bits, executor re-fetches surviving K at exec_bits."""
    kept = np.asarray(kept)
    k_pred = batch * Sk * d * pred_bits / 8
    if mode == "per_pair":
        exec_rows = kept.sum()
    else:
        exec_rows = kept.any(axis=-2).sum()
    k_exec = exec_rows * d * exec_bits / 8
    v_bytes = exec_rows if mode == "per_pair" else kept.any(axis=-2).sum()
    v_bytes = v_bytes * dv * exec_bits / 8
    qk = batch * Sq * Sk * d * pred_bits * pred_bits + kept.sum() * d * exec_bits ** 2
    sv = kept.sum() * dv * exec_bits ** 2
    return Complexity(float(k_pred + k_exec), float(v_bytes), float(qk + sv))


def chunk_progressive_complexity(
    chunks_fetched: np.ndarray,   # [.., Sq, Sk] int — 4-bit chunks consumed
    survivors: np.ndarray,
    d: int,
    dv: int,
    chunk_bits: int = 4,
    exec_bits: int = 12,
    mode: str = "per_pair",
) -> Complexity:
    """TokenPicker-style progressive chunking (reuses partials, no re-fetch)."""
    cf = np.asarray(chunks_fetched, dtype=np.float64)
    sv_mask = np.asarray(survivors)
    if mode == "per_pair":
        fetches = cf.sum()
        v_rows = sv_mask.sum()
    else:
        fetches = cf.max(axis=-2).sum()
        v_rows = sv_mask.any(axis=-2).sum()
    k_bytes = fetches * d * chunk_bits / 8
    v_bytes = v_rows * dv * exec_bits / 8
    qk = cf.sum() * d * exec_bits * chunk_bits
    sv = sv_mask.sum() * dv * exec_bits ** 2
    return Complexity(float(k_bytes), float(v_bytes), float(qk + sv))
