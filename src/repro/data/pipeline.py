"""Synthetic LM data pipeline (offline container: no external corpora).

Generates a *structured* token stream — a mixture of Zipfian unigrams and
repeated n-gram motifs — so a small LM actually has something to learn
(needed for the Fig-13a quality/efficiency reproduction, where we measure
loss deltas under BitStopper pruning).  Deterministic per (seed, step,
shard), so restarted/elastic runs replay identical batches: a checkpoint
at step N resumes bit-identically on any surviving topology.

Host-side double-buffer prefetch thread overlaps generation with compute.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    n_motifs: int = 64
    motif_prob: float = 0.35


class SyntheticLMDataset:
    """Deterministic synthetic LM batches, shardable by data-parallel rank."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1,
                 prefetch: int = 2):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        rng = np.random.default_rng(cfg.seed)
        self._motifs = rng.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len), dtype=np.int32)
        # Zipf over a shuffled alphabet so token ids don't correlate w/ rank.
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab).astype(np.int32)
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- deterministic batch synthesis --------------------------------------

    def batch_at(self, step: int) -> np.ndarray:
        """[local_batch, seq_len] int32 for this shard at this step."""
        cfg = self.cfg
        out = np.empty((self.local_batch, cfg.seq_len), np.int32)
        for i in range(self.local_batch):
            # seed by GLOBAL row index so shards tile the global batch
            # exactly (straggler reassignment depends on this).
            grow = self.shard * self.local_batch + i
            row_seed = cfg.seed * 1_000_003 + step * 131 + grow * 977
            rng = np.random.default_rng(row_seed)
            seq = self._perm[
                rng.choice(cfg.vocab, size=cfg.seq_len, p=self._probs)]
            # Splice motifs: learnable repeated structure.
            pos = 0
            while pos + cfg.motif_len < cfg.seq_len:
                if rng.random() < cfg.motif_prob:
                    m = self._motifs[rng.integers(cfg.n_motifs)]
                    seq[pos: pos + cfg.motif_len] = m
                    pos += cfg.motif_len
                else:
                    pos += rng.integers(4, 32)
            out[i] = seq
        return out

    # -- prefetch ------------------------------------------------------------

    def start_prefetch(self, start_step: int = 0):
        def worker():
            step = start_step
            while not self._stop.is_set():
                batch = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._queue.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1
        self._stop.clear()
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next_batch(self):
        return self._queue.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
