"""Data pipeline: synthetic LM stream with host prefetch + shard slicing."""

from repro.data.pipeline import DataConfig, SyntheticLMDataset  # noqa: F401
