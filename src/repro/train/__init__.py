"""Training substrate: optimizer, schedules, train step, fault-tolerant loop."""
