"""AdamW in plain JAX (no optax dependency), FSDP-friendly.

Optimizer state mirrors the parameter tree, so GSPMD shards it with the
same PartitionSpecs (ZeRO: the m/v moments live wherever the param shard
lives).  Options: bf16 moments (halves optimizer HBM for the 671B config)
and decoupled weight decay.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # "bfloat16" halves optimizer memory


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), gnorm


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def cosine_schedule(step, *, warmup: int, total: int, min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
