"""The fault-tolerant training loop.

Responsibilities:
* jit the train step with sharded in/out (when given MeshRules),
* checkpoint every ``ckpt_every`` steps (async, atomic) + resume-from-latest,
* straggler deadline tracking (EMA policy),
* step-retry on transient failure (``max_retries`` then re-raise),
* deterministic data: batch(step) is a pure function, so resume/elastic
  re-mesh replays identical data (no skew between surviving workers).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset
from repro.models.config import ModelConfig
from repro.runtime import StragglerPolicy
from repro.sharding.api import MeshRules, use_rules
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    max_retries: int = 2
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 run: TrainerConfig, rules: MeshRules | None = None,
                 data_cfg: DataConfig | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.run = run
        self.rules = rules
        self.data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, seq_len=256, global_batch=8, seed=run.seed)
        self.dataset = SyntheticLMDataset(self.data_cfg)
        self.ckpt = CheckpointManager(run.ckpt_dir)
        self.straggler = StragglerPolicy()
        self.metrics_log: list[dict] = []

        step_fn = make_train_step(cfg, tcfg)
        if rules is not None:
            state_like = jax.eval_shape(
                lambda k: init_train_state(k, cfg, tcfg),
                jax.random.PRNGKey(run.seed))
            # Path-based rules: "opt/m/.../attn/wq/w" matches the same
            # pattern as the parameter, so moments shard with their param.
            state_shardings = rules.tree_shardings(state_like)
            data_sharding = rules.sharding(("batch", None))
            self._step = jax.jit(
                step_fn,
                in_shardings=(state_shardings, data_sharding),
                out_shardings=(state_shardings, None),
            )
        else:
            self._step = jax.jit(step_fn)

    # ------------------------------------------------------------------

    def init_or_resume(self):
        state = init_train_state(jax.random.PRNGKey(self.run.seed),
                                 self.cfg, self.tcfg)
        start = 0
        try:
            state, ck_step = self.ckpt.restore(state)
            start = ck_step
            print(f"[trainer] resumed from step {ck_step}")
        except FileNotFoundError:
            pass
        return state, start

    def train(self, on_step: Callable[[int, dict], None] | None = None):
        state, start = self.init_or_resume()
        with use_rules(self.rules):
            for step in range(start, self.run.steps):
                batch = jax.numpy.asarray(self.dataset.batch_at(step))
                t0 = time.monotonic()
                state, metrics = self._run_with_retry(state, batch)
                dt = time.monotonic() - t0
                self.straggler.observe(dt)
                m = {k: float(v) for k, v in metrics.items()}
                m["step_time"] = dt
                m["straggler"] = self.straggler.is_straggler(dt)
                self.metrics_log.append(m)
                if on_step:
                    on_step(step, m)
                if self.run.log_every and step % self.run.log_every == 0:
                    print(f"[trainer] step {step} loss {m['loss']:.4f} "
                          f"gnorm {m['grad_norm']:.3f} ({dt*1e3:.0f} ms)")
                if (step + 1) % self.run.ckpt_every == 0:
                    self.ckpt.save_async(state, step + 1)
        self.ckpt.save_sync(state, self.run.steps)
        self.ckpt.wait()
        return state

    def _run_with_retry(self, state, batch):
        last_err = None
        for attempt in range(self.run.max_retries + 1):
            try:
                return self._step(state, batch)
            except Exception as e:  # transient device/runtime failure
                last_err = e
                print(f"[trainer] step failed (attempt {attempt + 1}): {e}")
                time.sleep(0.1 * (attempt + 1))
        raise last_err
