"""The jit-compiled train step: loss, grads, microbatching, compression.

* **loss** — next-token cross-entropy (+ MoE aux + optional MTP at t+2).
* **grad accumulation** — ``microbatches > 1`` scans over batch slices,
  trading HBM for time (the dry-run's knob for fitting train_4k).
* **int8 gradient compression with error feedback** — per-leaf symmetric
  int8 quantization before the data-parallel all-reduce, with the
  quantization residual carried to the next step (error feedback keeps the
  noise unbiased over time).  Under GSPMD the all-reduce is implicit; the
  compression happens in a ``shard_map`` wrapper over the data axes so the
  reduced bytes really are int8 on the wire.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWConfig, adamw_update, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1
    mtp_weight: float = 0.3
    grad_compression: str = "none"     # none | int8_ef


def lm_loss(logits, tokens, ignore_last: bool = True):
    """Next-token NLL.  logits [B,S,V] f32, tokens [B,S]."""
    tgt = jnp.roll(tokens, -1, axis=1)
    ll = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1)[..., 0]
    if ignore_last:
        w = jnp.ones_like(nll).at[:, -1].set(0.0)
        return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    return nll.mean()


def chunked_lm_loss(h, params, targets, cfg: ModelConfig,
                    chunk: int = 512, shift: int = 1):
    """Seq-chunked LM head + NLL — never materializes [B, S, vocab].

    Essential for big-vocab configs (deepseek 129k × 4k seq would be 34 GB
    of logits per device): each scan step computes one [B, chunk, V] slice
    (vocab-sharded under GSPMD) and reduces it immediately.  The target
    log-prob is taken with a one-hot einsum rather than take_along_axis so
    the vocab axis never needs gathering.
    """
    from repro.models import layers as Lyr

    B, S, _ = h.shape
    c = min(chunk, S)
    pad = (-S) % c
    tgt = jnp.roll(targets, -shift, axis=1)
    w = jnp.ones((B, S), jnp.float32)
    w = w.at[:, S - shift:].set(0.0)
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, 0), (0, pad)))
    n = h.shape[1] // c
    hc = h.reshape(B, n, c, -1).swapaxes(0, 1)
    tc = tgt.reshape(B, n, c).swapaxes(0, 1)
    wc = w.reshape(B, n, c).swapaxes(0, 1)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["unembed"]["w"].T)

    @jax.checkpoint  # recompute per-chunk logits in backward: never keep
    def step(carry, inp):  # more than one [B, c, V] slice alive.
        hx, tx, wx = inp
        logits = jax.lax.dot_general(
            hx, table, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # [B, c, V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(tx, logits.shape[-1], dtype=logits.dtype)
        tgt_logit = jnp.einsum("bcv,bcv->bc", logits, onehot)
        nll = (lse - tgt_logit) * wx
        return (carry[0] + nll.sum(), carry[1] + wx.sum()), None

    (total, count), _ = jax.lax.scan(step, (0.0, 0.0), (hc, tc, wc))
    return total / jnp.maximum(count, 1.0)


def loss_fn(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    """batch: tokens [B,S] int32, or {"tokens": ..., "patches": [B,P,D]}
    for stubbed-frontend VLM archs (loss over the text positions)."""
    from repro.models import layers as Lyr
    from repro.sharding.api import constrain

    tokens = batch["tokens"] if isinstance(batch, dict) else batch
    patches = batch.get("patches") if isinstance(batch, dict) else None
    n_patch = 0
    if patches is not None:
        n_patch = patches.shape[1]
        text = Lyr.embed(params["embed"], tokens)
        x = jnp.concatenate([patches.astype(text.dtype), text], axis=1)
        x = x.astype(cfg.activation_dtype)
    else:
        x = Lyr.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])
    h, _, aux = T.run_segments(params, x, positions, cfg)
    if n_patch:
        h = h[:, n_patch:]                 # loss over the text positions
        positions = positions[: h.shape[1]]
    hn = Lyr.norm(params["final_norm"], h)
    loss = chunked_lm_loss(hn, params, tokens, cfg) + aux
    if cfg.mtp:
        # MTP shares the trunk: one extra block over [h_t ; emb(t+1)]
        # predicting token t+2 (chunked head again — no [B,S,V] tensor).
        emb_next = Lyr.embed(params["embed"], jnp.roll(tokens, -1, axis=1))
        cat = jnp.concatenate(
            [Lyr.norm(params["mtp_norm"], h), emb_next.astype(h.dtype)],
            axis=-1)
        xm = Lyr.linear(params["mtp_proj"], cat)
        spec = cfg.segments[-1][0][-1]
        xm, _, _ = T.block_forward(params["mtp_block"], xm, positions, spec,
                                   cfg)
        hm = Lyr.norm(params["final_norm"], xm)
        loss = loss + tcfg.mtp_weight * chunked_lm_loss(
            hm, params, tokens, cfg, shift=2)
    return loss


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------


def _compress_int8(g, err):
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq            # residual -> error feedback


def compress_grads(grads, err_state):
    """Returns (int8 tree, scale tree, new error state)."""
    qs = jax.tree_util.tree_map(_compress_int8, grads, err_state)
    q = jax.tree_util.tree_map(lambda t: t[0], qs,
                               is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree_util.tree_map(lambda t: t[1], qs,
                               is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree_util.tree_map(lambda t: t[2], qs,
                               is_leaf=lambda x: isinstance(x, tuple))
    return q, s, e


def decompress_grads(q, s):
    return jax.tree_util.tree_map(
        lambda qi, si: qi.astype(jnp.float32) * si, q, s)


def allreduce_int8_ef(grads, err_state, mesh, data_axes=("data",)):
    """shard_map int8 all-reduce over the data axes with error feedback.

    Grad leaves are assumed data-replicated per shard (GSPMD has already
    reduce-scattered FSDP shards); the wire format of the cross-replica sum
    becomes int8 + one f32 scale per leaf.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(a for a in data_axes if a in mesh.shape)

    def body(g, e):
        q, s, e_new = compress_grads(g, e)
        q_sum = jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x.astype(jnp.int32), axes), q)
        s_max = jax.tree_util.tree_map(lambda x: jax.lax.pmax(x, axes), s)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        g_new = jax.tree_util.tree_map(
            lambda qi, si: qi.astype(jnp.float32) * si / n, q_sum, s_max)
        return g_new, e_new

    specs = jax.tree_util.tree_map(lambda _: P(), grads)
    return shard_map(
        body, mesh=mesh,
        in_specs=(specs, specs), out_specs=(specs, specs),
        check_rep=False,
    )(grads, err_state)


# ---------------------------------------------------------------------------
# The step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns step(state, tokens) -> (state, metrics); jit at the call site
    (the launcher attaches in/out shardings)."""

    def grads_of(params, tokens):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, tokens, cfg, tcfg)

        def slice_mb(x):
            mb = x.shape[0] // tcfg.microbatches
            return x.reshape((tcfg.microbatches, mb) + x.shape[1:])

        slices = jax.tree_util.tree_map(slice_mb, tokens)

        def acc_fn(carry, batch):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, batch, cfg, tcfg)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            return (loss_acc + l, g_acc), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, gsum), _ = jax.lax.scan(acc_fn, (0.0, zeros), slices)
        inv = 1.0 / tcfg.microbatches
        return loss * inv, jax.tree_util.tree_map(lambda g: g * inv, gsum)

    def step(state, tokens):
        from repro.sharding.api import current_rules
        params, opt, err = state["params"], state["opt"], state.get("err")
        loss, grads = grads_of(params, tokens)
        rules = current_rules()
        if rules is not None:
            # Pin gradient shardings to the parameter layout: without this
            # the backward scan emits *unsharded* stacked f32 grads
            # (measured +1.25 GiB/layer at 12B scale).
            grads = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, grads,
                rules.tree_shardings(grads))
        if tcfg.grad_compression == "int8_ef" and err is not None:
            from repro.sharding.api import current_rules
            rules = current_rules()
            if rules is not None:
                data_axes = tuple(a for a in ("pod", "data")
                                  if a in rules.mesh.shape)
                grads, err = allreduce_int8_ef(grads, err, rules.mesh,
                                               data_axes)
        lr_scale = cosine_schedule(
            opt["step"], warmup=tcfg.warmup_steps, total=tcfg.total_steps)
        params, opt, metrics = adamw_update(params, grads, opt,
                                            tcfg.optimizer, lr_scale)
        new_state = dict(state, params=params, opt=opt)
        if err is not None:
            new_state["err"] = err
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return step


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig):
    from repro.train.optimizer import init_opt_state
    params = T.init_model(key, cfg)
    state: dict[str, Any] = {
        "params": params,
        "opt": init_opt_state(params, tcfg.optimizer),
    }
    if tcfg.grad_compression == "int8_ef":
        state["err"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state
