"""Shared finding/report types for the analysis subsystem.

Every checker emits :class:`Finding` records — one per violation, each
anchored to a file and line — so the CLI can aggregate per-rule counts
into ``results/ANALYSIS.json`` and tests can assert that a seeded
violation is reported *where* it was seeded.
"""

from __future__ import annotations

import dataclasses

# Canonical kernel-contract rule ids.  Defined here (not in
# kernel_contracts.py) so the CLI can enumerate every rule without
# importing jax.
KERNEL_RULES = [
    "kernel-index-map-bounds",
    "kernel-output-coverage",
    "kernel-block-divisor",
    "kernel-tile-multiple",
    "kernel-scalar-prefetch",
    "kernel-interpret-routing",
    "kernel-scratch",
    "kernel-contract-run",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a specific source location."""

    rule: str       # stable rule id, e.g. "kernel-index-map-bounds"
    file: str       # path (repo-relative when possible)
    line: int       # 1-based line number (0 when no better anchor exists)
    message: str    # human-readable explanation

    def format(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}"


def summarize(findings: list[Finding],
              all_rules: list[str] | None = None) -> dict[str, int]:
    """Per-rule finding counts.  ``all_rules`` seeds zero-count entries so
    the JSON report shows every rule that *ran*, not just ones that
    fired."""
    counts: dict[str, int] = {r: 0 for r in (all_rules or [])}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return counts
