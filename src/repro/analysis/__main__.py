"""``python -m repro.analysis`` — run the repo's static analyzers.

Sections (select with ``--only``, default all three):

* ``kernels`` — Pallas kernel-contract checker (abstract-evals every
  ``pl.pallas_call`` across shape sweeps; see ``kernel_contracts.py``).
* ``pool``    — KV-pool sanitizer self-check (a blind detector would let
  CI keep trusting a broken ledger; see ``pool_sanitizer.py``).
* ``lint``    — repo-rule AST lint over ``src/`` (``lint.py``).

Exit status: 0 when clean; with ``--check``, 1 when any finding is
reported (CI gates on this).  A machine-readable per-rule summary is
always written to ``--out`` (default ``results/ANALYSIS.json``).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

from repro.analysis.lint import LINT_RULES, run_lint
from repro.analysis.pool_sanitizer import POOL_RULES, run_pool_selfcheck
from repro.analysis.report import KERNEL_RULES, summarize

# kernel_contracts itself imports jax — deferred below so `--only lint`
# and `--only pool` stay instant.
ALL_RULES = KERNEL_RULES + POOL_RULES + LINT_RULES

SECTIONS = ("kernels", "pool", "lint")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analyzers: Pallas kernel contracts, KV-pool "
                    "sanitizer self-check, repo-rule lint.")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any finding is reported (CI gate)")
    ap.add_argument("--only", choices=SECTIONS, action="append",
                    help="run only this section (repeatable)")
    ap.add_argument("--root", default=".",
                    help="repo root for the lint section (default: cwd)")
    ap.add_argument("--out", default="results/ANALYSIS.json",
                    help="JSON report path (default: results/ANALYSIS.json)")
    ap.add_argument("--list", action="store_true",
                    help="list rule ids and kernel entry points, then exit")
    args = ap.parse_args(argv)

    if args.list:
        from repro.analysis.kernel_contracts import CONTRACTS
        print("rules:")
        for r in ALL_RULES:
            print(f"  {r}")
        print("kernel entry points:")
        for c in CONTRACTS:
            print(f"  {c.module}")
        return 0

    sections = tuple(args.only) if args.only else SECTIONS
    findings = []
    meta: dict = {"sections": list(sections)}

    if "kernels" in sections:
        from repro.analysis.kernel_contracts import run_kernel_contracts
        kf, km = run_kernel_contracts()
        findings += kf
        meta["kernel_entry_points"] = km["entry_points"]
        meta["cases"] = km["cases"]
        meta["pallas_calls_checked"] = km["pallas_calls_checked"]
    if "pool" in sections:
        pf, pm = run_pool_selfcheck()
        findings += pf
        meta["pool_scenarios"] = pm["scenarios"]
    if "lint" in sections:
        findings += run_lint(args.root)

    rules = summarize(findings, ALL_RULES)
    report = {
        "ok": not findings,
        "rules": rules,
        "findings": [dataclasses.asdict(f) for f in findings],
        **meta,
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")

    for f in findings:
        print(f.format())
    n_rules = sum(1 for v in rules.values() if v)
    print(f"repro.analysis: {len(findings)} finding(s) across "
          f"{n_rules} rule(s); sections: {', '.join(sections)}; "
          f"report: {out}")
    return 1 if (args.check and findings) else 0


if __name__ == "__main__":
    sys.exit(main())
