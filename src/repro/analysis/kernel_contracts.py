"""Static Pallas kernel-contract checker.

Every Pallas entry point in the repo is listed in :data:`CONTRACTS` with
a sweep of representative shape cases.  Each case invokes the entry
point under an interception context that replaces ``pl.pallas_call``
with a recorder: the kernel body never runs — the recorder captures the
grid, BlockSpecs, operand/output avals, scratch shapes and the resolved
``interpret`` flag, and returns zeros of ``out_shape`` so the
surrounding host code traces through.  The captured records are then
checked *statically*:

* ``kernel-index-map-bounds``  — every BlockSpec index map, evaluated at
  every grid point, yields in-range block indices for its operand.
* ``kernel-output-coverage``   — the union of blocks an output's index
  map visits over the whole grid covers the output (no never-written
  block of garbage memory escapes the kernel).
* ``kernel-block-divisor``     — block shapes have the operand's rank
  and divide its dims (the repo pads to block multiples by contract).
* ``kernel-tile-multiple``     — at production shapes (``tile_check``
  cases) blocked dims respect the TPU native tile: a blocked last dim is
  the full dim or a multiple of 128, a blocked sublane dim the full dim
  or a multiple of the dtype's min sublane (f32 8, bf16 16, int8 32).
* ``kernel-scalar-prefetch``   — ``PrefetchScalarGridSpec`` scalar
  operands are integer arrays (they become SMEM DMA addressing).
* ``kernel-interpret-routing`` — the entry resolved ``interpret``
  through ``kernels/runtime.py:resolve_interpret`` and passed exactly
  that to ``pallas_call`` (observed via a spy on the module binding).
* ``kernel-scratch``           — scratch shapes equal the contract's
  declared shapes for the case's parameters (swept across cases, this
  proves scratch scales with the grid/block geometry, not the operand),
  and the VMEM working set (blocked operands + scratch) fits the ~16 MB
  per-core budget.
* ``kernel-contract-run``      — the case ran and produced at least one
  record (a silent zero-record case would vacuously pass everything).

Interception notes: entry points are invoked through ``.__wrapped__``
(the un-jitted function under ``functools.partial(jax.jit, ...)``) so
tracing always reaches ``pallas_call``; ``jax.clear_caches()`` runs
before and after every case so traces of inner jitted kernels built
against the fake ``pallas_call`` can never leak into later real calls.
"""

from __future__ import annotations

import contextlib
import dataclasses
import importlib
import itertools
import os
import traceback
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.report import KERNEL_RULES, Finding
from repro.kernels.runtime import resolve_interpret

__all__ = ["KERNEL_RULES", "PallasCallRecord", "record_pallas_calls",
           "spy_resolve_interpret", "check_record", "Case",
           "KernelContract", "CONTRACTS", "run_kernel_contracts"]

VMEM_BUDGET_BYTES = 16 * 1024 * 1024
_LANE = 128


@dataclasses.dataclass
class PallasCallRecord:
    """One intercepted ``pl.pallas_call`` invocation."""

    file: str
    line: int
    grid: tuple[int, ...]
    in_specs: list
    out_specs: list
    operands: list          # ShapeDtypeStruct per operand (incl. scalars)
    out_shapes: list        # ShapeDtypeStruct per output
    scratch: list           # raw scratch_shapes entries
    num_scalar_prefetch: int
    interpret: bool


def _aval(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(x.shape), jnp.dtype(x.dtype))


def _call_site() -> tuple[str, int]:
    """Source location of the pallas_call invocation: the innermost frame
    that is neither this module nor jax internals."""
    here = os.path.abspath(__file__)
    for fr in reversed(traceback.extract_stack()):
        fn = os.path.abspath(fr.filename)
        if fn == here:
            continue
        if os.sep + "jax" + os.sep in fn or os.sep + "jaxlib" + os.sep in fn:
            continue
        return fr.filename, fr.lineno or 0
    return "<unknown>", 0


@contextlib.contextmanager
def record_pallas_calls():
    """Replace ``pl.pallas_call`` with a recorder that skips kernel
    execution and returns zeros of ``out_shape``.  Yields the list of
    :class:`PallasCallRecord` as they are captured."""
    records: list[PallasCallRecord] = []
    real = pl.pallas_call

    def fake_pallas_call(kernel, *, grid_spec=None, grid=None,
                         in_specs=None, out_specs=None, out_shape=None,
                         scratch_shapes=(), interpret=False, **kw):
        file, line = _call_site()
        if grid_spec is not None:
            g = grid_spec.grid
            ins = list(grid_spec.in_specs)
            outs = grid_spec.out_specs
            scratch = list(grid_spec.scratch_shapes or ())
            nsp = getattr(grid_spec, "num_scalar_prefetch", 0) or 0
        else:
            g = grid if isinstance(grid, tuple) else (grid,)
            ins = list(in_specs or [])
            outs = out_specs
            scratch = list(scratch_shapes or ())
            nsp = 0
        outs = list(outs) if isinstance(outs, (list, tuple)) else [outs]
        single_out = not isinstance(out_shape, (list, tuple))
        shapes = [out_shape] if single_out else list(out_shape)

        def runner(*args):
            records.append(PallasCallRecord(
                file=file, line=line,
                grid=tuple(int(d) for d in g),
                in_specs=ins, out_specs=outs,
                operands=[_aval(a) for a in args],
                out_shapes=[jax.ShapeDtypeStruct(tuple(s.shape),
                                                 jnp.dtype(s.dtype))
                            for s in shapes],
                scratch=scratch,
                num_scalar_prefetch=int(nsp),
                interpret=bool(interpret),
            ))
            zeros = [jnp.zeros(s.shape, s.dtype) for s in shapes]
            return zeros[0] if single_out else zeros

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        yield records
    finally:
        pl.pallas_call = real


@contextlib.contextmanager
def spy_resolve_interpret(module_names: tuple[str, ...]):
    """Wrap each kernel module's ``resolve_interpret`` binding (they all
    ``from ... import resolve_interpret``, so the binding is per-module)
    with a recorder.  Yields ``{module: [resolved values]}``."""
    calls: dict[str, list[bool]] = {m: [] for m in module_names}
    originals = {}

    def make_spy(name, orig):
        def spy(x):
            r = orig(x)
            calls[name].append(r)
            return r
        return spy

    for name in module_names:
        mod = importlib.import_module(name)
        originals[name] = mod.resolve_interpret
        mod.resolve_interpret = make_spy(name, originals[name])
    try:
        yield calls
    finally:
        for name in module_names:
            importlib.import_module(name).resolve_interpret = originals[name]


# ---------------------------------------------------------------------------
# record checks
# ---------------------------------------------------------------------------

def _min_sublane(dtype) -> int:
    return max(8, 32 // jnp.dtype(dtype).itemsize)


def _blocked(spec) -> bool:
    bs = getattr(spec, "block_shape", None)
    return bs is not None and all(isinstance(b, int) for b in bs)


def _eval_index_map(spec, idx, nsp):
    return spec.index_map(*idx, *([0] * nsp))


def check_record(rec: PallasCallRecord, *,
                 expected_interpret: bool | None = None,
                 expected_scratch: list | None = None,
                 expected_sems: int | None = None,
                 tile_check: bool = False) -> list[Finding]:
    findings: list[Finding] = []
    where = (rec.file, rec.line)

    def report(rule, msg):
        findings.append(Finding(rule, where[0], where[1], msg))

    # scalar-prefetch operands must be integers
    for i in range(min(rec.num_scalar_prefetch, len(rec.operands))):
        dt = rec.operands[i].dtype
        if not jnp.issubdtype(dt, jnp.integer):
            report("kernel-scalar-prefetch",
                   f"scalar-prefetch operand #{i} has dtype {dt} — SMEM "
                   f"addressing operands must be integer arrays")

    tensor_ops = rec.operands[rec.num_scalar_prefetch:]
    pairs = ([("in", i, s, a) for i, (s, a) in
              enumerate(zip(rec.in_specs, tensor_ops))]
             + [("out", i, s, a) for i, (s, a) in
                enumerate(zip(rec.out_specs, rec.out_shapes))])

    vmem_bytes = 0
    grid_points = list(itertools.product(*(range(d) for d in rec.grid)))

    for kind, i, spec, aval in pairs:
        if not _blocked(spec):
            continue                     # ANY / SMEM / full-array specs
        block = tuple(spec.block_shape)
        label = f"{kind}_specs[{i}]"
        if len(block) != len(aval.shape):
            report("kernel-block-divisor",
                   f"{label}: block rank {len(block)} != operand rank "
                   f"{len(aval.shape)} (shape {aval.shape})")
            continue
        bad_div = False
        for d, (b, s) in enumerate(zip(block, aval.shape)):
            if b < 1 or s % b:
                report("kernel-block-divisor",
                       f"{label}: block dim {d} of size {b} does not "
                       f"divide operand dim {s} (shape {aval.shape}, "
                       f"block {block})")
                bad_div = True
        if bad_div:
            continue
        vmem_bytes += _size_bytes(block, aval.dtype)
        if tile_check and len(block) >= 2:
            b_lane, s_lane = block[-1], aval.shape[-1]
            if b_lane > 1 and b_lane != s_lane and b_lane % _LANE:
                report("kernel-tile-multiple",
                       f"{label}: blocked last dim {b_lane} is neither "
                       f"the full dim ({s_lane}) nor a multiple of "
                       f"{_LANE} lanes")
            b_sub, s_sub = block[-2], aval.shape[-2]
            sub = _min_sublane(aval.dtype)
            if b_sub > 1 and b_sub != s_sub and b_sub % sub:
                report("kernel-tile-multiple",
                       f"{label}: blocked sublane dim {b_sub} is neither "
                       f"the full dim ({s_sub}) nor a multiple of the "
                       f"{jnp.dtype(aval.dtype).name} min sublane {sub}")

        nblocks = tuple(s // b for s, b in zip(aval.shape, block))
        visited: set[tuple[int, ...]] = set()
        oob_reported = False
        for idx in grid_points:
            try:
                bi = _eval_index_map(spec, idx, rec.num_scalar_prefetch)
            except Exception as e:       # noqa: BLE001 — any failure is a finding
                report("kernel-index-map-bounds",
                       f"{label}: index map not statically evaluable at "
                       f"grid point {idx}: {e}")
                oob_reported = True
                break
            bi = tuple(int(x) for x in (bi if isinstance(bi, tuple)
                                        else (bi,)))
            if len(bi) != len(block):
                report("kernel-index-map-bounds",
                       f"{label}: index map returned {len(bi)} indices "
                       f"for a rank-{len(block)} block")
                oob_reported = True
                break
            if any(x < 0 or x >= n for x, n in zip(bi, nblocks)):
                report("kernel-index-map-bounds",
                       f"{label}: index map at grid point {idx} yields "
                       f"block index {bi}, outside the {nblocks} block "
                       f"grid of operand shape {aval.shape}")
                oob_reported = True
                break
            visited.add(bi)
        if kind == "out" and not oob_reported:
            want = set(itertools.product(*(range(n) for n in nblocks)))
            missing = want - visited
            if missing:
                report("kernel-output-coverage",
                       f"{label}: {len(missing)} of {len(want)} output "
                       f"block(s) never written over the {rec.grid} grid "
                       f"(e.g. block {sorted(missing)[0]})")

    # scratch
    vmem_scratch, n_sems = [], 0
    for s in rec.scratch:
        shape = getattr(s, "shape", None)
        dtype = getattr(s, "dtype", None)
        try:
            dt = jnp.dtype(dtype) if dtype is not None else None
        except TypeError:
            dt = None                    # semaphore dtypes aren't numpy dtypes
        if shape is not None and dt is not None:
            vmem_scratch.append((tuple(shape), dt))
            vmem_bytes += _size_bytes(tuple(shape), dt)
        else:
            n_sems += 1
    if expected_scratch is not None:
        want = [(tuple(sh), jnp.dtype(dt)) for sh, dt in expected_scratch]
        if vmem_scratch != want:
            report("kernel-scratch",
                   f"scratch shapes {vmem_scratch} do not match the "
                   f"contract's declared {want} for this case's geometry")
    if expected_sems is not None and n_sems != expected_sems:
        report("kernel-scratch",
               f"{n_sems} semaphore scratch entries, contract declares "
               f"{expected_sems}")
    if vmem_bytes > VMEM_BUDGET_BYTES:
        report("kernel-scratch",
               f"VMEM working set {vmem_bytes} bytes (blocks + scratch) "
               f"exceeds the {VMEM_BUDGET_BYTES} budget")

    if expected_interpret is not None and rec.interpret != expected_interpret:
        report("kernel-interpret-routing",
               f"pallas_call got interpret={rec.interpret} but "
               f"resolve_interpret would give {expected_interpret} — the "
               f"entry point must route interpret through "
               f"kernels/runtime.py:resolve_interpret")
    return findings


def _size_bytes(shape, dtype) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n * jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# contract registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Case:
    label: str
    run: Callable[[], None]
    expected_scratch: Callable[[], list] | None = None
    expected_sems: int | None = None
    tile_check: bool = False


@dataclasses.dataclass
class KernelContract:
    name: str
    module: str                       # module owning the pallas_call
    interpret_modules: tuple[str, ...]
    cases: Callable[[], list[Case]]


def _decode_cases() -> list[Case]:
    from repro.core.besf import BitStopperConfig
    from repro.kernels import paged_decode as m
    cfg = BitStopperConfig()
    bits = cfg.bits

    def mk(B, Hq, Hkv, D, bs, MB, P, window, stats, tile_check=False):
        def run():
            q = jnp.ones((B, Hq, D), jnp.float32)
            kq = jnp.zeros((P, bits, bs // 8, Hkv, D), jnp.uint8)
            v = jnp.zeros((P, bs, Hkv, D), jnp.float32)
            amax = jnp.ones((Hkv,), jnp.float32)
            m.paged_bitstopper_decode.__wrapped__(
                q, kq, v, jnp.zeros((B, MB), jnp.int32),
                jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
                amax, amax, cfg=cfg, window=window, stats=stats)

        def scratch():
            return [((2, bs // 8, Hkv, D), jnp.uint8),
                    ((bs, Hkv, D), jnp.float32),
                    ((Hq, bs), jnp.int32),
                    ((Hq,), jnp.float32),
                    ((Hq,), jnp.float32),
                    ((Hq,), jnp.float32),
                    ((Hq, D), jnp.float32)]

        return Case(
            label=(f"decode B{B} Hq{Hq} Hkv{Hkv} D{D} bs{bs} MB{MB} "
                   f"win{window} stats{stats}"),
            run=run, expected_scratch=scratch, expected_sems=2,
            tile_check=tile_check)

    return [
        mk(2, 4, 2, 8, 8, 3, 5, None, True),
        mk(1, 2, 2, 16, 16, 2, 4, 8, False),
        mk(2, 8, 2, 128, 128, 2, 4, None, True, tile_check=True),
        # Sharded serving (ServeConfig.mesh) calls the kernel inside
        # shard_map at per-shard geometry: local Hkv = n_kv_heads / tp
        # (grouped Q heads ride along), local batch = slots / dp.  The
        # contract must hold at these shapes too — e.g. tp=2 over the
        # Hq4/Hkv2 case above, down to a single local KV head.
        mk(1, 2, 1, 8, 8, 3, 5, None, True),
        mk(1, 4, 1, 128, 128, 2, 4, None, True, tile_check=True),
    ]


def _verify_cases() -> list[Case]:
    from repro.core.besf import BitStopperConfig
    from repro.kernels import paged_verify as m
    cfg = BitStopperConfig()
    bits = cfg.bits

    def mk(B, Sq, Hq, Hkv, D, bs, MB, P, window, stats, tile_check=False):
        SH = Sq * Hq

        def run():
            q = jnp.ones((B, Sq, Hq, D), jnp.float32)
            kq = jnp.zeros((P, bits, bs // 8, Hkv, D), jnp.uint8)
            v = jnp.zeros((P, bs, Hkv, D), jnp.float32)
            amax = jnp.ones((Hkv,), jnp.float32)
            m.paged_bitstopper_verify.__wrapped__(
                q, kq, v, jnp.zeros((B, MB), jnp.int32),
                jnp.zeros((B, Sq), jnp.int32),
                jnp.zeros((B, Sq), jnp.int32),
                amax, amax, cfg=cfg, window=window, stats=stats)

        def scratch():
            return [((2, bs // 8, Hkv, D), jnp.uint8),
                    ((bs, Hkv, D), jnp.float32),
                    ((SH, bs), jnp.int32),
                    ((SH,), jnp.float32),
                    ((SH,), jnp.float32),
                    ((SH,), jnp.float32),
                    ((SH, D), jnp.float32)]

        return Case(
            label=(f"verify B{B} Sq{Sq} Hq{Hq} Hkv{Hkv} D{D} bs{bs} "
                   f"MB{MB} win{window} stats{stats}"),
            run=run, expected_scratch=scratch, expected_sems=2,
            tile_check=tile_check)

    return [
        mk(2, 2, 2, 1, 8, 8, 2, 4, None, True),
        mk(1, 3, 4, 2, 16, 8, 3, 5, 4, False),
        mk(1, 2, 4, 2, 128, 128, 2, 4, None, True, tile_check=True),
        # Per-shard geometry under ServeConfig.mesh (tp=2 over the
        # Hq4/Hkv2 cases above): the Sq-tiled verify kernel must also
        # hold its contract at local Hkv = 1 with grouped Q heads.
        mk(1, 3, 2, 1, 16, 8, 3, 5, 4, False),
        mk(1, 2, 2, 1, 128, 128, 2, 4, None, True, tile_check=True),
    ]


def _bitstopper_cases() -> list[Case]:
    from repro.core.besf import BitStopperConfig
    from repro.kernels import bitstopper_qk as m
    cfg = BitStopperConfig()
    bits = cfg.bits

    def mk(shape_q, Sk, d, bq, bk, causal, tile_check=False):
        def run():
            q = jnp.ones(shape_q + (d,), jnp.float32)
            k = jnp.ones(shape_q[:-1] + (Sk, d), jnp.float32)
            v = jnp.ones(shape_q[:-1] + (Sk, d), jnp.float32)
            m.bitstopper_attention_kernel.__wrapped__(
                q, k, v, cfg=cfg, block_q=bq, block_k=bk, causal=causal)

        def scratch():
            bq_eff = min(bq, shape_q[-1])
            bk_eff = min(bk, Sk)
            return [((2, bk_eff // 8, d), jnp.uint8),
                    ((bk_eff, d), jnp.float32),
                    ((bq_eff, bk_eff), jnp.int32),
                    ((bq_eff,), jnp.float32),
                    ((bq_eff,), jnp.float32),
                    ((bq_eff, d), jnp.float32),
                    ((bq_eff,), jnp.float32)]

        return Case(
            label=f"bitstopper q{shape_q} Sk{Sk} d{d} b{bq}/{bk} "
                  f"causal{causal}",
            run=run, expected_scratch=scratch, expected_sems=2,
            tile_check=tile_check)

    return [
        mk((16,), 16, 8, 8, 8, False),
        mk((8,), 16, 8, 8, 8, True),
        mk((2, 16), 16, 8, 8, 8, False),          # batched: vmapped trace
        mk((256,), 256, 128, 128, 128, True, tile_check=True),
    ]


def _flash_cases() -> list[Case]:
    from repro.kernels import flash_attention as m

    def mk(Sq, Sk, d, bq, bk, causal, tile_check=False):
        def run():
            m.flash_attention_single.__wrapped__(
                jnp.ones((Sq, d), jnp.float32),
                jnp.ones((Sk, d), jnp.float32),
                jnp.ones((Sk, d), jnp.float32),
                causal=causal, block_q=bq, block_k=bk)

        def scratch():
            bq_eff = min(bq, Sq)
            return [((bq_eff,), jnp.float32),
                    ((bq_eff,), jnp.float32),
                    ((bq_eff, d), jnp.float32)]

        return Case(label=f"flash Sq{Sq} Sk{Sk} d{d} b{bq}/{bk} "
                          f"causal{causal}",
                    run=run, expected_scratch=scratch, expected_sems=0,
                    tile_check=tile_check)

    return [
        mk(16, 16, 8, 8, 8, False),
        mk(32, 32, 8, 8, 16, True),
        mk(256, 256, 128, 128, 128, True, tile_check=True),
    ]


def _ops_cases() -> list[Case]:
    from repro.kernels import ops as m

    def run_flash():
        q = jnp.ones((2, 2, 16, 8), jnp.float32)
        m.attention(q, q, q, impl="flash", causal=True,
                    block_q=8, block_k=8)

    def run_bitstopper():
        q = jnp.ones((24, 8), jnp.float32)
        m.attention(q, q, q, impl="bitstopper", causal=False,
                    block_q=8, block_k=8)

    return [
        Case(label="ops impl=flash batched", run=run_flash),
        Case(label="ops impl=bitstopper 2d", run=run_bitstopper),
    ]


CONTRACTS: list[KernelContract] = [
    KernelContract("paged_decode", "repro.kernels.paged_decode",
                   ("repro.kernels.paged_decode",), _decode_cases),
    KernelContract("paged_verify", "repro.kernels.paged_verify",
                   ("repro.kernels.paged_verify",), _verify_cases),
    KernelContract("bitstopper_qk", "repro.kernels.bitstopper_qk",
                   ("repro.kernels.bitstopper_qk",), _bitstopper_cases),
    KernelContract("flash_attention", "repro.kernels.flash_attention",
                   ("repro.kernels.flash_attention",), _flash_cases),
    KernelContract("ops", "repro.kernels.ops",
                   ("repro.kernels.flash_attention",
                    "repro.kernels.bitstopper_qk"), _ops_cases),
]


def run_kernel_contracts(
        contracts: list[KernelContract] | None = None
        ) -> tuple[list[Finding], dict]:
    """Run every contract case; returns (findings, meta) where meta feeds
    the JSON report (entry points covered, case/record counts)."""
    contracts = CONTRACTS if contracts is None else contracts
    findings: list[Finding] = []
    n_cases = n_records = 0
    expected = resolve_interpret(None)
    for contract in contracts:
        mod = importlib.import_module(contract.module)
        mod_file = getattr(mod, "__file__", contract.module)
        for case in contract.cases():
            n_cases += 1
            jax.clear_caches()
            try:
                with spy_resolve_interpret(contract.interpret_modules) \
                        as calls, record_pallas_calls() as recs:
                    case.run()
            except Exception as e:      # noqa: BLE001 — a crash is a finding
                findings.append(Finding(
                    "kernel-contract-run", mod_file, 0,
                    f"{contract.name} [{case.label}] raised during "
                    f"contract tracing: {type(e).__name__}: {e}"))
                continue
            finally:
                jax.clear_caches()
            n_records += len(recs)
            if not recs:
                findings.append(Finding(
                    "kernel-contract-run", mod_file, 0,
                    f"{contract.name} [{case.label}] recorded no "
                    f"pallas_call — entry point no longer reaches Pallas"))
                continue
            if not any(calls.values()):
                findings.append(Finding(
                    "kernel-interpret-routing", mod_file, 0,
                    f"{contract.name} [{case.label}] never called "
                    f"resolve_interpret — interpret must route through "
                    f"kernels/runtime.py"))
            for rec in recs:
                findings.extend(check_record(
                    rec,
                    expected_interpret=expected,
                    expected_scratch=(case.expected_scratch()
                                      if case.expected_scratch else None),
                    expected_sems=case.expected_sems,
                    tile_check=case.tile_check))
    meta = {
        "entry_points": [c.module for c in contracts],
        "cases": n_cases,
        "pallas_calls_checked": n_records,
    }
    return findings, meta
