"""Repo-rule AST lint — rules distilled from bugs earlier PRs actually fixed.

Rules (each maps to one :class:`~repro.analysis.report.Finding` rule id):

* ``repo-private-import`` — no cross-module use of ``_``-private names:
  neither ``from repro.x import _name`` nor ``alias._name`` where
  ``alias`` is an imported module.  Private helpers either stay private
  or get promoted to a public name with a contract.
* ``repo-config-field-unread`` — every declared ``ModelConfig`` /
  ``AttnConfig`` / ``ServeConfig`` field must be *read* somewhere in the
  runtime tree (the ``cfg.causal``-silently-ignored bug class: a config
  knob that nothing reads is a lie to its caller).
* ``repo-allocator-device-ops`` — the host-side block allocator
  (``serving/kv_pool.py``, and this package's sanitizer) is consulted
  between device steps at zero dispatch cost; importing ``jax`` there
  would put device dispatch on the scheduler hot path.
* ``repo-nondeterminism`` — no ``time.time``/``time.time_ns`` or stdlib
  ``random`` in ``src/`` (benchmarks live outside ``src/``): serving is
  schedule-invariant and replayable by construction.  Exemption:
  ``time.time()`` compared against file mtimes (``getmtime``/
  ``st_mtime``) is wall-clock vs wall-clock and stays.
* ``repo-tick-wallclock`` — engine tick paths (``serving/``) may not even
  *import* ``time`` or ``datetime``: every scheduling, fault-injection,
  deadline, and snapshot decision is indexed by the engine's tick
  counter, which is what makes crash/restore traces bit-replayable
  (docs/robustness.md).  The one legitimately wall-clock-driven serving
  component — the stuck-tick watchdog — lives in
  ``runtime/fault_tolerance.py`` and wraps the engine from outside.
* ``repo-async-boundary`` — only ``serving/frontdoor/`` may import
  ``asyncio`` (or spawn threads): the engine is a deterministic,
  synchronous tick loop, and every event-driven concern — admission,
  streaming, shutdown signals — lives behind the front door.  An
  ``asyncio`` import in core ``serving/`` is a scheduler about to grow a
  second, nondeterministic event loop.

All rules work on the AST only — no imports of the scanned code — so the
lint runs in milliseconds and can't be confused by import-time side
effects.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib

from repro.analysis.report import Finding

LINT_RULES = [
    "repo-private-import",
    "repo-config-field-unread",
    "repo-allocator-device-ops",
    "repo-nondeterminism",
    "repo-tick-wallclock",
    "repo-async-boundary",
]


@dataclasses.dataclass(frozen=True)
class ConfigSpec:
    """Where a config dataclass lives and which extra trees count as
    readers (the runtime surfaces; tests don't keep a field alive)."""

    path: str        # file defining the dataclass, relative to root
    cls: str         # dataclass name


DEFAULT_CONFIG_SPECS = [
    ConfigSpec("src/repro/models/config.py", "ModelConfig"),
    ConfigSpec("src/repro/models/attention.py", "AttnConfig"),
    ConfigSpec("src/repro/serving/engine.py", "ServeConfig"),
]

# Host-side allocator modules: pure Python by contract.
DEFAULT_ALLOCATOR_PATHS = [
    "src/repro/serving/kv_pool.py",
    "src/repro/analysis/pool_sanitizer.py",
]

# Engine tick-path trees: tick-indexed and wall-clock-free by contract
# (docs/robustness.md) — a clock read here would make crash/restore
# replay and fault injection nondeterministic.
DEFAULT_TICKPATH_DIRS = [
    "src/repro/serving",
]

_DEVICE_MODULES = ("jax", "jaxlib")

_WALLCLOCK_MODULES = ("time", "datetime")


def _parse(path: pathlib.Path) -> ast.Module | None:
    try:
        return ast.parse(path.read_text())
    except SyntaxError:
        return None


def _module_aliases(tree: ast.Module) -> set[str]:
    """Names bound to *modules* in this file (``import x as y``, and
    ``from pkg import mod``-style imports of submodules)."""
    aliases: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            # `from repro.models import transformer as T` binds a module;
            # `from repro.models.transformer import forward` binds an
            # object.  Statically we can't always tell, but in this repo
            # submodule imports always use an alias or a lowercase module
            # name that is then used with attribute access — treating
            # every from-import name as a *potential* module alias only
            # matters if a private attribute is read off it, which is
            # exactly the pattern the rule forbids either way (private
            # attribute of another module's object).
            for a in node.names:
                aliases.add(a.asname or a.name)
    return aliases


def check_private_imports(files: list[pathlib.Path],
                          root: pathlib.Path) -> list[Finding]:
    out: list[Finding] = []
    for f in files:
        tree = _parse(f)
        if tree is None:
            continue
        rel = str(f.relative_to(root))
        aliases = _module_aliases(tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name.startswith("_") and not a.name.startswith("__"):
                        out.append(Finding(
                            "repo-private-import", rel, node.lineno,
                            f"imports private name `{a.name}` from "
                            f"`{node.module}` — promote it to a public "
                            f"name or keep it module-local"))
            elif isinstance(node, ast.Attribute):
                if (node.attr.startswith("_")
                        and not node.attr.startswith("__")
                        and isinstance(node.value, ast.Name)
                        and node.value.id in aliases):
                    out.append(Finding(
                        "repo-private-import", rel, node.lineno,
                        f"reads private attribute `{node.value.id}."
                        f"{node.attr}` of an imported module"))
    return out


def _dataclass_fields(tree: ast.Module, cls: str) -> list[tuple[str, int]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls:
            return [(st.target.id, st.lineno) for st in node.body
                    if isinstance(st, ast.AnnAssign)
                    and isinstance(st.target, ast.Name)]
    return []


def check_unread_config_fields(
        files: list[pathlib.Path], root: pathlib.Path,
        config_specs: list[ConfigSpec] | None = None) -> list[Finding]:
    """A field is *read* if `.field` appears as an attribute access or as
    a string constant argument to ``getattr`` anywhere in the scanned
    runtime tree.  Deliberately conservative (any object's attribute of
    the same name counts): false negatives beat noisy false positives in
    a gate that blocks CI."""
    specs = DEFAULT_CONFIG_SPECS if config_specs is None else config_specs
    reads: set[str] = set()
    for f in files:
        tree = _parse(f)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute):
                reads.add(node.attr)
            elif (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "getattr"):
                for a in node.args:
                    if isinstance(a, ast.Constant) and isinstance(a.value,
                                                                  str):
                        reads.add(a.value)
    out: list[Finding] = []
    for spec in specs:
        path = root / spec.path
        if not path.exists():
            continue
        tree = _parse(path)
        if tree is None:
            continue
        for name, lineno in _dataclass_fields(tree, spec.cls):
            if name not in reads:
                out.append(Finding(
                    "repo-config-field-unread", spec.path, lineno,
                    f"{spec.cls}.{name} is never read — either wire it "
                    f"into the runtime or delete the field"))
    return out


def check_allocator_device_ops(
        root: pathlib.Path,
        allocator_paths: list[str] | None = None) -> list[Finding]:
    paths = (DEFAULT_ALLOCATOR_PATHS if allocator_paths is None
             else allocator_paths)
    out: list[Finding] = []
    for rel in paths:
        f = root / rel
        if not f.exists():
            continue
        tree = _parse(f)
        if tree is None:
            continue
        for node in ast.walk(tree):
            bad = None
            if isinstance(node, ast.Import):
                bad = next((a.name for a in node.names
                            if a.name.split(".")[0] in _DEVICE_MODULES),
                           None)
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module.split(".")[0] in _DEVICE_MODULES:
                    bad = node.module
            if bad is not None:
                out.append(Finding(
                    "repo-allocator-device-ops", rel, node.lineno,
                    f"host-side allocator imports `{bad}` — the scheduler "
                    f"consults this module between device steps and must "
                    f"stay dispatch-free"))
    return out


def check_tick_wallclock(
        root: pathlib.Path,
        tickpath_dirs: list[str] | None = None) -> list[Finding]:
    """Engine tick paths may not import ``time``/``datetime`` at all.
    Import-level is deliberate: a clock *binding* in a tick-path module is
    one refactor away from a clock *read* in a scheduling decision, and
    the watchdog — the one component that needs a clock — already lives
    outside (``runtime/fault_tolerance.py``) with the clock injected."""
    dirs = (DEFAULT_TICKPATH_DIRS if tickpath_dirs is None
            else tickpath_dirs)
    out: list[Finding] = []
    for rel_dir in dirs:
        d = root / rel_dir
        if not d.exists():
            continue
        for f in sorted(d.rglob("*.py")):
            tree = _parse(f)
            if tree is None:
                continue
            rel = str(f.relative_to(root))
            for node in ast.walk(tree):
                bad = None
                if isinstance(node, ast.Import):
                    bad = next((a.name for a in node.names
                                if a.name.split(".")[0]
                                in _WALLCLOCK_MODULES), None)
                elif isinstance(node, ast.ImportFrom) and node.module:
                    if node.module.split(".")[0] in _WALLCLOCK_MODULES:
                        bad = node.module
                if bad is not None:
                    out.append(Finding(
                        "repo-tick-wallclock", rel, node.lineno,
                        f"engine tick path imports `{bad}` — serving "
                        f"decisions are indexed by the engine tick "
                        f"counter, never the wall clock; wall-clock "
                        f"supervision belongs in runtime/ (EngineWatchdog "
                        f"wraps the engine with an injected clock)"))
    return out


_ASYNC_MODULES = ("asyncio", "threading", "concurrent")
DEFAULT_ASYNC_SERVING_DIR = "src/repro/serving"
DEFAULT_ASYNC_EXEMPT = "src/repro/serving/frontdoor"


def check_async_boundary(
        root: pathlib.Path,
        serving_dir: str = DEFAULT_ASYNC_SERVING_DIR,
        exempt_dir: str = DEFAULT_ASYNC_EXEMPT) -> list[Finding]:
    """Only ``serving/frontdoor/`` may import asyncio (or thread pools).
    The engine tick loop is deterministic and synchronous; concurrency
    lives behind the door, where rids are pinned at arrival so event
    ordering can't change tokens."""
    d = root / serving_dir
    if not d.exists():
        return []
    exempt = root / exempt_dir
    out: list[Finding] = []
    for f in sorted(d.rglob("*.py")):
        if exempt in f.parents:
            continue
        tree = _parse(f)
        if tree is None:
            continue
        rel = str(f.relative_to(root))
        for node in ast.walk(tree):
            bad = None
            if isinstance(node, ast.Import):
                bad = next((a.name for a in node.names
                            if a.name.split(".")[0] in _ASYNC_MODULES),
                           None)
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module.split(".")[0] in _ASYNC_MODULES:
                    bad = node.module
            if bad is not None:
                out.append(Finding(
                    "repo-async-boundary", rel, node.lineno,
                    f"core serving imports `{bad}` — the engine is a "
                    f"deterministic synchronous tick loop; event-driven "
                    f"code (admission, streaming, shutdown) belongs in "
                    f"serving/frontdoor/, the one package exempt from "
                    f"this rule"))
    return out


def _stmt_has_mtime(stmt: ast.stmt) -> bool:
    for node in ast.walk(stmt):
        if isinstance(node, ast.Attribute) and node.attr in ("getmtime",
                                                             "st_mtime"):
            return True
    return False


def check_nondeterminism(files: list[pathlib.Path],
                         root: pathlib.Path) -> list[Finding]:
    out: list[Finding] = []
    for f in files:
        tree = _parse(f)
        if tree is None:
            continue
        rel = str(f.relative_to(root))
        # stdlib-`random` bindings in this file (np.random / jax.random
        # are seeded and deterministic — not this rule's business).
        random_aliases: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "random":
                        random_aliases.add(a.asname or "random")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    out.append(Finding(
                        "repo-nondeterminism", rel, node.lineno,
                        "imports from stdlib `random` — use a seeded "
                        "np.random.Generator or jax.random instead"))
        # Parent map so the mtime exemption can inspect the *smallest
        # enclosing statement* of each time.time() call — the whole
        # comparison expression, without double-visiting nested bodies.
        parent: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                parent[child] = node

        def enclosing_stmt(node: ast.AST) -> ast.stmt | None:
            while node is not None and not isinstance(node, ast.stmt):
                node = parent.get(node)
            return node

        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                fn = node.func
                if (isinstance(fn, ast.Attribute)
                        and isinstance(fn.value, ast.Name)
                        and fn.value.id == "time"
                        and fn.attr in ("time", "time_ns")):
                    stmt = enclosing_stmt(node)
                    if stmt is None or not _stmt_has_mtime(stmt):
                        out.append(Finding(
                            "repo-nondeterminism", rel, node.lineno,
                            "wall-clock `time.time` in src/ — use "
                            "time.monotonic for durations (mtime "
                            "comparisons are exempt)"))
            elif (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in random_aliases):
                out.append(Finding(
                    "repo-nondeterminism", rel, node.lineno,
                    f"stdlib random use `{node.value.id}.{node.attr}` "
                    f"in src/"))
    return out


def run_lint(root: pathlib.Path | str,
             src: str = "src",
             read_trees: tuple[str, ...] = ("src", "benchmarks", "examples"),
             config_specs: list[ConfigSpec] | None = None,
             allocator_paths: list[str] | None = None,
             tickpath_dirs: list[str] | None = None) -> list[Finding]:
    """Run every lint rule over ``root/src`` (reads for the unread-field
    rule are additionally counted in ``benchmarks/`` and ``examples/`` —
    a field only a benchmark reads is still live config)."""
    root = pathlib.Path(root)
    src_files = sorted((root / src).rglob("*.py"))
    read_files: list[pathlib.Path] = []
    for tree_dir in read_trees:
        d = root / tree_dir
        if d.exists():
            read_files.extend(sorted(d.rglob("*.py")))
    findings: list[Finding] = []
    findings += check_private_imports(src_files, root)
    findings += check_unread_config_fields(read_files, root, config_specs)
    findings += check_allocator_device_ops(root, allocator_paths)
    findings += check_nondeterminism(src_files, root)
    findings += check_tick_wallclock(root, tickpath_dirs)
    findings += check_async_boundary(root)
    # deterministic report order
    findings.sort(key=lambda f: (f.rule, f.file, f.line))
    return findings
