"""Shadow-ledger sanitizer + poison mode for :class:`KVBlockPool`.

Opt-in via ``REPRO_SANITIZE=1`` (any non-empty value other than ``0``):
:func:`make_kv_pool` — the engine's pool constructor — then returns a
:class:`SanitizedKVBlockPool`, which *independently replays* every pool
operation in a shadow ledger and cross-checks the real allocator's state
after each one.  The ledger never trusts the pool's own bookkeeping, so
a bug in either side trips a :class:`PoolInvariantError` at the exact
operation that diverged, with a trailing op log for diagnosis.

Invariants (rule ids as reported by the CLI meta-check and the negative
tests):

* ``pool-conservation``    — free + live + parked == capacity, and
  outstanding reservations never exceed reclaimable capacity.
* ``pool-refcount``        — refcounts are >= 1 for live blocks and the
  shadow's counts match the pool's exactly (a drift is a leak).
* ``pool-use-after-free``  — no incref/decref of a block that is not
  live (double-free, stale handle).
* ``pool-rollback-reservation`` — ``rollback(reserve=True)`` re-creates
  exactly ``len(bids)`` reservation units.
* ``pool-registered-protection`` — rollback/preempt never touch a
  registered prefix block or a shared (refcount > 1) block.
* ``pool-poisoned-read``   — poison mode (below) makes violations of the
  fill-level/stale-table masking invariant loud.
* ``pool-tier-conservation`` — the host tiers (:class:`SwapPool` swap /
  warm-prefix records) conserve bytes: per-record sizes sum exactly to
  ``bytes_used``, the budget is never exceeded, and the peak never trails
  the current level (:class:`SanitizedSwapPool`).

**Poison mode**: when the engine hands :func:`make_kv_pool` a
``poison_cb``, every block that returns to the free list (decref-to-free,
rollback, preempt, LRU eviction of a parked block at realloc) is reported
so the engine can overwrite the block's pool pages — K/V with
``POISON_KV``, positions with ``POISON_POS``, packed ``kq`` plane bytes
with ``POISON_BYTE``.  Any read that reaches a freed page through a stale
block table or a fill-level hole then produces wildly wrong, greppable
values instead of silently reusing stale KV.  The sentinels are finite
(not NaN) so correctly-masked dead lanes (``jnp.where`` selection, gated
``lax.cond`` branches) stay bit-identical: ``0 * POISON_KV == 0``.

This module is host-side allocator code: pure Python, **no jax imports**
(the ``repo-allocator-device-ops`` lint rule applies here too) — the
device-side poison writes live in the engine's callback.
"""

from __future__ import annotations

import collections
import os
from typing import Callable

from repro.serving.kv_pool import KVBlockPool, SwapPool

# Poison sentinels (engine-side callbacks use these; finite on purpose —
# masked-out lanes multiply by zero and must stay exactly zero).
POISON_KV = 1.0e4       # f32/bf16 K and V pool pages
POISON_POS = -7777      # position plane: passes causal/fill masks (unlike
                        # POS_SENTINEL) so the poisoned K/V gets *read*
POISON_BYTE = 0xAB      # packed kq bit-plane bytes


def sanitize_enabled() -> bool:
    v = os.environ.get("REPRO_SANITIZE", "")
    return v not in ("", "0")


class PoolInvariantError(AssertionError):
    """A pool operation violated a ledger invariant.  ``rule`` is the
    machine-readable class; the message carries the trailing op log."""

    def __init__(self, rule: str, message: str, oplog=()):
        self.rule = rule
        tail = "\n  ".join(str(op) for op in oplog)
        super().__init__(
            f"[{rule}] {message}" + (f"\nlast ops:\n  {tail}" if tail else ""))


class _Shadow:
    """Independent replay of KVBlockPool semantics (including LRU order
    of the parked cache — eviction order is observable)."""

    def __init__(self, pool_blocks: int, prefix_sharing: bool):
        self.capacity = pool_blocks - 1
        self.prefix_sharing = prefix_sharing
        self.free: collections.deque[int] = collections.deque(
            range(1, pool_blocks))
        self.live: dict[int, int] = {}
        self.cached: collections.OrderedDict[tuple, int] = \
            collections.OrderedDict()
        self.registry: dict[tuple, int] = {}
        self.key_of: dict[int, tuple] = {}
        self.reserved = 0


class SanitizedKVBlockPool(KVBlockPool):
    """Drop-in KVBlockPool that replays every op in a shadow ledger and
    audits pool-vs-ledger agreement after each one."""

    def __init__(self, pool_blocks: int, page_size: int,
                 prefix_sharing: bool = True,
                 poison_cb: Callable[[list[int]], None] | None = None,
                 oplog_len: int = 32, evict_cb=None):
        super().__init__(pool_blocks, page_size,
                         prefix_sharing=prefix_sharing, evict_cb=evict_cb)
        self._shadow = _Shadow(pool_blocks, prefix_sharing)
        self._poison_cb = poison_cb
        self._oplog: collections.deque = collections.deque(maxlen=oplog_len)

    # -- helpers -------------------------------------------------------

    def _fail(self, rule: str, msg: str):
        raise PoolInvariantError(rule, msg, self._oplog)

    def _poison(self, bids: list[int]) -> None:
        if self._poison_cb is not None and bids:
            for bid in bids:
                if bid == 0:
                    self._fail("pool-conservation",
                               "attempt to poison the null block")
            self._poison_cb(list(bids))

    def _audit(self) -> None:
        s = self._shadow
        # conservation — on the shadow AND on the real pool, separately,
        # then set-for-set agreement (order included for the LRU cache).
        for name, free, live, cached, reserved in (
                ("shadow", s.free, s.live, s.cached, s.reserved),
                ("pool", self._free, self._ref, self._cached,
                 self._reserved)):
            if len(free) + len(live) + len(cached) != s.capacity:
                self._fail(
                    "pool-conservation",
                    f"{name}: free({len(free)}) + live({len(live)}) + "
                    f"parked({len(cached)}) != capacity({s.capacity})")
            if reserved > len(free) + len(cached):
                self._fail(
                    "pool-conservation",
                    f"{name}: {reserved} reserved exceeds reclaimable "
                    f"{len(free) + len(cached)}")
        if set(self._free) != set(s.free):
            self._fail("pool-conservation",
                       f"free-list drift: pool {sorted(self._free)} vs "
                       f"ledger {sorted(s.free)}")
        if dict(self._ref) != s.live:
            self._fail("pool-refcount",
                       f"refcount drift: pool {dict(self._ref)} vs "
                       f"ledger {s.live}")
        for bid, n in s.live.items():
            if n < 1:
                self._fail("pool-refcount",
                           f"block {bid} live with refcount {n}")
        if list(self._cached.items()) != list(s.cached.items()):
            self._fail("pool-conservation",
                       "parked-LRU drift between pool and ledger")
        if dict(self._registry) != s.registry:
            self._fail("pool-conservation", "prefix-registry drift")
        if self._reserved != s.reserved:
            self._fail("pool-rollback-reservation",
                       f"reservation drift: pool {self._reserved} vs "
                       f"ledger {s.reserved}")

    # -- audited operations -------------------------------------------

    def reserve(self, n: int) -> None:
        self._oplog.append(("reserve", n))
        super().reserve(n)
        self._shadow.reserved += n
        self._audit()

    def cancel_reservation(self, n: int) -> None:
        self._oplog.append(("cancel_reservation", n))
        in_alloc = getattr(self, "_in_alloc", False)
        super().cancel_reservation(n)
        if not in_alloc:
            self._shadow.reserved -= n
            self._audit()

    def alloc(self, reserved: bool = False) -> int:
        self._oplog.append(("alloc", reserved))
        s = self._shadow
        # base-class alloc consumes a reservation via cancel_reservation;
        # flag so the nested call doesn't double-replay.
        self._in_alloc = True
        try:
            bid = super().alloc(reserved=reserved)
        finally:
            self._in_alloc = False
        evicted = False
        if bid in s.free:
            s.free.remove(bid)
        elif s.cached:
            lru_key = next(iter(s.cached))
            if s.cached[lru_key] != bid:
                self._fail("pool-conservation",
                           f"alloc evicted block {bid}, but ledger LRU "
                           f"head is {s.cached[lru_key]}")
            del s.cached[lru_key]
            del s.registry[lru_key]
            del s.key_of[bid]
            evicted = True
        else:
            self._fail("pool-use-after-free",
                       f"alloc returned block {bid} that the ledger "
                       f"holds as neither free nor parked")
        if reserved:
            s.reserved -= 1
        s.live[bid] = 1
        self._audit()
        if evicted:
            # the parked block's pages are dead the instant its registry
            # entry drops — poison before the new owner writes
            self._poison([bid])
        return bid

    def incref(self, bid: int) -> None:
        self._oplog.append(("incref", bid))
        if bid not in self._shadow.live:
            self._fail("pool-use-after-free",
                       f"incref of non-live block {bid}")
        super().incref(bid)
        self._shadow.live[bid] += 1
        self._audit()

    def decref(self, bid: int) -> None:
        self._oplog.append(("decref", bid))
        s = self._shadow
        if bid not in s.live:
            self._fail("pool-use-after-free",
                       f"decref of non-live block {bid} "
                       f"(double-free or stale handle)")
        super().decref(bid)
        if s.live[bid] > 1:
            s.live[bid] -= 1
            self._audit()
            return
        del s.live[bid]
        key = s.key_of.get(bid)
        freed = False
        if key is not None and s.prefix_sharing:
            s.cached[key] = bid
            s.cached.move_to_end(key)
        else:
            if key is not None:
                del s.registry[key]
                del s.key_of[bid]
            s.free.append(bid)
            freed = True
        self._audit()
        if freed:
            self._poison([bid])

    def _replay_free_exclusive(self, bids: list[int], verb: str) -> None:
        s = self._shadow
        for bid in bids:
            if bid not in s.live:
                self._fail("pool-use-after-free",
                           f"{verb} of non-live block {bid}")
            if s.live[bid] != 1:
                self._fail("pool-registered-protection",
                           f"{verb} of shared block {bid} "
                           f"(refcount {s.live[bid]})")
            if bid in s.key_of:
                self._fail("pool-registered-protection",
                           f"{verb} of registered prefix block {bid}")
        for bid in bids:
            del s.live[bid]
            s.free.append(bid)

    def rollback(self, bids: list[int], reserve: bool = True) -> None:
        self._oplog.append(("rollback", tuple(bids), reserve))
        self._replay_free_exclusive(bids, "rollback")
        reserved_before = self._reserved
        super().rollback(bids, reserve=reserve)
        if reserve:
            self._shadow.reserved += len(bids)
            if self._reserved != reserved_before + len(bids):
                self._fail(
                    "pool-rollback-reservation",
                    f"rollback of {len(bids)} block(s) moved the pool's "
                    f"reservation from {reserved_before} to "
                    f"{self._reserved}")
        self._audit()
        self._poison(list(bids))

    def preempt(self, bids: list[int]) -> None:
        self._oplog.append(("preempt", tuple(bids)))
        self._replay_free_exclusive(bids, "preempt")
        super().preempt(bids)
        self._audit()
        self._poison(list(bids))

    def register(self, key: tuple, bid: int) -> None:
        self._oplog.append(("register", key, bid))
        s = self._shadow
        if bid not in s.live:
            self._fail("pool-use-after-free",
                       f"register of non-live block {bid}")
        super().register(key, bid)
        if s.prefix_sharing and key not in s.registry:
            s.registry[key] = bid
            s.key_of[bid] = key
        self._audit()

    def lookup(self, key: tuple):
        self._oplog.append(("lookup", key))
        s = self._shadow
        # A live hit routes through self.incref — the audited override —
        # so that path is already replayed; only the parked-resurrect
        # path (which bypasses incref) needs a ledger update here.
        bid = super().lookup(key)
        if s.prefix_sharing and key in s.registry:
            sbid = s.registry[key]
            if bid != sbid:
                self._fail("pool-conservation",
                           f"lookup({key!r}) returned {bid}, ledger "
                           f"registry says {sbid}")
            if sbid not in s.live:
                del s.cached[key]
                s.live[sbid] = 1
        elif bid is not None:
            self._fail("pool-conservation",
                       f"lookup hit {bid} for a key the ledger never "
                       f"saw registered")
        self._audit()
        return bid


class SanitizedSwapPool(SwapPool):
    """Audited :class:`SwapPool`: replays the byte accounting of every
    put/get/take in a shadow ledger and cross-checks tier conservation
    after each op.  The host tiers hold KV the device pool dropped —
    losing track of a record silently re-prefills (a perf bug), while
    under-counting bytes busts the swap budget (a memory bug); both trip
    ``pool-tier-conservation`` at the exact op that diverged."""

    def __init__(self, budget_bytes: int = 0, evict_cb=None,
                 oplog_len: int = 32):
        super().__init__(budget_bytes, evict_cb=evict_cb)
        self._ledger: dict = {}          # key -> nbytes, replayed
        self._oplog: collections.deque = collections.deque(maxlen=oplog_len)

    def _fail(self, msg: str):
        raise PoolInvariantError("pool-tier-conservation", msg, self._oplog)

    def _audit(self) -> None:
        if set(self._ledger) != set(self._records):
            self._fail(f"record-set drift: tier holds "
                       f"{sorted(map(str, self._records))}, ledger "
                       f"{sorted(map(str, self._ledger))}")
        if self._ledger != self._nbytes:
            self._fail(f"per-record byte drift: tier {self._nbytes}, "
                       f"ledger {self._ledger}")
        total = sum(self._ledger.values())
        if total != self.bytes_used:
            self._fail(f"bytes_used({self.bytes_used}) != sum of records "
                       f"({total}) — tier accounting leaked")
        if self.bytes_used > self.budget_bytes:
            self._fail(f"bytes_used({self.bytes_used}) exceeds budget "
                       f"({self.budget_bytes})")
        if self.peak_bytes < self.bytes_used:
            self._fail(f"peak_bytes({self.peak_bytes}) trails "
                       f"bytes_used({self.bytes_used})")

    def put(self, key, record, nbytes: int) -> bool:
        self._oplog.append(("put", key, int(nbytes)))
        before = dict(self._ledger)
        ok = super().put(key, record, nbytes)
        # replay: the base op may have evicted LRU records to make room
        # (their keys vanished from _records) and/or replaced `key`.
        self._ledger = {k: n for k, n in before.items()
                        if k in self._records and k != key}
        if ok:
            self._ledger[key] = int(nbytes)
        self._audit()
        return ok

    def take(self, key):
        self._oplog.append(("take", key))
        had = key in self._ledger
        rec = super().take(key)
        if (rec is not None) != had:
            self._fail(f"take({key!r}) {'hit' if rec is not None else 'missed'} "
                       f"but the ledger says {'present' if had else 'absent'}")
        self._ledger.pop(key, None)
        self._audit()
        return rec

    def get(self, key):
        self._oplog.append(("get", key))
        rec = super().get(key)
        if (rec is not None) != (key in self._ledger):
            self._fail(f"get({key!r}) disagrees with ledger membership")
        self._audit()
        return rec


POOL_RULES = [
    "pool-conservation",
    "pool-refcount",
    "pool-use-after-free",
    "pool-rollback-reservation",
    "pool-registered-protection",
    "pool-poisoned-read",
    "pool-tier-conservation",
]

_SELF = "src/repro/analysis/pool_sanitizer.py"


def run_pool_selfcheck():
    """Prove the sanitizer itself works: a canned legal op sequence must
    pass silently, and one seeded corruption per rule class must trip a
    :class:`PoolInvariantError` carrying exactly that rule.  A detector
    that has gone blind is worse than none — CI would keep trusting it.

    Returns ``(findings, meta)`` in the same shape as the other checkers;
    findings are emitted only when detection is broken.
    """
    from repro.analysis.report import Finding

    findings: list[Finding] = []

    # -- legal sequence must NOT raise --------------------------------
    poisoned: list[int] = []
    try:
        p = SanitizedKVBlockPool(8, 16, prefix_sharing=True,
                                 poison_cb=poisoned.extend)
        p.reserve(2)
        a = p.alloc(reserved=True)
        b = p.alloc(reserved=True)
        p.incref(a)
        p.decref(a)
        p.register(("k", 1), a)
        got = p.lookup(("k", 1))          # live hit: routes via incref
        assert got == a
        p.decref(a)
        p.decref(a)                       # parks (registered prefix)
        got = p.lookup(("k", 1))          # parked hit: resurrect path
        assert got == a
        p.decref(a)                       # parks again
        p.rollback([b], reserve=True)
        p.cancel_reservation(1)
        c = p.alloc()                     # from free list
        p.decref(c)                       # unregistered -> truly freed
    except Exception as e:                # noqa: BLE001 — any raise is a bug
        findings.append(Finding(
            "pool-conservation", _SELF, 0,
            f"sanitizer rejected a legal op sequence: {e}"))
    else:
        if b not in poisoned or c not in poisoned:
            findings.append(Finding(
                "pool-poisoned-read", _SELF, 0,
                f"poison callback missed freed blocks (reported "
                f"{sorted(set(poisoned))}, expected to include {b} "
                f"and {c}) — stale-read poisoning is dark"))

    # -- each seeded corruption must trip its rule --------------------
    def expect(rule, scenario):
        try:
            scenario()
        except PoolInvariantError as e:
            if e.rule != rule:
                findings.append(Finding(
                    rule, _SELF, 0,
                    f"seeded {rule} violation detected but "
                    f"misclassified as {e.rule}"))
        else:
            findings.append(Finding(
                rule, _SELF, 0,
                f"seeded {rule} violation went undetected — the "
                f"sanitizer has gone blind to this class"))

    def leak_block():
        p = SanitizedKVBlockPool(8, 16)
        p._free.pop()                     # a block vanishes
        p.reserve(0)                      # any audited op re-audits

    def refcount_drift():
        p = SanitizedKVBlockPool(8, 16)
        bid = p.alloc()
        p._ref[bid] += 1                  # pool leaks a reference
        p.reserve(0)

    def double_free():
        p = SanitizedKVBlockPool(8, 16, prefix_sharing=False)
        bid = p.alloc()
        p.decref(bid)
        p.decref(bid)

    def reservation_drift():
        p = SanitizedKVBlockPool(8, 16)
        p._reserved += 1                  # phantom reservation
        p.reserve(0)

    def rollback_registered():
        p = SanitizedKVBlockPool(8, 16)
        bid = p.alloc()
        p.register(("prefix",), bid)
        p.rollback([bid])

    expect("pool-conservation", leak_block)
    expect("pool-refcount", refcount_drift)
    expect("pool-use-after-free", double_free)
    expect("pool-rollback-reservation", reservation_drift)
    expect("pool-registered-protection", rollback_registered)

    # -- host tiers: legal sequence + seeded byte-ledger corruption ----
    spilled: list = []
    try:
        t = SanitizedSwapPool(100, evict_cb=lambda k, r, n:
                              spilled.append((k, n)))
        assert t.put("a", "rec-a", 40)
        assert t.put("b", "rec-b", 40)
        assert t.get("a") == "rec-a"      # LRU touch: b is now oldest
        assert t.put("c", "rec-c", 40)    # evicts b down a tier
        assert spilled == [("b", 40)]
        assert t.take("a") == "rec-a" and t.take("a") is None
        assert not t.put("huge", "x", 101)  # over budget outright
        refusing = SanitizedSwapPool(50)    # no evict_cb: refuse, don't evict
        assert refusing.put("a", "rec", 30)
        assert not refusing.put("b", "rec", 30)
        assert "a" in refusing              # refused put evicted nothing
    except Exception as e:                # noqa: BLE001 — any raise is a bug
        findings.append(Finding(
            "pool-tier-conservation", _SELF, 0,
            f"swap-tier sanitizer rejected a legal op sequence: {e}"))

    def tier_byte_leak():
        t = SanitizedSwapPool(100)
        t.put("a", "rec", 10)
        t.bytes_used -= 5                 # tier loses track of bytes
        t.get("a")                        # any audited op re-audits

    expect("pool-tier-conservation", tier_byte_leak)

    meta = {"scenarios": 8}
    return findings, meta


def make_kv_pool(pool_blocks: int, page_size: int,
                 prefix_sharing: bool = True,
                 poison_cb: Callable[[list[int]], None] | None = None,
                 evict_cb=None) -> KVBlockPool:
    """The engine's pool constructor: a plain :class:`KVBlockPool` unless
    ``REPRO_SANITIZE`` opts in to the audited + poisoning wrapper."""
    if sanitize_enabled():
        return SanitizedKVBlockPool(pool_blocks, page_size,
                                    prefix_sharing=prefix_sharing,
                                    poison_cb=poison_cb, evict_cb=evict_cb)
    return KVBlockPool(pool_blocks, page_size,
                       prefix_sharing=prefix_sharing, evict_cb=evict_cb)


def make_swap_pool(budget_bytes: int, evict_cb=None) -> SwapPool:
    """The engine's host-tier constructor (swap records and the warm
    prefix tier): audited under ``REPRO_SANITIZE``, plain otherwise."""
    if sanitize_enabled():
        return SanitizedSwapPool(budget_bytes, evict_cb=evict_cb)
    return SwapPool(budget_bytes, evict_cb=evict_cb)
