"""Static-analysis / sanitizer subsystem.

Three parts, one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.kernel_contracts` — statically verifies every
  Pallas entry point's BlockSpecs, index maps, scalar-prefetch operands,
  scratch shapes and ``interpret`` routing against a shape-sweep registry.
* :mod:`repro.analysis.pool_sanitizer` — opt-in (``REPRO_SANITIZE=1``)
  shadow ledger + poison mode wrapping :class:`repro.serving.kv_pool.
  KVBlockPool`.
* :mod:`repro.analysis.lint` — repo-rule AST lint (private cross-module
  imports, unread config fields, device ops in the host allocator,
  nondeterminism).

See ``docs/analysis.md`` for what each checker proves and how to extend
the registries.
"""

from repro.analysis.report import Finding, summarize  # noqa: F401
