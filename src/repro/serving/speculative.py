"""Speculative-decoding drafters: who proposes the draft tokens.

The verify half lives in :class:`repro.serving.engine.PagedEngine` (one
Sq=k+1 BitStopper verify forward per scheduler tick, longest-matching-
prefix acceptance, paged block-table rollback of the rejected tail) and is
**lossless**: served traces are bit-identical to non-speculative serving
under the same seed no matter which drafter runs or how bad its guesses
are.  Drafters therefore only trade proposal *quality* (acceptance rate)
against proposal *cost*:

* :class:`NGramDrafter` — prompt-lookup / self-speculation: continue the
  longest recent n-gram match found earlier in the request's own context
  (prompt + generated so far).  Needs no extra weights and costs a host-
  side scan; it shines on repetitive text (code, templated prose, long
  copies) where acceptance approaches 100%.
* :class:`DraftModelDrafter` — a small draft transformer sharing the
  target's tokenizer/vocab greedily proposes k tokens.  This repro keeps
  it a semantic model: cache-free bucket-padded forwards per draft token
  (no draft KV cache), so it is the *acceptance-rate* reference, not a
  latency win on its own.  Passing the target model itself ("self-draft")
  gives acceptance 1.0 under greedy sampling — the degenerate case the
  verify-loop tests pin down.

A drafter is anything with ``propose(context, k) -> list[int]`` returning
at most k token ids; returning fewer (or none) is always safe — the engine
pads the draft block and, with zero drafts across the batch, falls back to
a plain decode tick.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class Drafter(Protocol):
    def propose(self, context: np.ndarray, k: int) -> list[int]:
        """Given the request's full context (prompt + generated, the last
        entry being the token about to be fed to the target), return up to
        ``k`` proposed continuation tokens."""
        ...


class NGramDrafter:
    """Prompt-lookup self-drafter (no weights).

    Finds the longest suffix n-gram of the context (n from ``max_n`` down
    to ``min_n``) that occurred earlier in the context, and proposes the
    tokens that followed its most recent earlier occurrence.
    """

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, got "
                             f"({min_n}, {max_n})")
        self.max_n = max_n
        self.min_n = min_n

    def propose(self, context: np.ndarray, k: int) -> list[int]:
        ctx = np.asarray(context)
        L = len(ctx)
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            pat = ctx[L - n:]
            # Most recent earlier occurrence with at least one follower
            # (the suffix itself, ending at L, is excluded by the range).
            for s in range(L - n - 1, -1, -1):
                if np.array_equal(ctx[s:s + n], pat):
                    return [int(t) for t in ctx[s + n:s + n + k]]
        return []


class DraftModelDrafter:
    """Greedy draft-transformer proposals (vocab shared with the target).

    Runs the draft model cache-free over the (bucket-padded) context once
    per proposed token — a deliberate semantic model that keeps the
    drafter stateless across the engine's admission/eviction/rollback
    machinery.  ``max_context`` truncates very long contexts so proposal
    cost stays bounded; bucketing keeps the jit cache small.
    """

    def __init__(self, cfg, params, max_context: int = 256,
                 bucket: int = 32):
        from repro.models import transformer as T
        self.cfg = cfg
        self.params = params
        self.max_context = max_context
        self.bucket = bucket

        def fwd(params, tokens, last_idx):
            logits, _, _ = T.forward(params, tokens, cfg)
            last = jax.lax.dynamic_slice_in_dim(logits, last_idx, 1, axis=1)
            return jnp.argmax(last[0, 0], axis=-1)

        self._fwd = jax.jit(fwd)

    def propose(self, context: np.ndarray, k: int) -> list[int]:
        toks = [int(t) for t in np.asarray(context)[-self.max_context:]]
        out: list[int] = []
        for _ in range(k):
            L = len(toks)
            Sp = -(-L // self.bucket) * self.bucket
            padded = np.zeros((1, Sp), np.int32)
            padded[0, :L] = toks
            # Trailing zero-pad is causally invisible to position L-1.
            t = int(self._fwd(self.params, jnp.asarray(padded),
                              jnp.asarray(L - 1, jnp.int32)))
            out.append(t)
            toks.append(t)
            if len(toks) > self.max_context:
                toks = toks[-self.max_context:]
        return out


def make_drafter(kind: str, cfg, params, draft_cfg=None, draft_params=None):
    """Resolve ``ServeConfig.speculative`` to a drafter instance.

    ``"ngram"`` needs no weights.  ``"draft"`` uses the provided draft
    model, falling back to self-drafting with the target model (always
    available, acceptance 1.0 under greedy — the plumbing-proof default).
    """
    if kind == "ngram":
        return NGramDrafter()
    if kind == "draft":
        if (draft_cfg is None) != (draft_params is None):
            raise ValueError("draft_cfg and draft_params come together")
        if draft_cfg is None:
            draft_cfg, draft_params = cfg, params
        if draft_cfg.vocab != cfg.vocab:
            raise ValueError(
                f"draft model must share the target vocab "
                f"({draft_cfg.vocab} != {cfg.vocab})")
        return DraftModelDrafter(draft_cfg, draft_params)
    raise ValueError(f"unknown drafter kind {kind!r} (ngram|draft)")
