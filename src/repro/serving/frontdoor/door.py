"""The async front door: asyncio admission + per-request token streaming
over the JetStream-style engine API surface.

``AsyncFrontDoor`` owns the boundary between asynchronous clients and
the strictly deterministic, single-threaded engine tick loop:

* **Arrival-time identity.**  ``submit()`` assigns the request's rid the
  moment it arrives.  Sampling keys are ``fold_in(fold_in(seed, rid),
  n)`` — a pure function of (seed, rid, token index) — so the fairness
  scheduler below can reorder *admission* freely without changing a
  single served token.  This is what makes async streams bit-identical
  to the synchronous trace.
* **Fairness-aware admission.**  Pending requests queue per SLO class;
  before each tick the door drains them into the engine in a strict
  round-robin over ``strict -> standard -> besteffort`` (one from each
  non-empty class per cycle), so a burst of best-effort work can't
  starve strict arrivals of admission.  The order actually handed to
  the engine is recorded in ``admission_log`` (a deterministic field the
  bench gates on).
* **Streaming.**  ``stream(rid)`` is an async iterator fed by diffing
  the request registry after every tick: tokens the engine committed are
  published to a per-request queue, terminal states (finish, deadline
  truncation, shed) close it.  A stream attached after a restart first
  replays everything already generated — lossless resume.
* **Wall-clock SLAs.**  A ``deadline_s`` on submit is mapped to engine
  ticks by the :class:`~repro.serving.frontdoor.sla.SlaMapper`, fed by
  tick durations measured with the *injected* clock (serving/ itself is
  wall-clock-free by lint).
* **Graceful shutdown.**  ``shutdown("drain")`` stops new admissions and
  serves everything already accepted to completion.
  ``shutdown("snapshot")`` hands still-pending submissions to the
  engine, stops the loop, and persists ``PagedEngine.snapshot()``
  through the checkpoint store; ``start()`` on a fresh door reclaims
  orphaned staging (``gc_staging``), restores the newest snapshot, and
  the interrupted streams replay losslessly.

The backend is anything with the engine protocol — a ``PagedEngine``
(colocated) or a ``DisaggController`` (prefill/decode disaggregation,
``serving/frontdoor/disagg.py``).
"""

from __future__ import annotations

import asyncio
import collections

import numpy as np

from repro.checkpoint.store import (gc_staging, latest_step, load_snapshot,
                                    save_snapshot)
from repro.serving.engine import Request
from repro.serving.frontdoor.sla import SlaMapper

_DONE = object()          # stream sentinel: request reached a terminal state
_INTERRUPTED = object()   # stream sentinel: door stopped for a snapshot

_SLO_ORDER = ("strict", "standard", "besteffort")


class AsyncFrontDoor:
    """Asyncio serving front door over a deterministic engine backend."""

    def __init__(self, backend, *, clock=None, sla: SlaMapper | None = None,
                 snapshot_dir: str | None = None, seed: int = 0):
        if snapshot_dir is not None and not hasattr(backend, "snapshot"):
            raise ValueError(
                "snapshot_dir needs a snapshot-capable backend "
                "(PagedEngine); the disaggregated controller drains "
                "instead")
        self.backend = backend
        self.clock = clock
        self.sla = sla if sla is not None else SlaMapper()
        self.snapshot_dir = snapshot_dir
        self.seed = seed
        self._pending = {cls: collections.deque() for cls in _SLO_ORDER}
        self._queues: dict[int, asyncio.Queue] = {}
        self._published: dict[int, int] = {}
        self._done: set[int] = set()
        self.interrupted: set[int] = set()
        self._next_rid = max(backend.requests, default=-1) + 1
        self._wake = asyncio.Event()
        self._stop = False
        self._drain = False
        self._running = False
        self.restored = False
        self.ticks_run = 0                     # engine ticks this door drove
        self.admission_log: list[int] = []     # rids in engine-submit order
        self.first_token_tick: dict[int, int] = {}
        self.finish_tick: dict[int, int] = {}

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def start(self) -> bool:
        """Prepare the backend: reclaim snapshot staging orphans, restore
        the newest snapshot if one exists (returns True — in-flight
        streams will replay and continue), else begin fresh under
        ``seed``."""
        if self.snapshot_dir is not None:
            gc_staging(self.snapshot_dir)
            if latest_step(self.snapshot_dir) is not None:
                state, _ = load_snapshot(self.snapshot_dir)
                self.backend.restore(state)
                self._next_rid = max(self.backend.requests, default=-1) + 1
                self.restored = True
                return True
        self.backend.begin(self.seed)
        return False

    def submit(self, prompt, max_new_tokens: int = 32,
               slo: str = "standard", deadline_s: float | None = None,
               deadline_ticks: int | None = None) -> int:
        """Accept a request; returns its rid (the stream handle).  The
        rid is fixed NOW, in arrival order — admission may reorder later
        without changing tokens (see module docstring)."""
        if self._stop or self._drain:
            raise RuntimeError("front door is shutting down")
        if slo not in _SLO_ORDER:
            raise ValueError(
                f"slo must be strict|standard|besteffort, got {slo!r}")
        if deadline_s is not None:
            if deadline_ticks is not None:
                raise ValueError(
                    "give deadline_s or deadline_ticks, not both")
            deadline_ticks = self.sla.ticks_for(deadline_s)
        req = Request(prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, slo=slo,
                      deadline_ticks=deadline_ticks, rid=self._next_rid)
        self._next_rid += 1
        self._pending[slo].append(req)
        self._queues[req.rid] = asyncio.Queue()
        self._published[req.rid] = 0
        self._wake.set()
        return req.rid

    async def run(self) -> None:
        """The engine loop: admit pending work fairly, tick the backend,
        publish committed tokens to streams.  Exits on drain completion
        or a stop; persists a snapshot on the way out when stopping with
        a ``snapshot_dir``."""
        if self._running:
            raise RuntimeError("run() is already active")
        self._running = True
        try:
            while not self._stop:
                self._admit_pending()
                self._publish()
                if self.backend.pending():
                    if self.clock is not None:
                        t0 = self.clock()
                        self.backend.step()
                        self.sla.observe_tick(self.clock() - t0)
                    else:
                        self.backend.step()
                    self.ticks_run += 1
                    self._publish()
                    await asyncio.sleep(0)
                elif self._drain:
                    break
                else:
                    self._wake.clear()
                    await self._wake.wait()
        finally:
            self._running = False
            if self._stop and self.snapshot_dir is not None:
                self._snapshot()
            self._finalize_streams()

    def shutdown(self, mode: str = "drain") -> None:
        """Begin a graceful shutdown.  ``"drain"``: refuse new
        submissions, serve everything already accepted to completion.
        ``"snapshot"``: hand pending submissions to the engine so the
        snapshot owns them, stop the loop now, persist engine state;
        open streams end marked interrupted and a restarted door resumes
        them losslessly.  The caller awaits its ``run()`` task for
        completion."""
        if mode not in ("drain", "snapshot"):
            raise ValueError(f"mode must be drain|snapshot, got {mode!r}")
        if mode == "drain":
            self._drain = True
        else:
            self._admit_pending()
            self._stop = True
        self._wake.set()

    # ------------------------------------------------------------------
    # streams
    # ------------------------------------------------------------------

    async def stream(self, rid: int):
        """Async-iterate the request's tokens as the engine commits them.
        Tokens generated before attachment (or before a restart) replay
        first, so a reconnecting client always sees the full stream."""
        q = self._queues.get(rid)
        if q is None:
            if rid not in self.backend.requests:
                raise KeyError(f"unknown rid {rid}")
            q = self._queues[rid] = asyncio.Queue()
            self._published[rid] = 0
            self._wake.set()
        while True:
            tok = await q.get()
            if tok is _DONE:
                return
            if tok is _INTERRUPTED:
                return
            yield tok

    def result(self, rid: int) -> Request:
        """The request object (tokens + terminal status) for a rid."""
        for cls in _SLO_ORDER:
            for req in self._pending[cls]:
                if req.rid == rid:
                    return req
        return self.backend.requests[rid]

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _admit_pending(self) -> None:
        """Drain door-pending requests into the engine: round-robin one
        per non-empty SLO class per cycle, strict first."""
        while any(self._pending.values()):
            for cls in _SLO_ORDER:
                if self._pending[cls]:
                    req = self._pending[cls].popleft()
                    self.backend.submit(req)
                    self.admission_log.append(req.rid)

    def _publish(self) -> None:
        """Diff the request registry against what each stream has seen
        and push the difference.  Terminal states close the stream."""
        for rid, req in self.backend.requests.items():
            if rid in self._done:
                continue
            q = self._queues.get(rid)
            if q is None:
                q = self._queues[rid] = asyncio.Queue()
                self._published[rid] = 0
            n0 = self._published[rid]
            new = req.generated[n0:]
            if new and rid not in self.first_token_tick:
                self.first_token_tick[rid] = self.ticks_run
            for tok in new:
                q.put_nowait(int(tok))
            self._published[rid] = len(req.generated)
            if req.finished_step >= 0 or req.shed_reason is not None:
                self.finish_tick.setdefault(rid, self.ticks_run)
                q.put_nowait(_DONE)
                self._done.add(rid)

    def _snapshot(self) -> None:
        state = self.backend.snapshot()
        save_snapshot(state, self.snapshot_dir, int(self.backend.ticks))

    def _finalize_streams(self) -> None:
        for rid, q in self._queues.items():
            if rid not in self._done:
                self.interrupted.add(rid)
                q.put_nowait(_INTERRUPTED)
