"""Wall-clock → tick SLA mapping for the async front door.

The engine's QoS machinery (deadlines, shedding, SLO-aware victim
selection — docs/robustness.md) is **tick-indexed**: serving/ is
wall-clock-free by lint rule, so a client's "answer within 300 ms" has
to be translated at the boundary.  :class:`SlaMapper` does it with two
ingredients, both injected:

* a clock (``repro.runtime.clock``) whose ``granularity`` quantizes
  client deadlines UP to resolvable multiples — a deadline never rounds
  below what the client asked for;
* a tick-duration estimate: an EMA over observed engine ticks (the same
  ``StragglerPolicy`` EMA the watchdog uses), seeded by
  ``default_tick_s`` until observations arrive.  With a ``ManualClock``
  that never advances, the estimate stays at ``default_tick_s`` and the
  mapping is a pure function — how the deterministic CI gates use it.
"""

from __future__ import annotations

import math

from repro.runtime.fault_tolerance import StragglerPolicy


class SlaMapper:
    """Maps wall-clock deadlines onto engine-tick deadlines.

    ``ticks_for(deadline_s)`` = ``deadline_s``, quantized up to a clock
    granularity multiple, divided by the estimated tick duration,
    floored at one tick.  The division rounds DOWN (a partial tick past
    the deadline is already late), except that the granularity
    quantization happens first — so a sub-granularity deadline still
    buys the client one full granule of service."""

    def __init__(self, granularity: float = 1e-3,
                 default_tick_s: float = 1e-2,
                 ema_alpha: float = 0.1):
        if granularity <= 0.0:
            raise ValueError(f"granularity must be > 0, got {granularity}")
        if default_tick_s <= 0.0:
            raise ValueError(
                f"default_tick_s must be > 0, got {default_tick_s}")
        self.granularity = granularity
        self.default_tick_s = default_tick_s
        self._policy = StragglerPolicy(ema_alpha=ema_alpha)
        self.observed_ticks = 0

    @property
    def tick_estimate(self) -> float:
        """Current tick-duration estimate: the EMA once ticks have been
        observed, else the configured default."""
        ema = self._policy.ema
        return ema if ema is not None else self.default_tick_s

    def observe_tick(self, dt: float) -> None:
        """Feed one measured engine-tick duration (from the injected
        clock).  Zero/negative durations are dropped — a ManualClock that
        never advances keeps the mapper on ``default_tick_s``."""
        if dt <= 0.0:
            return
        self._policy.observe(dt)
        self.observed_ticks += 1

    def quantize(self, deadline_s: float) -> float:
        """Round a wall-clock deadline UP to a granularity multiple."""
        g = self.granularity
        return math.ceil(deadline_s / g - 1e-12) * g

    def ticks_for(self, deadline_s: float) -> int:
        """Tick budget a wall-clock deadline buys at the current tick
        estimate.  Always >= 1: the engine requires a positive deadline,
        and admission itself costs a tick."""
        if deadline_s <= 0.0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        q = self.quantize(deadline_s)
        return max(1, int(q / self.tick_estimate + 1e-9))
