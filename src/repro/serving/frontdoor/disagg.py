"""Prefill/decode disaggregation: two engine instances, one token stream.

``DisaggController`` runs a *prefill engine* and a *decode engine* as
separate ``PagedEngine`` instances — separate pools, separate block
numbering, separate jitted closures — and moves work between them as
:class:`~repro.serving.engine.Prefix` handles through an in-process
:class:`TransferQueue`:

```
submit ─► controller queue ─► prefill_engine.prefill() ─► extract()
                                    (chunked prefill,         │
                                     prefix-registry CoW)     ▼
                                                        TransferQueue
                                                              │ (detached:
                                                              │  K/V/pos rows
                                                              ▼  + amax)
             decode stream ◄─ decode_engine.step() ◄─ decode_engine.insert()
```

The handoff serializes block contents *through the pool* (``extract``),
so the decode instance's pool layout is fully independent; ``insert``
CoW-matches the chain against the decode pool's own registry first and
only scatters blocks it has never seen.  The controller exposes the same
protocol the async door drives (``submit/begin/step/pending/requests``),
so colocated and disaggregated serving are interchangeable behind
``AsyncFrontDoor`` — and bit-identical to the synchronous trace, because
rids are fixed at submission and sampling keys are (seed, rid, n).

Decode-side oversubscription, speculation and preemption work unchanged:
an inserted slot is indistinguishable from a post-preemption resume.
Deadlines re-anchor at insert (the two engines' tick clocks are
unrelated), so a ``deadline_ticks`` bounds *decode* service in this
mode.
"""

from __future__ import annotations

import collections

from repro.serving.engine import InsufficientBlocks, PagedEngine, Prefix, \
    Request


class TransferQueue:
    """FIFO of detached prefixes in flight from prefill to decode, with
    transfer accounting (the bench's disaggregation traffic fields)."""

    def __init__(self):
        self._q: collections.deque[Prefix] = collections.deque()
        self.counters = {"prefixes_transferred": 0,
                         "blocks_transferred": 0,
                         "payload_bytes": 0}

    def put(self, prefix: Prefix, blocks: int) -> None:
        if prefix.payload is None:
            raise ValueError("transfer queue carries DETACHED prefixes "
                             "only — extract() before put()")
        self.counters["prefixes_transferred"] += 1
        self.counters["blocks_transferred"] += blocks
        self.counters["payload_bytes"] += sum(
            a.nbytes for layer in prefix.payload["layers"]
            for a in layer.values())
        self._q.append(prefix)

    def peek(self) -> Prefix:
        return self._q[0]

    def get(self) -> Prefix:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)


class DisaggController:
    """Two-instance prefill/decode serving behind the door's backend
    protocol.  One ``step()`` = land ready prefixes into free decode
    slots, prefill (at most) one waiting request to completion, then one
    decode tick."""

    def __init__(self, prefill_engine: PagedEngine,
                 decode_engine: PagedEngine, xfer: TransferQueue = None):
        if prefill_engine is decode_engine \
                or prefill_engine.pool is decode_engine.pool:
            raise ValueError(
                "disaggregation needs two distinct engine instances")
        if prefill_engine.scfg.page_size != decode_engine.scfg.page_size:
            raise ValueError(
                "prefill and decode engines must agree on page_size "
                f"({prefill_engine.scfg.page_size} vs "
                f"{decode_engine.scfg.page_size})")
        # The FIRST token of every request is sampled by the prefill
        # engine (from the final prefill logits) — the instances must
        # agree on everything sampling-visible or the handoff would
        # change tokens.
        for field in ("temperature", "eos_id"):
            a = getattr(prefill_engine.scfg, field)
            b = getattr(decode_engine.scfg, field)
            if a != b:
                raise ValueError(
                    f"prefill and decode engines must agree on {field} "
                    f"({a!r} vs {b!r}): the first token samples on the "
                    f"prefill side")
        self.prefill_engine = prefill_engine
        self.decode_engine = decode_engine
        self.xfer = xfer if xfer is not None else TransferQueue()
        self.queue: collections.deque[Request] = collections.deque()
        self.requests: dict[int, Request] = {}
        self._next_rid = 0
        self.ticks = 0

    def begin(self, seed: int = 0) -> None:
        # Both instances derive the same base key: a token sampled on the
        # decode engine lands under the same (seed, rid, n) key the
        # colocated engine would use.
        self.prefill_engine.begin(seed)
        self.decode_engine.begin(seed)

    def submit(self, req: Request) -> Request:
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if req.slo not in ("besteffort", "standard", "strict"):
            raise ValueError(
                f"slo must be besteffort|standard|strict, got {req.slo!r}")
        if req.rid < 0:
            req.rid = self._next_rid
        elif (req.rid in self.requests
              and self.requests[req.rid] is not req):
            raise ValueError(
                f"rid {req.rid} already belongs to another request")
        self._next_rid = max(self._next_rid, req.rid + 1)
        self.requests[req.rid] = req
        req.submitted_tick = self.ticks
        self.queue.append(req)
        return req

    def pending(self) -> bool:
        return bool(self.queue or len(self.xfer)
                    or self.decode_engine.pending())

    def step(self) -> bool:
        self.ticks += 1
        # 1) Land ready prefixes into free decode slots.  A decode pool
        # too tight for the head prefix right now retries next tick —
        # evictions return capacity.
        while len(self.xfer):
            free = self.decode_engine.free_slots()
            if not free:
                break
            try:
                self.decode_engine.insert(self.xfer.peek(), free[0])
            except InsufficientBlocks:
                break
            self.xfer.get()
        # 2) Prefill at most one waiting request to completion and ship
        # its detached prefix.  A prefill pool too tight right now also
        # retries (extract() frees the previous prefix's refs, so
        # pressure here is transient).
        if self.queue and self.prefill_engine.free_slots():
            req = self.queue[0]
            try:
                prefix = self.prefill_engine.prefill(req)
            except InsufficientBlocks:
                pass
            else:
                self.queue.popleft()
                if not prefix.finished:
                    page = self.prefill_engine.scfg.page_size
                    n_ctx = -(-prefix.length // page)
                    self.xfer.put(self.prefill_engine.extract(prefix),
                                  blocks=n_ctx)
        # 3) One decode tick.
        if self.decode_engine.pending():
            self.decode_engine.step()
        return self.pending()

    def run(self, seed: int = 0) -> None:
        self.begin(seed)
        while self.pending():
            self.step()

    def generate(self, requests: list[Request], seed: int = 0):
        for r in requests:
            self.submit(r)
        self.run(seed)
        return requests

    @property
    def counters(self) -> dict:
        """Decode-engine counters (the serving-side truth), with the
        prefill engine's rolled in under a ``prefill_engine_`` prefix and
        the transfer queue's verbatim."""
        out = dict(self.decode_engine.counters)
        out.update(self.xfer.counters)
        for k, v in self.prefill_engine.counters.items():
            out[f"prefill_engine_{k}"] = v
        return out
