"""Async serving front door (docs/serving.md, "Async front door").

The only corner of ``serving/`` allowed to touch asyncio (lint rule
``repo-async-boundary``): the engine itself stays a deterministic,
synchronous tick loop, and everything event-driven lives behind this
package's door.
"""

from repro.serving.frontdoor.disagg import (  # noqa: F401
    DisaggController, TransferQueue,
)
from repro.serving.frontdoor.door import AsyncFrontDoor  # noqa: F401
from repro.serving.frontdoor.sla import SlaMapper  # noqa: F401
