"""Batched prefill/decode serving engine.

BitStopper is an *inference* accelerator: this engine is where the paper's
technique is deployed.  Requests are batched by length bucket (uniform
cache length per batch — the block-granular kernel's masks are shared
across the batch), prefilled once, then decoded step-by-step with the
sparse score path (``attn_impl="bitstopper_xla"`` on CPU, the Pallas kernel
on a real TPU).

The engine also exposes ``sparsity_report()`` — measured plane-fetch /
survivor statistics from the semantic model, feeding the Fig. 11/12
benchmarks with *served-traffic* numbers rather than synthetic ones.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0          # 0 = greedy
    cache_dtype: str = "float32"


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                # [S] int32
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg

        def prefill_fn(params, tokens, caches):
            S = tokens.shape[1]
            logits, caches, _ = T.forward(params, tokens, cfg, caches=caches,
                                          positions=jnp.arange(S))
            return logits[:, -1], caches

        def decode_fn(params, token, caches, pos):
            logits, caches, _ = T.forward(
                params, token, cfg, caches=caches,
                positions=pos[None])
            return logits[:, -1], caches

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

    def init_caches(self, batch: int):
        dt = jnp.bfloat16 if self.scfg.cache_dtype == "bfloat16" else jnp.float32
        return T.init_caches(self.cfg, batch, self.scfg.max_len, dt)

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.scfg.temperature)

    def generate(self, requests: list[Request], seed: int = 0):
        """Serve one same-length batch of requests to completion."""
        assert len({len(r.prompt) for r in requests}) == 1, \
            "batch requests by prompt length (length bucketing)"
        prompts = jnp.asarray(np.stack([r.prompt for r in requests]))
        B, S = prompts.shape
        caches = self.init_caches(B)
        logits, caches = self._prefill(self.params, prompts, caches)
        key = jax.random.PRNGKey(seed)
        max_new = max(r.max_new_tokens for r in requests)
        token = self._sample(logits, key)
        for r, t in zip(requests, np.asarray(token)):
            r.generated.append(int(t))
        for i in range(1, max_new):
            key, sub = jax.random.split(key)
            logits, caches = self._decode(
                self.params, token[:, None], caches,
                jnp.asarray(S + i - 1, jnp.int32))
            token = self._sample(logits, sub)
            for r, t in zip(requests, np.asarray(token)):
                if len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(t))
        return requests

    # ------------------------------------------------------------------

    def sparsity_report(self, prompts: np.ndarray) -> dict[str, float]:
        """Measured BitStopper traffic on a served batch: mean planes
        fetched per (q, kv-block) and survivor fraction, from the semantic
        model run over the prefill attention of the first layer."""
        from repro.core.block_adaptation import block_bitstopper_attention
        from repro.models import layers as L

        cfg = self.cfg
        x = L.embed(self.params["embed"], jnp.asarray(prompts)).astype(
            cfg.activation_dtype)
        p0 = _first_attn_params(self.params, cfg)
        if p0 is None:
            return {}
        from repro.models.layers import linear, rope
        acfg = cfg.attn_config(False)
        pos = jnp.arange(x.shape[1])
        q = rope(linear(p0["wq"], x), pos[None], acfg.rope_theta)
        k = rope(linear(p0["wk"], x), pos[None], acfg.rope_theta)
        v = linear(p0["wv"], x)
        G = acfg.n_heads // acfg.n_kv_heads
        kr = jnp.repeat(k, G, axis=2).swapaxes(1, 2)
        vr = jnp.repeat(v, G, axis=2).swapaxes(1, 2)
        qt = q.swapaxes(1, 2)
        # Small q-tiles: a kv block stops fetching planes only when EVERY
        # query row in the tile agrees, so tall tiles can't terminate.
        res = block_bitstopper_attention(
            qt, kr, vr, cfg=cfg.bitstopper,
            block_q=min(8, qt.shape[-2]), block_k=min(16, kr.shape[-2]),
            causal=True)
        rounds = np.asarray(res.stats.rounds_per_block, np.float64)
        alive = np.asarray(res.stats.block_alive)
        surv = np.asarray(res.stats.survivors)
        return {
            "mean_rounds": float(rounds.mean()),
            "plane_fraction": float(rounds.mean() / cfg.bitstopper.bits),
            "block_alive_fraction": float(alive.mean()),
            "survivor_fraction": float(surv.mean()),
        }


def _first_attn_params(params, cfg: ModelConfig):
    for si, (unit, reps) in enumerate(cfg.segments):
        for i, spec in enumerate(unit):
            if spec.mixer in ("attn", "local_attn"):
                seg = params[f"seg{si}"]
                blk = seg[f"b{i}"] if isinstance(seg, dict) else seg[0][f"b{i}"]
                p = blk["attn"]
                if cfg.scan_layers and reps > 1 and isinstance(seg, dict):
                    p = jax.tree_util.tree_map(lambda a: a[0], p)
                return p
    return None
