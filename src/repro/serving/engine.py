"""Continuous-batching serving engines with decode-specialized BitStopper.

BitStopper is an *inference* accelerator: these engines are where the
paper's technique is deployed.  Two continuous batchers share the
scheduler surface:

* :class:`PagedEngine` (the default ``ServingEngine``) — a vLLM-style
  **paged** batcher: the KV cache is a refcounted block pool
  (``serving/kv_pool.py`` + ``init_caches(..., paged=PagedLayout(...))``),
  admission is bounded by pool capacity rather than ``max_len``, full
  prompt-prefix blocks are shared copy-on-write across requests, and
  prompts prefill in fixed-size chunks interleaved with decode steps.
* :class:`ContinuousBatchingEngine` — the contiguous per-slot cache
  (``init_caches(..., per_slot=True)``): each slot reserves ``max_len``
  rows; retained as the bit-identity baseline for the paged engine.

Both run decode through the single-query BitStopper fast path
(``besf_attention_decode``): all bit-plane contributions in one fused
integer contraction, per-round LATS logic reduced to elementwise ops.

Sampling is deterministic under a passed-in PRNG seed and
*schedule-invariant*: token n of request rid draws from
``fold_in(fold_in(base_key, rid), n)``, so the same trace + seed
reproduces every token on either engine regardless of slot assignment or
prefill chunking.

``sparsity_report()`` returns measured plane-fetch / survivor statistics
both aggregated and **per request**, feeding the Fig. 12/13 benchmarks
with served-traffic numbers.

``StaticBucketEngine`` preserves the pre-continuous-batching static
length-bucketed batcher as the baseline that
``benchmarks/serve_throughput.py`` compares against.
"""

from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# NOTE: repro.analysis.pool_sanitizer is imported lazily at pool
# construction — it imports serving.kv_pool, and a module-level import
# here would be circular through serving/__init__.
from repro.models import transformer as T
from repro.models.attention import (POS_SENTINEL, PagedLayout,
                                    apply_inject_amax_rule,
                                    extract_block_rows, repack_block_planes,
                                    requant_plane_pools, splice_block_rows)
from repro.models.config import ModelConfig
from repro.serving.chaos import CheckpointInterrupted, KernelFault
from repro.serving.kv_pool import KVBlockPool


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512                # contiguous: KV capacity per slot;
                                      # paged: default sizing for the pool
    max_slots: int = 4                # concurrent decode batch width
    prefill_bucket: int = 16          # prompts pad up to a multiple of this
    temperature: float = 0.0          # 0 = greedy
    cache_dtype: str = "float32"
    eos_id: int | None = None         # optional early stop token
    # ---- paged engine (PagedEngine) knobs ----
    page_size: int = 16               # tokens per KV block
    pool_blocks: int | None = None    # physical blocks incl. the null block
                                      # (default: full capacity, no paging
                                      # pressure: 1 + slots*max_blocks)
    max_blocks_per_req: int | None = None  # block-table width per slot
                                      # (default: ceil(max_len / page_size))
    prefill_chunk: int | None = None  # prompt tokens per prefill tick
                                      # (default: 4*prefill_bucket; must be
                                      # a multiple of prefill_bucket)
    prefix_sharing: bool = True       # share full prompt-prefix blocks
    oversubscribe: bool = False       # paged: admit against prompt-sized
                                      # reservations instead of worst case;
                                      # mid-decode exhaustion preempts a
                                      # victim (freed + requeued, lossless
                                      # resume via chunked-prefill recompute)
    preempt_policy: str = "fewest_tokens"  # victim choice under
                                      # oversubscription: "fewest_tokens"
                                      # (least generated -> cheapest
                                      # recompute) | "lifo" (newest admitted)
    fused_decode: bool | None = None  # BitStopper decode through the fused
                                      # paged Pallas kernel (True), the
                                      # pure-JAX gather fallback (False), or
                                      # auto: kernel iff running on TPU
    # ---- speculative decoding (PagedEngine) ----
    speculative: str = "off"          # "off" | "ngram" (prompt-lookup
                                      # self-drafter) | "draft" (draft
                                      # transformer; defaults to self-draft)
    draft_k: int = 4                  # draft tokens proposed per tick
    # ---- multi-device serving (PagedEngine) ----
    mesh: Any = None                  # jax.sharding.Mesh over ("data",
                                      # "model"): slots shard over "data",
                                      # KV heads (pools + attention) over
                                      # "model"; params replicated.  Output
                                      # stays bit-identical to mesh=None
                                      # (docs/serving.md).  None =
                                      # single-device.
    # ---- robustness (PagedEngine; docs/robustness.md) ----
    deadline_ticks: int | None = None # default per-request deadline, in
                                      # scheduler ticks from submission
                                      # (Request.deadline_ticks overrides);
                                      # expiry truncates a started request
                                      # (its tokens stay a prefix of the
                                      # undisturbed stream) and sheds a
                                      # never-started one.  None = none.
    shed_watermark: float | None = None  # pool-saturation fraction past
                                      # which queued "besteffort" requests
                                      # are rejected-with-reason instead of
                                      # admitted; needs oversubscribe=True
                                      # (worst-case-reserved admission
                                      # blocks instead of overcommitting,
                                      # so shedding could never relieve
                                      # preemption pressure).  None = off.
    snapshot_every: int = 0           # crash-snapshot cadence in ticks for
                                      # serving/chaos.serve_with_chaos and
                                      # launch/serve --snapshot-every
                                      # (0 = only the initial snapshot)
    # ---- KV memory hierarchy (PagedEngine; docs/serving.md) ----
    swap_host_bytes: int = 0          # host-RAM budget for swap-to-host
                                      # preemption: a victim's exclusive
                                      # blocks copy to host and resume by
                                      # splice instead of chunked-prefill
                                      # recompute (0 = recompute only)
    prefix_store_dir: str | None = None  # persistent prefix store: cold
                                      # registered prefix blocks spill to
                                      # disk via checkpoint/store.py and a
                                      # (re)started engine warms its prefix
                                      # cache from it.  None = off.
    prefix_host_bytes: int = 0        # host-RAM tier between the device
                                      # prefix LRU and the disk store
                                      # (evictions cascade downward);
                                      # 0 = spill straight to disk

    def __post_init__(self):
        if self.mesh is not None:
            axes = set(getattr(self.mesh, "axis_names", ()))
            if not axes or not axes <= {"data", "model"}:
                raise ValueError(
                    "ServeConfig.mesh must be a Mesh over axes named "
                    f"'data'/'model', got axes {sorted(axes)}")
        # Fail at construction with a nameable field, not deep inside jit.
        for name in ("max_len", "max_slots", "prefill_bucket", "page_size"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.cache_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"cache_dtype must be float32|bfloat16, got "
                             f"{self.cache_dtype!r}")
        if self.pool_blocks is not None and self.pool_blocks < 2:
            raise ValueError("pool_blocks must be >= 2 (block 0 is the "
                             f"null block), got {self.pool_blocks}")
        if self.max_blocks_per_req is not None and self.max_blocks_per_req < 1:
            raise ValueError(f"max_blocks_per_req must be >= 1, got "
                             f"{self.max_blocks_per_req}")
        if self.prefill_chunk is not None:
            if self.prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{self.prefill_chunk}")
            if self.prefill_chunk % self.prefill_bucket:
                raise ValueError(
                    f"prefill_chunk ({self.prefill_chunk}) must be a "
                    f"multiple of prefill_bucket ({self.prefill_bucket}): "
                    f"chunks are bucket-padded jit shapes")
        if self.fused_decode and self.page_size % 8:
            raise ValueError(
                f"fused_decode needs page_size % 8 == 0 (bit planes pack 8 "
                f"tokens/byte along the page axis), got page_size="
                f"{self.page_size}")
        if self.preempt_policy not in ("fewest_tokens", "lifo"):
            raise ValueError(
                f"preempt_policy must be fewest_tokens|lifo, got "
                f"{self.preempt_policy!r}")
        if self.speculative not in ("off", "ngram", "draft"):
            raise ValueError(
                f"speculative must be off|ngram|draft, got "
                f"{self.speculative!r}")
        if self.draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {self.draft_k}")
        if self.deadline_ticks is not None and self.deadline_ticks < 1:
            raise ValueError(f"deadline_ticks must be >= 1, got "
                             f"{self.deadline_ticks}")
        if self.shed_watermark is not None:
            if not 0.0 < self.shed_watermark < 1.0:
                raise ValueError(
                    f"shed_watermark must be in (0, 1), got "
                    f"{self.shed_watermark}")
            if not self.oversubscribe:
                raise ValueError(
                    "shed_watermark requires oversubscribe=True: worst-case"
                    "-reserved admission blocks the head of line instead of "
                    "overcommitting the pool, so saturation-based shedding "
                    "could never relieve preemption pressure")
        if self.snapshot_every < 0:
            raise ValueError(f"snapshot_every must be >= 0, got "
                             f"{self.snapshot_every}")
        if self.swap_host_bytes < 0:
            raise ValueError(f"swap_host_bytes must be >= 0, got "
                             f"{self.swap_host_bytes}")
        if self.prefix_host_bytes < 0:
            raise ValueError(f"prefix_host_bytes must be >= 0, got "
                             f"{self.prefix_host_bytes}")
        if self.swap_host_bytes and not self.oversubscribe:
            raise ValueError(
                "swap_host_bytes requires oversubscribe=True: swap-to-host "
                "captures preemption victims, and only oversubscribed "
                "admission ever preempts")
        if ((self.prefix_store_dir is not None or self.prefix_host_bytes)
                and not self.prefix_sharing):
            raise ValueError(
                "the prefix store extends the registered-prefix LRU tier "
                "downward; it needs prefix_sharing=True")

    # Resolved paged-layout sizes (None fields get max_len-derived defaults).
    def resolved_max_blocks(self) -> int:
        return self.max_blocks_per_req or -(-self.max_len // self.page_size)

    def resolved_pool_blocks(self) -> int:
        return (self.pool_blocks
                or 1 + self.max_slots * self.resolved_max_blocks())

    def resolved_chunk(self) -> int:
        return self.prefill_chunk or 4 * self.prefill_bucket


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                # [S] int32
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)
    rid: int = -1                     # assigned at submit()
    # ---- robustness / QoS (PagedEngine; docs/robustness.md) ----
    deadline_ticks: int | None = None # per-request deadline in scheduler
                                      # ticks from submission (overrides
                                      # ServeConfig.deadline_ticks)
    slo: str = "standard"             # "besteffort" (sheddable past the
                                      # watermark, victimized first) |
                                      # "standard" | "strict" (victimized
                                      # last)
    # per-request accounting, filled by the engine
    prefill_len: int = 0
    admitted_step: int = -1
    finished_step: int = -1
    preemptions: int = 0              # times this request was victimized
    submitted_tick: int = -1          # engine tick at submit()
    shed_reason: str | None = None    # "watermark" | "deadline" when the
                                      # engine rejected it (no tokens)
    deadline_hit: bool = False        # finished by deadline truncation
                                      # (generated is a PREFIX of the
                                      # undisturbed stream)


class InsufficientBlocks(RuntimeError):
    """The pool cannot cover an engine-API ``prefill()``/``insert()``
    right now.  Retryable: capacity returns as requests finish — callers
    (the disaggregation controller, the async door) back off a tick
    instead of failing the request."""


@dataclasses.dataclass
class Prefix:
    """Handle to a prefilled context: paged block handles plus sampling
    state — the currency of the JetStream-style engine API
    (``PagedEngine.prefill() -> insert() -> generate_step()``).

    Two forms:

    * **attached** (``pool`` is the source engine's pool): ``blocks``
      holds physical block ids whose references the Prefix OWNS — insert
      into the same engine is a pure block-table splice, no KV moves.
    * **detached** (``payload`` set, ``pool``/``blocks`` cleared by
      ``extract()``): block contents serialized through the pool to host
      arrays, so a *different* engine instance — its own pool, its own
      block numbering — can ``insert()`` it.  This is the
      prefill/decode-disaggregation handoff.
    """
    req: Request
    chain: np.ndarray          # cached context tokens [L] int32
    length: int                # tokens cached (== len(chain))
    last_token: int            # next decode input (already appended to
                               # req.generated by the prefill sample)
    blocks: list               # attached: block ids, refs owned here
    pool: Any = None           # pool identity the blocks live in
    payload: Any = None        # detached: {"layers": [...], "amax": [...]}
    finished: bool = False     # request completed during prefill (eos /
                               # max_new_tokens == 1 / deadline) — nothing
                               # to insert, tokens already in req.generated


def _supported(cfg: ModelConfig) -> None:
    mixers = {spec.mixer for unit, _ in cfg.segments for spec in unit}
    bad = mixers - {"attn", "local_attn"}
    if bad:
        raise ValueError(
            f"continuous batching serves attention models only "
            f"(per-slot KV cache); config has mixers {sorted(bad)}")


@partial(jax.jit, static_argnames=("temperature",))
def _sample_tokens(base_key, logits, rids, counts, temperature: float):
    """Per-request deterministic sampling: row i's key is
    ``fold_in(fold_in(base_key, rid_i), n_generated_i)`` — a pure function
    of (seed, request, token index), so the sampled trace is independent of
    scheduling (slot assignment, chunked vs one-shot prefill, interleaving
    order) and identical across engines."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)

    def one(row, rid, n):
        key = jax.random.fold_in(jax.random.fold_in(base_key, rid), n)
        return jax.random.categorical(key, row / temperature)

    return jax.vmap(one)(logits, rids, counts)


def _kv_bytes_per_token(cfg: ModelConfig, dtype) -> int:
    """KV-cache bytes one cached token costs across all attention layers."""
    itemsize = jnp.dtype(dtype).itemsize
    total = 0
    for unit, reps in cfg.segments:
        for spec in unit:
            if spec.mixer in ("attn", "local_attn"):
                acfg = cfg.attn_config(spec.mixer == "local_attn")
                total += reps * 2 * acfg.n_kv_heads * acfg.head_dim * itemsize
    return total


def _plane_bytes_per_token(cfg: ModelConfig) -> int:
    """Bit-plane-pool bytes one cached token costs across BitStopper
    layers when the fused decode kernel maintains the packed ``kq`` pool:
    ``bits`` planes x 1 bit x Hkv x D per token."""
    total = 0
    for unit, reps in cfg.segments:
        for spec in unit:
            if spec.mixer not in ("attn", "local_attn"):
                continue
            acfg = cfg.attn_config(spec.mixer == "local_attn")
            if (acfg.impl in ("bitstopper", "bitstopper_xla")
                    and acfg.fused_decode):
                total += (reps * acfg.bitstopper.bits
                          * acfg.n_kv_heads * acfg.head_dim) // 8
    return total


def _amax_static_bytes(cfg: ModelConfig) -> int:
    """Pool-wide running quant-scale state (``k_amax``/``v_amax``, f32 per
    KV head) carried by every BitStopper layer's paged cache — static in
    the pool size but part of the honest resident footprint."""
    total = 0
    for unit, reps in cfg.segments:
        for spec in unit:
            if spec.mixer not in ("attn", "local_attn"):
                continue
            acfg = cfg.attn_config(spec.mixer == "local_attn")
            if acfg.impl in ("bitstopper", "bitstopper_xla"):
                total += reps * 2 * acfg.n_kv_heads * 4
    return total


def _kv_bytes_contiguous(cfg: ModelConfig, scfg: ServeConfig, dtype) -> int:
    """Resident bytes of the contiguous per-slot cache: max_len rows per
    slot per layer, except sliding-window layers whose ring buffers only
    allocate min(max_len, window) rows."""
    itemsize = jnp.dtype(dtype).itemsize
    total = 0
    for unit, reps in cfg.segments:
        for spec in unit:
            if spec.mixer not in ("attn", "local_attn"):
                continue
            acfg = cfg.attn_config(spec.mixer == "local_attn")
            rows = scfg.max_len
            if spec.mixer == "local_attn" and acfg.window:
                rows = min(rows, acfg.window)
            total += (reps * rows * 2 * acfg.n_kv_heads * acfg.head_dim
                      * itemsize)
    return total * scfg.max_slots


def _amax_leaves(caches) -> list:
    """Every ``k_amax``/``v_amax`` leaf of a paged cache pytree, in a
    deterministic traversal order (used to detect pool-wide quant-scale
    growth across a speculative draft-block write)."""
    out = []
    if isinstance(caches, dict):
        for key in sorted(caches):
            if key in ("k_amax", "v_amax"):
                out.append(caches[key])
            else:
                out.extend(_amax_leaves(caches[key]))
    elif isinstance(caches, (list, tuple)):
        for c in caches:
            out.extend(_amax_leaves(c))
    return out


def _set_amax_leaves(caches, values: list):
    """Write quant-scale leaves back into a paged cache pytree, in the
    same deterministic traversal order :func:`_amax_leaves` reads them —
    the restore half of engine snapshotting.  The running scales are
    monotone and order-dependent (growth overshoots by ``AMAX_HEADROOM``),
    so a restored engine must inherit the crash-time scales rather than
    re-derive them from recomputed tokens: with identical scales the
    recompute writes trigger no growth and every future growth event fires
    identically to the undisturbed run."""
    it = iter(values)

    def rec(c):
        if isinstance(c, dict):
            out = {}
            for key in sorted(c):
                if key in ("k_amax", "v_amax"):
                    ref = c[key]
                    out[key] = jnp.asarray(
                        np.asarray(next(it), np.float32).reshape(ref.shape),
                        ref.dtype)
                else:
                    out[key] = rec(c[key])
            return out
        if isinstance(c, (list, tuple)):
            new = [rec(x) for x in c]
            return new if isinstance(c, list) else tuple(new)
        return c

    new = rec(caches)
    leftover = sum(1 for _ in it)
    if leftover:
        raise ValueError(f"snapshot carries {leftover} extra quant-scale "
                         f"leaves the cache has no home for")
    return new


def _attach_tables(caches, table: np.ndarray, length: np.ndarray):
    """Rebuild a paged cache pytree with the engine's authoritative block
    table / fill levels attached to every layer (stacked layers broadcast
    along their leading reps axis).  K/V/pos pool leaves pass through."""
    t = jnp.asarray(table, jnp.int32)
    ln = jnp.asarray(length, jnp.int32)

    def rec(c):
        if isinstance(c, dict):
            if "table" in c:
                nt, nl = t, ln
                if c["table"].ndim == 3:          # scanned: [reps, B, MB]
                    reps = c["table"].shape[0]
                    nt = jnp.broadcast_to(t[None], (reps,) + t.shape)
                    nl = jnp.broadcast_to(ln[None], (reps,) + ln.shape)
                return dict(c, table=nt, length=nl)
            return {k: rec(v) for k, v in c.items()}
        if isinstance(c, list):
            return [rec(x) for x in c]
        return c

    return rec(caches)


class _EngineCommon:
    """Shared scheduler-loop + measurement surface of the serving engines."""

    def begin(self, seed: int = 0) -> None:
        """Fix the sampling seed for a serving run.  Split out of
        :meth:`run` so external drivers (``serving/chaos.py``) can own the
        tick loop; a restored engine re-derives the same base key, keeping
        every continuation token under its original (seed, rid, n) key."""
        self._seed = seed
        self._base_key = jax.random.PRNGKey(seed)

    def pending(self) -> bool:
        """True while any submitted request is unfinished (queued or in a
        slot) — the tick-loop condition."""
        return bool(self.queue or any(r is not None for r in self.slots))

    def run(self, seed: int = 0) -> None:
        """Drain queue + slots to completion, deterministically under seed."""
        self.begin(seed)
        while self.pending():
            self.step()

    def generate(self, requests: list[Request], seed: int = 0):
        """Serve a list of requests (arbitrary prompt lengths) to
        completion; returns the same list with ``generated`` filled."""
        for r in requests:
            self.submit(r)
        self.run(seed)
        return requests

    def _sample_rows(self, logits, rids, counts) -> np.ndarray:
        toks = _sample_tokens(self._base_key, logits,
                              jnp.asarray(rids, jnp.int32),
                              jnp.asarray(counts, jnp.int32),
                              self.scfg.temperature)
        return np.asarray(toks, np.int32)

    def _bucketed(self, L: int) -> int:
        b = self.scfg.prefill_bucket
        return min(self.scfg.max_len, -(-L // b) * b)

    # ------------------------------------------------------------------
    # measured-traffic reporting
    # ------------------------------------------------------------------

    def sparsity_report(self, prompts) -> dict[str, Any]:
        """Measured BitStopper traffic, per request and aggregated.

        ``prompts``: 2-D int array [B, S] or a list of 1-D int arrays of
        arbitrary (per-request) lengths.  Each request's prefill attention
        at the first attention layer is run through the block-granular
        semantic model; returns mean planes fetched per (q, kv-block),
        plane fraction vs dense 12-bit, block-level V-fetch fraction and
        token survivor fraction — aggregated under the legacy keys, plus a
        ``per_request`` list for served-traffic benchmarks."""
        if isinstance(prompts, np.ndarray) and prompts.ndim == 2:
            prompts = list(prompts)
        per_request = []
        for p in prompts:
            rep = _prompt_sparsity(self.cfg, self.params, np.asarray(p))
            if rep:
                per_request.append(rep)
        if not per_request:
            return {}
        # Weighted aggregation: a long prompt has many more (q-tile,
        # kv-block) units and (q, k) pairs than a short one — an
        # unweighted mean over requests would let short prompts skew the
        # traffic headline.
        blocks = np.array([r["n_blocks"] for r in per_request], np.float64)
        pairs = np.array([r["n_pairs"] for r in per_request], np.float64)

        def wmean(key, w):
            vals = np.array([r[key] for r in per_request], np.float64)
            return float((vals * w).sum() / w.sum())

        return {
            "mean_rounds": wmean("mean_rounds", blocks),
            "plane_fraction": wmean("plane_fraction", blocks),
            "block_alive_fraction": wmean("block_alive_fraction", blocks),
            "survivor_fraction": wmean("survivor_fraction", pairs),
            "per_request": per_request,
        }


class ContinuousBatchingEngine(_EngineCommon):
    """Request-level continuous batching over a per-slot KV cache."""

    def __init__(self, cfg: ModelConfig, params,
                 scfg: ServeConfig = ServeConfig()):
        _supported(cfg)
        if scfg.speculative != "off":
            raise ValueError(
                "speculative decoding needs the paged engine (block-table "
                "rollback); use PagedEngine")
        if scfg.oversubscribe:
            raise ValueError(
                "oversubscription needs the paged engine (block-pool "
                "preemption); use PagedEngine")
        if (scfg.deadline_ticks is not None
                or scfg.shed_watermark is not None or scfg.snapshot_every):
            raise ValueError(
                "deadlines / load shedding / crash snapshots are "
                "PagedEngine features (docs/robustness.md); use PagedEngine")
        if (scfg.swap_host_bytes or scfg.prefix_host_bytes
                or scfg.prefix_store_dir is not None):
            raise ValueError(
                "the KV memory hierarchy (swap_host_bytes / "
                "prefix_store_dir / prefix_host_bytes) is a PagedEngine "
                "feature (docs/serving.md); use PagedEngine")
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        if scfg.mesh is not None:
            raise ValueError(
                "ServeConfig.mesh is a PagedEngine feature (the contiguous "
                "per-slot engine is the single-device baseline)")
        self._dtype = (jnp.bfloat16 if scfg.cache_dtype == "bfloat16"
                       else jnp.float32)

        def prefill_fn(params, tokens, caches, positions, last_idx):
            # tokens/positions [1, Sp] (bucket-padded; pads hold the
            # sentinel position and are dropped by the cache write).
            logits, caches, _ = T.forward(params, tokens, cfg, caches=caches,
                                          positions=positions)
            last = jax.lax.dynamic_slice_in_dim(logits, last_idx, 1, axis=1)
            return last[:, 0], caches

        def decode_fn(params, tokens, caches, positions):
            # tokens/positions [B, 1] — B slots, each at its own position.
            logits, caches, _ = T.forward(params, tokens, cfg, caches=caches,
                                          positions=positions)
            return logits[:, -1], caches

        def insert_fn(big, small, slot):
            def ins(b, s):
                # The slot (batch) axis is the first one where the engine
                # cache (max_slots wide) and the batch-1 prefill cache
                # differ; with max_slots == 1 every axis matches and the
                # insert is a whole-cache replacement.
                axis = next((i for i, (x, y) in
                             enumerate(zip(b.shape, s.shape)) if x != y),
                            None)
                if axis is None:
                    return s.astype(b.dtype)
                starts = tuple(slot if i == axis else 0
                               for i in range(b.ndim))
                return jax.lax.dynamic_update_slice(
                    b, s.astype(b.dtype), starts)

            return jax.tree_util.tree_map(ins, big, small)

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self._insert = jax.jit(insert_fn)

        B = scfg.max_slots
        self.caches = T.init_caches(cfg, B, scfg.max_len, self._dtype,
                                    per_slot=True)
        # Reused on every admission: jax arrays are immutable and prefill
        # is functional, so one empty 1-slot cache serves all requests.
        self._empty_slot = T.init_caches(cfg, 1, scfg.max_len, self._dtype,
                                         per_slot=True)
        self.slots: list[Request | None] = [None] * B
        self.queue: collections.deque[Request] = collections.deque()
        self.lengths = np.zeros((B,), np.int32)       # tokens in each slot
        self.last_token = np.zeros((B,), np.int32)    # next decode input
        self._next_rid = 0
        self._step = 0
        self._seed = None
        self._base_key = jax.random.PRNGKey(0)
        self.counters = {"prefill_tokens": 0, "decode_tokens": 0,
                         "decode_steps": 0, "decode_slot_steps": 0,
                         "requests_finished": 0}

    def kv_bytes_resident(self) -> int:
        """KV memory the cache keeps resident: contiguous slots reserve
        their full capacity (ring buffers: the window) no matter the
        occupancy."""
        return _kv_bytes_contiguous(self.cfg, self.scfg, self._dtype)

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> Request:
        L = len(req.prompt)
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if L + req.max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"request needs {L}+{req.max_new_tokens} tokens, "
                f"max_len={self.scfg.max_len}")
        req.rid = self._next_rid
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _admit(self) -> None:
        while self.queue and None in self.slots:
            slot = self.slots.index(None)
            req = self.queue.popleft()
            L = len(req.prompt)
            Sp = self._bucketed(L)
            tokens = np.zeros((1, Sp), np.int32)
            tokens[0, :L] = np.asarray(req.prompt, np.int32)
            positions = np.full((1, Sp), POS_SENTINEL, np.int32)
            positions[0, :L] = np.arange(L, dtype=np.int32)

            last_logits, small = self._prefill(
                self.params, jnp.asarray(tokens), self._empty_slot,
                jnp.asarray(positions), jnp.asarray(L - 1, jnp.int32))
            self.caches = self._insert(self.caches, small,
                                       jnp.asarray(slot, jnp.int32))

            tok = int(self._sample_rows(last_logits, [req.rid], [0])[0])
            req.generated.append(tok)
            req.prefill_len = L
            req.admitted_step = self._step
            self.counters["prefill_tokens"] += L
            self.slots[slot] = req
            self.lengths[slot] = L
            self.last_token[slot] = tok
            self._maybe_evict(slot, tok)

    def _maybe_evict(self, slot: int, tok: int) -> None:
        req = self.slots[slot]
        if req is None:
            return
        done = len(req.generated) >= req.max_new_tokens
        if self.scfg.eos_id is not None and tok == self.scfg.eos_id:
            done = True
        if done:
            req.finished_step = self._step
            self.counters["requests_finished"] += 1
            self.slots[slot] = None

    def step(self) -> bool:
        """One scheduler tick: admit from the queue, then one decode step
        over every active slot.  Returns False when there is no work."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return bool(self.queue)
        self._step += 1
        tokens = jnp.asarray(self.last_token[:, None])
        positions = jnp.asarray(self.lengths[:, None])
        logits, self.caches = self._decode(
            self.params, tokens, self.caches, positions)
        rids = [r.rid if r is not None else 0 for r in self.slots]
        counts = [len(r.generated) if r is not None else 0
                  for r in self.slots]
        toks = self._sample_rows(logits, rids, counts)
        self.counters["decode_steps"] += 1
        self.counters["decode_slot_steps"] += len(self.slots)
        for i in active:
            req = self.slots[i]
            req.generated.append(int(toks[i]))
            self.counters["decode_tokens"] += 1
            self.lengths[i] += 1
            self.last_token[i] = toks[i]
            self._maybe_evict(i, int(toks[i]))
        return True


# ---------------------------------------------------------------------------
# Paged engine: block-pool KV cache, prefix sharing, chunked prefill
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _PagedSlot:
    """Scheduler-side state of one occupied serving slot."""
    req: Request
    next_prefill: int          # ctx tokens [0, next_prefill) are cached
    blocks_reserved: int       # reservation units not yet turned into allocs
    ctx: np.ndarray            # prefill token sequence: the prompt, or —
                               # resuming a preempted request — the prompt
                               # plus every generated token already cached
                               # (all but the last, which is the next
                               # decode input, never written back yet)
    resumed: bool = False      # resuming after preemption: the tail of
                               # ``generated`` is replayed, not re-sampled
    seq: int = 0               # admission order (LIFO victim policy)

    def prefilled(self) -> bool:
        return self.next_prefill >= len(self.ctx)


class PagedEngine(_EngineCommon):
    """Continuous batching over a paged block-pool KV cache.

    Differences from :class:`ContinuousBatchingEngine`:

    * **Paged KV.**  One batch-free K/V pool per layer; slots address it
      through refcounted block tables (``kv_pool.KVBlockPool`` owns the
      host-side allocation).  Admission is bounded by *pool capacity*, not
      ``max_len``: a request may generate past ``max_len`` as long as its
      block-table width (``max_blocks_per_req``) and the pool allow.
    * **Prefix sharing.**  Full prompt blocks are published under their
      token-chain key; a later request with the same prompt prefix maps the
      shared physical blocks into its table (refcount++), skips recomputing
      those tokens, and pays near-zero duplicate KV memory — the
      system-prompt workload.
    * **Chunked prefill.**  A prompt is prefilled ``prefill_chunk`` tokens
      per scheduler tick, interleaved with decode steps of in-flight slots,
      bounding decode-latency jitter from long prompts.
    * **Oversubscription** (``ServeConfig.oversubscribe``).  Admission
      reserves only the context blocks plus one decode block instead of
      the worst case — a pool sized for realistic traffic admits more
      concurrency than worst-case ``max_new_tokens`` would allow.  When a
      mid-decode block claim then finds the pool dry, the scheduler
      preempts a victim (``preempt_policy``: fewest tokens generated, or
      newest admission): its exclusively-owned blocks free outright,
      shared/registered prefix blocks drop a reference (staying mapped or
      parking resurrectable in the LRU), and the request requeues at the
      head of the line.  Resume is **lossless**: the victim re-admits with
      its context (prompt + generated tokens), re-maps still-registered
      prefix blocks for free, recomputes the unshared tail through the
      ordinary chunked prefill, and continues decoding from its last
      sampled token — sampling keys are a pure function of (seed, rid,
      token index), so the served trace is bit-identical to an uncontended
      run (on the dense path; see ``docs/serving.md`` for the BitStopper
      quant-scale caveat).

    On the dense (``xla``) score path the served tokens are bit-identical
    to the contiguous engine: per-query attention sees the same KV set
    under the same mask, and masked view slots are exact zeros (padding
    with exact zeros/NEG_INF never perturbs f32 accumulation).  The
    BitStopper paths track the contiguous engine within LATS/quantization
    tolerance, not bit-for-bit: block prefill tiles per chunk, and paged
    decode quantizes K/V under the pool-wide running scales (a shared
    physical page must mean the same integers to every table mapping it)
    where the contiguous engine re-derives per-row view scales.

    **Fused paged decode.**  With a BitStopper impl (and ``page_size``
    divisible by 8) the cache additionally maintains an incremental
    bit-plane pool at write time, and the decode tick never gathers the
    dense per-row KV view: it hands the pool + block tables + fill levels
    straight to the paged BESF decode — the fused Pallas kernel
    (``kernels/paged_decode.py``) when ``fused_decode`` resolves True,
    else the pure-JAX paged oracle (``besf_attention_decode_paged``, the
    retained gather fallback).  The two are bit-identical (tested), so
    flipping the switch never changes served tokens.

    **Speculative decoding** (``ServeConfig.speculative``).  Each decode
    tick a drafter proposes up to ``draft_k`` tokens per slot
    (``serving/speculative.py``); the tick then runs ONE Sq=k+1 verify
    forward — [last sampled token, draft 1..k] written into the paged
    cache in a batched scatter, BitStopper attention through the
    multi-query paged verify (each query bit-identical to the Sq=1 decode
    at its position; fused Sq-tiled kernel or oracle per ``fused_decode``)
    — and accepts the longest draft prefix matching the target's own
    greedy/seeded samples.  Acceptance is **lossless**: token n is always
    sampled from logits bit-identical to non-speculative decode under the
    same ``fold_in(fold_in(seed, rid), n)`` key, so traces never change,
    only how many forwards they take.  The rejected tail is a *rollback*,
    not a rewrite: fill levels retreat (stale pool slots are unobservable
    behind the fill-level masks) and draft-tail blocks return to the pool
    with their reservation units restored (``KVBlockPool.rollback``) —
    never past the prompt/shared-prefix boundary, which lives below the
    decode region by construction.  A write that grows the pool-wide quant
    scale mid-draft-block would make earlier queries see a "future" scale;
    the engine detects scale growth on the device, discards the whole
    speculative step (immutable-cache snapshot restore) and replays it as
    a plain decode tick — rare after warmup, and the replay is the
    non-speculative path itself, so losslessness is unconditional."""

    def __init__(self, cfg: ModelConfig, params,
                 scfg: ServeConfig = ServeConfig(), drafter=None):
        _supported(cfg)
        # Resolve the decode-kernel choice once: the fused paged Pallas
        # kernel wants compiled Pallas (TPU); everywhere else the pure-JAX
        # paged oracle (the gather fallback) is the fast interpreter-free
        # path.  An explicit ServeConfig.fused_decode bool always wins —
        # fused_decode=True off-TPU runs the kernel in interpret mode,
        # which is how CI validates it.
        fused = scfg.fused_decode
        if fused is None:
            fused = jax.default_backend() == "tpu" and scfg.page_size % 8 == 0
        cfg = self.cfg = cfg.replace(fused_decode=bool(fused))
        self.params = params
        self.scfg = scfg
        # Mesh-sharded serving: slots over "data", KV heads (paged pools +
        # per-head BESF attention) over "model", parameters replicated —
        # the layout under which sharded output is bit-identical to
        # single-device (make_serve_rules / docs/serving.md).  The rules
        # are entered inside the jitted closures so constrain() and the
        # paged shard_map see them at trace time; the host-side scheduler,
        # KVBlockPool allocator, block tables and fill levels are untouched
        # (replicated across "model", so CoW sharing / preemption /
        # rollback / the sanitizer ledger work unchanged).
        # (MQA fallback: a KV-head count the model axis doesn't divide
        # replicates the pools via PAGED_CACHE_RULES' divisibility check
        # and skips the attention shard_map — still correct, still
        # bit-identical, just not tensor-parallel.)
        self._rules = None
        if scfg.mesh is not None:
            from repro.sharding.rules import make_serve_rules
            self._rules = make_serve_rules(scfg.mesh)
        self._dtype = (jnp.bfloat16 if scfg.cache_dtype == "bfloat16"
                       else jnp.float32)
        self._page = scfg.page_size
        self._mb = scfg.resolved_max_blocks()
        self._chunk = scfg.resolved_chunk()
        self.layout = PagedLayout(scfg.resolved_pool_blocks(), self._page,
                                  self._mb)
        # Under REPRO_SANITIZE=1 this is the shadow-ledger wrapper with
        # freed-page poisoning (see analysis/pool_sanitizer.py); otherwise
        # a plain KVBlockPool.
        from repro.analysis.pool_sanitizer import make_kv_pool, make_swap_pool
        # KV memory hierarchy (docs/serving.md "Memory hierarchy"): host
        # swap records for preemption victims, plus a host-RAM -> disk
        # spill cascade for registered prefix blocks the device LRU
        # evicts.  The pool's evict_cb fires while the stolen block's
        # device content is still intact (before the new owner can write
        # and before the sanitizer poisons), so the spill copy is exact.
        self._swap = (make_swap_pool(scfg.swap_host_bytes)
                      if scfg.swap_host_bytes else None)
        self._prefix_host = (
            make_swap_pool(scfg.prefix_host_bytes,
                           evict_cb=self._spill_prefix_record)
            if scfg.prefix_host_bytes else None)
        evict_cb = (self._on_prefix_evict
                    if (self._prefix_host is not None
                        or scfg.prefix_store_dir is not None) else None)
        self.pool = make_kv_pool(self.layout.pool_blocks, self._page,
                                 prefix_sharing=scfg.prefix_sharing,
                                 poison_cb=self._poison_blocks,
                                 evict_cb=evict_cb)

        # Deterministic fault injection (serving/chaos.py): when a
        # FaultInjector is attached, the engine consults it at its
        # injection points (pool claim, fused kernel call, drafter) keyed
        # on self.ticks — nothing else in the tick path changes.
        self.chaos = None

        # Speculative decoding: drafter selection happens before the jits
        # are built (the verify closure exists iff a drafter does).
        self._drafter = None
        self._spec_k = scfg.draft_k
        if scfg.speculative != "off":
            if (cfg.attn_impl in ("bitstopper", "bitstopper_xla")
                    and scfg.page_size % 8):
                raise ValueError(
                    "speculative BitStopper serving needs page_size % 8 == "
                    "0 (the paged verify shares the pool-wide quant state; "
                    f"got page_size={scfg.page_size})")
            from repro.serving.speculative import make_drafter
            self._drafter = drafter if drafter is not None else \
                make_drafter(scfg.speculative, cfg, params)
        elif drafter is not None:
            raise ValueError(
                "drafter passed but ServeConfig.speculative == 'off'")

        self._build_jits()

        B = scfg.max_slots
        self.caches = T.init_caches(cfg, B, scfg.max_len, self._dtype,
                                    paged=self.layout)
        if self._rules is not None:
            # Commit the pool leaves to their mesh placement (KV-head shard
            # over "model", bookkeeping replicated) and the params to full
            # replication; jit keeps these shardings on the returned caches,
            # so every subsequent tick runs sharded without further movement.
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.sharding.rules import cache_shardings
            self.caches = jax.device_put(
                self.caches, cache_shardings(self._rules, self.caches))
            self.params = jax.device_put(
                self.params, NamedSharding(scfg.mesh, PartitionSpec()))
        self.slots: list[_PagedSlot | None] = [None] * B
        self.queue: collections.deque[Request] = collections.deque()
        self.table = np.zeros((B, self._mb), np.int32)
        self.lengths = np.zeros((B,), np.int32)
        self.last_token = np.zeros((B,), np.int32)
        self._prefill_fifo: collections.deque[int] = collections.deque()
        self._next_rid = 0
        self._admit_seq = 0
        self._step = 0
        self._seed = None
        self._base_key = jax.random.PRNGKey(0)
        # Public monotone tick counter: every fault-injection decision,
        # deadline, and snapshot cadence keys on it (never wall clock), so
        # chaos runs are replayable bit-for-bit.  Persisted by snapshot().
        self.ticks = 0
        # Every request ever submitted, by rid — makes a snapshot (and a
        # post-crash restore) self-contained: the full trace output is
        # recoverable from the engine alone.
        self.requests: dict[int, Request] = {}
        self.counters = {"prefill_tokens": 0, "prefix_hit_tokens": 0,
                         "prefill_chunks": 0, "decode_tokens": 0,
                         "decode_steps": 0, "decode_slot_steps": 0,
                         "decode_kv_tokens": 0, "requests_finished": 0,
                         "spec_ticks": 0, "spec_proposed": 0,
                         "spec_accepted": 0, "spec_bailouts": 0,
                         "preemptions": 0, "preempt_freed_blocks": 0,
                         "preempt_dropped_tokens": 0,
                         "requests_shed": 0, "shed_watermark": 0,
                         "shed_deadline": 0, "deadline_truncated": 0,
                         "degradations": 0, "drafter_failures": 0,
                         "forced_preemptions": 0,
                         # JetStream-style engine API (frontdoor/disagg)
                         "prefixes_prefilled": 0, "prefixes_inserted": 0,
                         "prefix_transfers": 0,
                         # KV memory hierarchy (docs/serving.md)
                         "swap_outs": 0, "swap_ins": 0,
                         "swap_fallbacks": 0, "swap_in_tokens": 0,
                         "prefix_spills": 0, "prefix_store_hits": 0,
                         "prefix_store_tokens": 0,
                         "prefix_store_interrupts": 0}

    # ------------------------------------------------------------------
    # jitted forwards + the kernel circuit breaker
    # ------------------------------------------------------------------

    def _build_jits(self) -> None:
        """(Re)build the jitted forward closures from the *current*
        ``self.cfg`` — at construction, and again when the circuit breaker
        flips ``fused_decode`` off.  The closures capture cfg by value, so
        a degrade must rebuild them; the cache pytree itself is untouched
        (the read path keys on cfg, the write path on cache structure, and
        the f32 pool is always maintained — the fallback reads the same
        cache the kernel did)."""
        cfg = self.cfg
        from repro.sharding.api import use_rules

        def prefill_fn(params, tokens, caches, positions, last_idx):
            # tokens/positions [1, Sp]: one chunk of one slot's prompt,
            # written straight into the shared pool through the slot's
            # block-table row — no post-hoc cache insert.
            with use_rules(self._rules):
                logits, caches, _ = T.forward(params, tokens, cfg,
                                              caches=caches,
                                              positions=positions)
            last = jax.lax.dynamic_slice_in_dim(logits, last_idx, 1, axis=1)
            return last[:, 0], caches

        def decode_fn(params, tokens, caches, positions):
            with use_rules(self._rules):
                logits, caches, _ = T.forward(params, tokens, cfg,
                                              caches=caches,
                                              positions=positions)
            return logits[:, -1], caches

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

        # The Sq=k+1 verify forward closes over spec_verify=True so
        # multi-query BitStopper attention routes through the paged verify
        # (NOT block prefill).
        if self._drafter is not None:
            cfg_v = cfg.replace(spec_verify=True)

            def verify_fn(params, tokens, caches, positions):
                with use_rules(self._rules):
                    logits, new_caches, _ = T.forward(
                        params, tokens, cfg_v, caches=caches,
                        positions=positions)
                # Scale-growth probe: did this draft-block write grow any
                # layer's pool-wide running max-abs?  (Non-BitStopper
                # impls carry no amax leaves: grew is constant False.)
                old_amax = _amax_leaves(caches)
                new_amax = _amax_leaves(new_caches)
                grew = jnp.zeros((), bool)
                for o, n in zip(old_amax, new_amax):
                    grew |= jnp.any(n > o)
                return logits, new_caches, grew

            self._verify = jax.jit(verify_fn)

    def _degrade(self, why: str) -> None:
        """Per-engine circuit breaker: a fused-kernel fault flips the
        engine onto the pure-JAX gather oracle for the rest of its life.
        Still **lossless** — fused and fallback decode/verify are
        bit-identical (tests/test_paged_decode.py, fused-vs-fallback trace
        tests), so degrading never changes served tokens, only per-step
        traffic.  Counter-reported as ``degradations``."""
        if not self.cfg.fused_decode:
            raise RuntimeError(
                f"kernel fault on the gather-fallback path ({why}): the "
                f"breaker has nothing simpler to fall back to")
        self.cfg = self.cfg.replace(fused_decode=False)
        self._build_jits()
        self.counters["degradations"] += 1

    def _guarded_decode(self, *args):
        """The decode forward behind the circuit breaker.  A failed jitted
        call leaves ``self.caches`` unmutated (the caller assigns only on
        return), so the post-degrade retry re-runs the *same tick* through
        the fallback against identical state — bit-identical recovery."""
        try:
            if (self.chaos is not None and self.cfg.fused_decode
                    and self.chaos.fire("kernel_fail", self.ticks)):
                raise KernelFault(
                    f"injected fused-decode fault at tick {self.ticks}")
            return self._decode(*args)
        except KernelFault as e:
            self._degrade(str(e))
            return self._decode(*args)

    def _guarded_verify(self, *args):
        try:
            if (self.chaos is not None and self.cfg.fused_decode
                    and self.chaos.fire("kernel_fail", self.ticks)):
                raise KernelFault(
                    f"injected fused-verify fault at tick {self.ticks}")
            return self._verify(*args)
        except KernelFault as e:
            self._degrade(str(e))
            return self._verify(*args)

    # ------------------------------------------------------------------
    # sanitizer poison hook
    # ------------------------------------------------------------------

    def _poison_blocks(self, bids: list[int]) -> None:
        """REPRO_SANITIZE poison mode: overwrite freed blocks' pool pages
        with loud sentinels the moment they return to the free list (and
        before any realloc can hand them to a new owner).  A read through
        a stale block table or a fill-level hole then produces wildly
        wrong values instead of silently reusing stale KV; correctly
        masked paths are unaffected because every dead-lane consumer
        multiplies by zero or selects away — finite poison stays exactly
        maskable (``0 * POISON_KV == 0``)."""
        if not bids or getattr(self, "caches", None) is None:
            return
        from repro.analysis.pool_sanitizer import (POISON_BYTE, POISON_KV,
                                                   POISON_POS)
        idx = jnp.asarray(sorted(set(bids)), jnp.int32)

        def poison_layer(c):
            if not isinstance(c, dict):
                if isinstance(c, list):
                    return [poison_layer(x) for x in c]
                return c
            if "table" not in c:
                return {k: poison_layer(v) for k, v in c.items()}
            # paged layer: stacked (scanned) layers carry a leading reps
            # axis on every pool leaf; the table's rank tells which.
            stacked = c["table"].ndim == 3

            def pset(a, val):
                return a.at[:, idx].set(val) if stacked else \
                    a.at[idx].set(val)

            new = dict(c)
            new["k"] = pset(c["k"], POISON_KV)
            new["v"] = pset(c["v"], POISON_KV)
            new["pos"] = pset(c["pos"], POISON_POS)
            if "kq" in c:
                new["kq"] = pset(c["kq"], POISON_BYTE)
            return new

        self.caches = poison_layer(self.caches)

    # ------------------------------------------------------------------
    # capacity accounting
    # ------------------------------------------------------------------

    def _blocks_for(self, req: Request) -> int:
        """Worst-case block need: the final sampled token is never written
        back, so at most prompt + max_new_tokens - 1 slots are cached."""
        tokens = len(req.prompt) + req.max_new_tokens - 1
        return max(1, -(-tokens // self._page))

    def kv_bytes_resident(self, peak: bool = True) -> int:
        """KV memory actually backed by live blocks (peak over the run by
        default) — the paged analogue of the contiguous engine's static
        ``max_slots * max_len`` reservation.

        BitStopper caches are charged for everything a live block really
        carries: the f32 K/V rows AND, when the fused decode kernel is on,
        the packed bit-plane pool (``kq``: bits x Hkv x D bits per token —
        the plane-pool overhead the fused path trades for its traffic
        win), plus the tiny static ``k_amax``/``v_amax`` scale state."""
        blocks = (self.pool.peak_live_blocks if peak
                  else self.pool.live_blocks())
        per_tok = _kv_bytes_per_token(self.cfg, self._dtype)
        extra = 0
        if self._page % 8 == 0:
            per_tok += _plane_bytes_per_token(self.cfg)
            extra = _amax_static_bytes(self.cfg)
        return blocks * self._page * per_tok + extra

    def kv_bytes_contiguous_equiv(self) -> int:
        """What a contiguous per-slot cache of the same ServeConfig would
        keep resident (window layers: ring-buffer rows), for benchmark
        comparisons."""
        return _kv_bytes_contiguous(self.cfg, self.scfg, self._dtype)

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def _validate_request(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        need = self._blocks_for(req)
        if need > self._mb:
            raise ValueError(
                f"request needs {need} KV blocks, block table holds "
                f"{self._mb} (raise max_blocks_per_req or max_len)")
        if need > self.pool.capacity:
            raise ValueError(
                f"request needs {need} KV blocks, pool has "
                f"{self.pool.capacity} (raise pool_blocks)")
        if req.slo not in ("besteffort", "standard", "strict"):
            raise ValueError(
                f"slo must be besteffort|standard|strict, got {req.slo!r}")
        if req.deadline_ticks is not None and req.deadline_ticks < 1:
            raise ValueError(
                f"deadline_ticks must be >= 1, got {req.deadline_ticks}")

    def _register(self, req: Request) -> None:
        """Record the request under its rid, assigning one if unset.
        Pre-assigned rids let an external admission layer
        (``serving/frontdoor``) fix each request's sampling identity at
        ARRIVAL time and then reorder actual submission freely: keys are
        ``fold_in(fold_in(seed, rid), n)``, so fairness reordering cannot
        change a single served token."""
        if req.rid < 0:
            req.rid = self._next_rid
        elif (req.rid in self.requests
              and self.requests[req.rid] is not req):
            raise ValueError(
                f"rid {req.rid} already belongs to another request")
        self._next_rid = max(self._next_rid, req.rid + 1)
        self.requests[req.rid] = req

    def submit(self, req: Request) -> Request:
        self._validate_request(req)
        self._register(req)
        req.submitted_tick = self.ticks
        self.queue.append(req)
        return req

    def _deadline_of(self, req: Request) -> int | None:
        """Effective deadline in ticks from submission (request override,
        else the config default).  A request submitted at tick t is
        expired once ``self.ticks > t + deadline`` — it had ``deadline``
        full ticks of service."""
        return (req.deadline_ticks if req.deadline_ticks is not None
                else self.scfg.deadline_ticks)

    def _expired(self, req: Request) -> bool:
        ddl = self._deadline_of(req)
        return ddl is not None and self.ticks > req.submitted_tick + ddl

    def _match_prefix(self, tokens: np.ndarray,
                      keep_last: bool = True) -> list[int]:
        """Longest chain of already-cached full blocks of ``tokens`` (refs
        taken).  With ``keep_last`` at least one token is always left to
        prefill — its forward produces the logits that sample the first new
        token.  A resumed request passes ``keep_last=False``: its next
        input token is already known (``generated[-1]``), so a fully-cached
        context needs no prefill forward at all."""
        bs = self._page
        matched: list[int] = []
        for j in range((len(tokens) - (1 if keep_last else 0)) // bs):
            key = tuple(int(t) for t in tokens[:(j + 1) * bs])
            bid = self.pool.lookup(key)
            if bid is None:
                break
            matched.append(bid)
        return matched

    def _reserve_goal(self, total: int, n_ctx: int) -> int:
        """Blocks admission must secure.  Default: the worst case, so
        mid-decode allocation can never fail.  Oversubscribed: just the
        context blocks plus one decode block — enough to prefill and make
        decode progress; further blocks are claimed unreserved and may
        preempt a victim when the pool runs dry."""
        if not self.scfg.oversubscribe:
            return total
        return min(total, n_ctx + 1)

    def _admit(self) -> None:
        while self.queue and None in self.slots:
            req = self.queue[0]
            # Deadline expiry in queue: a request that already produced
            # tokens (a preemption victim awaiting resume) *finishes
            # truncated* — its emitted tokens are a prefix of the
            # undisturbed stream, never divergent; a request with nothing
            # emitted yet is shed outright (reject-with-reason).
            if self._expired(req):
                self.queue.popleft()
                req.finished_step = self._step
                if req.generated:
                    req.deadline_hit = True
                    self.counters["deadline_truncated"] += 1
                    self.counters["requests_finished"] += 1
                else:
                    req.shed_reason = "deadline"
                    self.counters["requests_shed"] += 1
                    self.counters["shed_deadline"] += 1
                continue
            # Load shedding: past the saturation watermark, besteffort
            # requests that never started are rejected instead of queued
            # into a preemption storm.  Started requests are never shed —
            # shedding is lossy only for work with zero sunk cost.
            if (self.scfg.shed_watermark is not None
                    and req.slo == "besteffort" and not req.generated
                    and self.pool.saturation() > self.scfg.shed_watermark):
                self.queue.popleft()
                req.finished_step = self._step
                req.shed_reason = "watermark"
                self.counters["requests_shed"] += 1
                self.counters["shed_watermark"] += 1
                continue
            resumed = len(req.generated) > 0
            # Resume context: everything already cached at preemption time
            # — the prompt plus all generated tokens but the last (which is
            # the next decode input, never written back yet).
            ctx = (np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.generated[:-1], np.int32)])
                   if resumed else np.asarray(req.prompt, np.int32))
            Lc = len(ctx)
            total = self._blocks_for(req)
            n_ctx = -(-Lc // self._page)
            goal = self._reserve_goal(total, n_ctx)
            # Cheap pre-check before building O(L^2/page) prefix keys: if
            # even a full prefix match couldn't fit, the head of line is
            # blocked — don't churn the registry every tick.
            max_match = (Lc - (0 if resumed else 1)) // self._page
            if goal - max_match > self.pool.available():
                break
            matched = self._match_prefix(ctx, keep_last=not resumed)
            need = goal - len(matched)
            if need > self.pool.available():
                # Head-of-line blocked on capacity: roll the prefix refs
                # back and wait for evictions to return blocks.
                for bid in matched:
                    self.pool.decref(bid)
                break
            self.queue.popleft()
            self.pool.reserve(need)
            slot = self.slots.index(None)
            row = np.zeros((self._mb,), np.int32)
            row[:len(matched)] = matched
            # Blocks covering the un-shared context tail are claimed now;
            # decode-tail blocks stay reserved (or, oversubscribed, unmet)
            # and materialize lazily.
            for j in range(len(matched), n_ctx):
                row[j] = self.pool.alloc(reserved=True)
            hit_len = len(matched) * self._page
            # Fill freshly claimed context blocks from the memory
            # hierarchy (host swap record, then prefix store) instead of
            # recomputing them — a no-op when no tier is configured.
            cached_len = self._rehydrate(req, row, ctx, len(matched),
                                         hit_len, resumed)
            self.table[slot] = row
            self.lengths[slot] = cached_len
            self.slots[slot] = _PagedSlot(
                req, next_prefill=cached_len,
                blocks_reserved=goal - n_ctx,
                ctx=ctx, resumed=resumed, seq=self._admit_seq)
            self._admit_seq += 1
            if cached_len < Lc:
                self._prefill_fifo.append(slot)
            else:
                # Fully-cached resume (every ctx block resurrected from the
                # registry): no prefill forward needed — decode continues
                # from the already-sampled last token.
                self.last_token[slot] = int(req.generated[-1])
            if not resumed:
                req.prefill_len = Lc
                req.admitted_step = self._step
            self.counters["prefix_hit_tokens"] += hit_len

    def _prefill_tick(self) -> None:
        """Run ONE bucket-padded chunk of the oldest admitted-but-unprefilled
        request — long prompts no longer monopolize a scheduler tick.  A
        resumed (previously preempted) request prefills its *context* —
        prompt plus already-generated tokens — through the identical path:
        recompute of the unshared tail is just more chunked prefill."""
        if not self._prefill_fifo:
            return
        slot = self._prefill_fifo[0]
        st = self.slots[slot]
        req = st.req
        L = len(st.ctx)
        s = st.next_prefill
        e = min(s + self._chunk, L)
        n = e - s
        Sp = min(self._chunk, -(-n // self.scfg.prefill_bucket)
                 * self.scfg.prefill_bucket)
        tokens = np.zeros((1, Sp), np.int32)
        tokens[0, :n] = np.asarray(st.ctx[s:e], np.int32)
        positions = np.full((1, Sp), POS_SENTINEL, np.int32)
        positions[0, :n] = np.arange(s, e, dtype=np.int32)

        caches = _attach_tables(self.caches, self.table[slot:slot + 1],
                                self.lengths[slot:slot + 1])
        last_logits, self.caches = self._prefill(
            self.params, jnp.asarray(tokens), caches, jnp.asarray(positions),
            jnp.asarray(n - 1, jnp.int32))
        self.lengths[slot] += n
        st.next_prefill = e
        self.counters["prefill_tokens"] += n
        self.counters["prefill_chunks"] += 1

        # Publish newly completed full context blocks for prefix sharing
        # (re-registration of already-shared blocks is a no-op).  Keys are
        # the full token chain, so generated-region blocks of a resumed
        # request share exactly like prompt blocks — a second preemption
        # resumes them for free.
        bs = self._page
        for j in range(s // bs, e // bs):
            key = tuple(int(t) for t in st.ctx[:(j + 1) * bs])
            self.pool.register(key, int(self.table[slot, j]))

        if e == L:
            self._prefill_fifo.popleft()
            if st.resumed:
                # The context's successor token was already sampled before
                # the preemption — replay it as the next decode input
                # instead of re-sampling (the logits are not consumed, so
                # the resumed trace stays bit-identical).
                self.last_token[slot] = int(req.generated[-1])
            else:
                tok = int(self._sample_rows(last_logits, [req.rid], [0])[0])
                req.generated.append(tok)
                self.last_token[slot] = tok
                self._maybe_evict(slot, tok)

    def _maybe_evict(self, slot: int, tok: int) -> None:
        st = self.slots[slot]
        if st is None:
            return
        req = st.req
        done = len(req.generated) >= req.max_new_tokens
        if self.scfg.eos_id is not None and tok == self.scfg.eos_id:
            done = True
        # Mid-decode deadline: finish truncated after this tick's token.
        # Truncation only ever *shortens* the stream — the emitted tokens
        # are exactly the undisturbed stream's prefix.
        if not done and self._expired(req):
            req.deadline_hit = True
            self.counters["deadline_truncated"] += 1
            done = True
        if not done:
            return
        req.finished_step = self._step
        self.counters["requests_finished"] += 1
        for j in range(self._mb):
            bid = int(self.table[slot, j])
            if bid:
                self.pool.decref(bid)
        self.pool.cancel_reservation(st.blocks_reserved)
        self.table[slot] = 0
        self.lengths[slot] = 0
        self.last_token[slot] = 0
        self.slots[slot] = None

    def step(self) -> bool:
        """One scheduler tick: admit, one prefill chunk, one decode step
        (plain or speculative) over every prefilled slot.  Returns False
        when there is no work."""
        # The tick counter is the engine's only clock: fault injection,
        # deadlines, and snapshot cadence all key on it (wall clock is
        # lint-banned from serving/ — repo-tick-wallclock).
        self.ticks += 1
        self._admit()
        self._prefill_tick()
        active = [i for i, st in enumerate(self.slots)
                  if st is not None and st.prefilled()]
        if not active:
            return bool(self.queue
                        or any(st is not None for st in self.slots))
        self._step += 1
        # Materialize the block behind each decoding row's next write
        # position up front: under oversubscription this claim may preempt
        # a victim — possibly one of this tick's own rows, which then drops
        # out of `active` (it is requeued, not lost).  Mandatory claims
        # happen here, before any speculative drafting, so a spec tick
        # never preempts for optional draft blocks.
        for i in active:
            st = self.slots[i]
            if st is None or not st.prefilled():
                continue                      # preempted by an earlier claim
            j = int(self.lengths[i]) // self._page
            if self.table[i, j] == 0:
                self._claim_block(i, j)
        active = [i for i in active if self.slots[i] is not None
                  and self.slots[i].prefilled()]
        if not active:
            return True
        if self._drafter is not None:
            self._spec_decode_tick(active)
        else:
            self._plain_decode_tick(active)
        return True

    # ------------------------------------------------------------------
    # JetStream-style engine API: prefill -> insert -> generate_step
    # (serving/frontdoor builds the async door and the prefill/decode
    # disaggregation on exactly this surface; docs/serving.md)
    # ------------------------------------------------------------------

    def prefill(self, req: Request) -> Prefix:
        """Engine API step 1: prefill a fresh request's prompt to
        completion and hand back a :class:`Prefix` — the prompt's paged
        blocks (ownership transferred, refs held by the Prefix) plus the
        first sampled token.  Runs through the ordinary chunked-prefill
        path (prefix-registry CoW hits, block publication, the standard
        first-token sample), so a later ``insert()`` + decode is
        bit-identical to serving the request through ``submit()``.

        The slot used for prefilling frees on return; only the blocks
        stay live.  Raises :class:`InsufficientBlocks` (retryable) when
        the pool cannot cover the prompt right now."""
        if req.generated:
            raise ValueError(
                "prefill() takes a fresh request; preemption resume runs "
                "through the scheduler (submit()/step())")
        self._validate_request(req)
        if None not in self.slots:
            raise RuntimeError("prefill() needs a free slot")
        self._register(req)
        req.submitted_tick = self.ticks
        ctx = np.asarray(req.prompt, np.int32)
        L = len(ctx)
        n_ctx = -(-L // self._page)
        matched = self._match_prefix(ctx, keep_last=True)
        need = n_ctx - len(matched)
        if need > self.pool.available():
            for bid in matched:
                self.pool.decref(bid)
            raise InsufficientBlocks(
                f"prompt needs {need} blocks beyond its prefix hits, pool "
                f"has {self.pool.available()}")
        self.pool.reserve(need)
        slot = self.slots.index(None)
        row = np.zeros((self._mb,), np.int32)
        row[:len(matched)] = matched
        for j in range(len(matched), n_ctx):
            row[j] = self.pool.alloc(reserved=True)
        cached = len(matched) * self._page
        self.table[slot] = row
        self.lengths[slot] = cached
        # blocks_reserved=0: prefill writes only context blocks, all
        # allocated above — the decode tail is reserved at insert() time
        # against the DECODE engine's pool.
        self.slots[slot] = _PagedSlot(req, next_prefill=cached,
                                      blocks_reserved=0, ctx=ctx,
                                      seq=self._admit_seq)
        self._admit_seq += 1
        req.prefill_len = L
        req.admitted_step = self._step
        self.counters["prefix_hit_tokens"] += cached
        # keep_last guarantees >= 1 token left to prefill, so the loop
        # always runs and the first token samples through _prefill_tick.
        self._prefill_fifo.appendleft(slot)
        while (self.slots[slot] is not None
               and not self.slots[slot].prefilled()):
            self.ticks += 1
            self._prefill_tick()
        if self.slots[slot] is None:
            # Finished during prefill (max_new_tokens == 1, eos, or a
            # deadline): _maybe_evict released every block already and the
            # tokens are in req.generated — nothing to hand off.
            last = int(req.generated[-1]) if req.generated else 0
            return Prefix(req=req, chain=ctx, length=0, last_token=last,
                          blocks=[], pool=self.pool, finished=True)
        # Detach: block ownership moves from the slot to the Prefix (the
        # refs taken above are NOT dropped); the slot frees.
        bids = [int(self.table[slot, j]) for j in range(n_ctx)]
        last = int(self.last_token[slot])
        self.table[slot] = 0
        self.lengths[slot] = 0
        self.last_token[slot] = 0
        self.slots[slot] = None
        self.counters["prefixes_prefilled"] += 1
        return Prefix(req=req, chain=ctx, length=L, last_token=last,
                      blocks=bids, pool=self.pool)

    def extract(self, prefix: Prefix) -> Prefix:
        """Detach a prefix from this engine: serialize its blocks' K/V/pos
        rows (plus the pool-wide quant scales) through the pool to host
        arrays, then drop the block refs.  The result is pool-layout
        independent — a decode engine with its own pool and block
        numbering can ``insert()`` it: the disaggregation handoff.
        Registered source blocks park in the LRU on decref, so the
        prefill engine's prefix cache stays warm for repeat prompts."""
        if prefix.finished or prefix.payload is not None:
            return prefix
        if prefix.pool is not self.pool:
            raise ValueError(
                "extract() must run on the engine owning the prefix")
        layers = extract_block_rows(self.caches, prefix.blocks)
        amax = [np.asarray(a, np.float32) for a in _amax_leaves(self.caches)]
        for bid in prefix.blocks:
            self.pool.decref(bid)
        self.counters["prefix_transfers"] += 1
        return dataclasses.replace(prefix, blocks=[], pool=None,
                                   payload={"layers": layers, "amax": amax})

    def release(self, prefix: Prefix) -> None:
        """Drop an attached prefix without inserting it (client went away
        between prefill and insert).  Detached/finished prefixes hold no
        pool state — nothing to do."""
        if prefix.pool is not self.pool or not prefix.blocks:
            return
        for bid in prefix.blocks:
            self.pool.decref(bid)
        prefix.blocks = []
        prefix.pool = None

    def insert(self, prefix: Prefix, slot: int) -> None:
        """Engine API step 2: mount a prefilled context into a free slot
        and arm it for decode.  Attached (same-pool) prefixes splice by
        block handle — no KV moves; detached ones CoW-match against this
        pool's own registry first and scatter only unmatched blocks from
        the payload, merging the source's quant scales (elementwise max —
        amax is monotone, so the merged grid is the union trajectory) and
        rebuilding the packed plane pools so every resident page means
        the same integers under it.

        The slot state is exactly the post-preemption resume contract
        (``resumed=True``, next decode input = ``prefix.last_token``), so
        decode, speculation, oversubscription and deadlines behave as if
        the request had always lived here."""
        if not 0 <= slot < len(self.slots):
            raise ValueError(f"slot {slot} out of range "
                             f"[0, {len(self.slots)})")
        if self.slots[slot] is not None:
            raise RuntimeError(
                f"insert into occupied slot {slot} (rid "
                f"{self.slots[slot].req.rid} is live there)")
        if prefix.finished:
            raise ValueError(
                "prefix finished during prefill — nothing to insert")
        req = prefix.req
        if req.rid < 0:
            raise ValueError("prefix carries an unregistered request")
        chain = np.asarray(prefix.chain, np.int32)
        n_ctx = -(-prefix.length // self._page)
        total = self._blocks_for(req)
        goal = self._reserve_goal(total, n_ctx)
        if prefix.pool is self.pool:
            # Attached handoff: a pure block-table splice — the refs taken
            # at prefill() transfer to this slot.
            need = goal - n_ctx
            if need > self.pool.available():
                raise InsufficientBlocks(
                    f"decode tail needs {need} reserved blocks, pool has "
                    f"{self.pool.available()}")
            self.pool.reserve(need)
            row_bids = [int(b) for b in prefix.blocks]
            prefix.blocks = []
            prefix.pool = None
            cached_hit = 0
        else:
            if prefix.payload is None:
                raise ValueError(
                    "cross-engine insert needs a detached prefix: call "
                    "extract() on the source engine first")
            matched = self._match_prefix(chain, keep_last=False)
            need = goal - len(matched)
            if need > self.pool.available():
                for bid in matched:
                    self.pool.decref(bid)
                raise InsufficientBlocks(
                    f"prefix needs {need} blocks beyond its local CoW "
                    f"hits, pool has {self.pool.available()}")
            self.pool.reserve(need)
            sel = list(range(len(matched), n_ctx))
            fresh = [self.pool.alloc(reserved=True) for _ in sel]
            row_bids = [int(b) for b in matched] + fresh
            if fresh:
                self.caches = splice_block_rows(
                    self.caches, fresh, prefix.payload["layers"], sel)
            self._merge_amax(prefix.payload["amax"])
            # Publish transferred FULL blocks for CoW under their chain
            # keys (the partial tail block stays exclusively owned and
            # unregistered — repo invariant).
            for j in range(len(matched), prefix.length // self._page):
                key = tuple(int(t) for t in chain[:(j + 1) * self._page])
                self.pool.register(key, row_bids[j])
            if self._rules is not None:
                from repro.sharding.rules import cache_shardings
                self.caches = jax.device_put(
                    self.caches, cache_shardings(self._rules, self.caches))
            cached_hit = len(matched) * self._page
        self._register(req)
        # Deadlines re-anchor at insert: in disaggregated mode the
        # prefill and decode engines' tick clocks are unrelated, so
        # ``deadline_ticks`` bounds decode-side service from here.
        req.submitted_tick = self.ticks
        row = np.zeros((self._mb,), np.int32)
        row[:n_ctx] = row_bids
        self.table[slot] = row
        self.lengths[slot] = prefix.length
        self.last_token[slot] = int(prefix.last_token)
        self.slots[slot] = _PagedSlot(req, next_prefill=prefix.length,
                                      blocks_reserved=goal - n_ctx,
                                      ctx=chain, resumed=True,
                                      seq=self._admit_seq)
        self._admit_seq += 1
        self.counters["prefix_hit_tokens"] += cached_hit
        self.counters["prefixes_inserted"] += 1

    def _merge_amax(self, incoming: list) -> None:
        """Fold another engine's quant-scale leaves into this one's
        (elementwise max) and rebuild the packed plane pools.  Runs on
        every detached insert even when nothing grew: the freshly
        spliced pages carry no plane rows until the requant writes
        them."""
        cur = _amax_leaves(self.caches)
        if len(cur) != len(incoming):
            raise ValueError(
                f"prefix payload carries {len(incoming)} quant-scale "
                f"leaves, cache has {len(cur)}")
        if not cur:
            return
        merged = []
        for c, p in zip(cur, incoming):
            cn = np.asarray(c, np.float32)
            merged.append(np.maximum(cn,
                                     np.asarray(p,
                                                np.float32).reshape(cn.shape)))
        self.caches = _set_amax_leaves(self.caches, merged)
        self.caches = requant_plane_pools(self.caches)

    def generate_step(self) -> list[dict]:
        """Engine API step 3: one scheduler tick, returning the tokens it
        committed as per-request events ``{"rid", "slot", "tokens",
        "finished"}`` (sorted by rid; ``slot`` is -1 once the request has
        left its slot).  A preemption emits no event — the requeued
        request's tokens stand; an expiry/shed emits a terminal event
        with no tokens.  Token content is exactly ``step()``'s: this is a
        diff of the request registry, not a different decode path."""
        before = {rid: (len(r.generated),
                        r.finished_step >= 0 or r.shed_reason is not None)
                  for rid, r in self.requests.items()}
        self.step()
        slot_of = {st.req.rid: i for i, st in enumerate(self.slots)
                   if st is not None}
        events = []
        for rid in sorted(before):
            n0, was_done = before[rid]
            req = self.requests[rid]
            done = req.finished_step >= 0 or req.shed_reason is not None
            toks = [int(t) for t in req.generated[n0:]]
            if toks or (done and not was_done):
                events.append({"rid": rid, "slot": slot_of.get(rid, -1),
                               "tokens": toks, "finished": done})
        return events

    def free_slots(self) -> list[int]:
        """Indices of currently unoccupied slots (insert targets)."""
        return [i for i, st in enumerate(self.slots) if st is None]

    # ------------------------------------------------------------------
    # crash-consistent snapshot / restore (docs/robustness.md)
    # ------------------------------------------------------------------

    def snapshot(self) -> dict:
        """Crash-consistent engine state, fully JSON-serializable.

        Persists every piece of host-side truth: the request registry
        (prompts, generated tokens, QoS/accounting fields), queue and slot
        occupancy, scheduler counters, tick counter, sampling seed, the
        pool's allocator state (free list, refcounts, registry,
        reservations — for fidelity and offline inspection), and the
        pool-wide quant-scale leaves (``k_amax``/``v_amax``).

        Deliberately NOT persisted: device KV.  Restore re-materializes it
        through the PR-5 lossless-resume path — in-flight requests requeue
        with their generated tokens and recompute their context via
        chunked prefill (re-sharing prefix blocks across each other as
        they go), which is bit-identical because K/V written for (token,
        position) is schedule-invariant and the restored quant scales
        make the recompute's rescale trajectory match the undisturbed
        run's exactly."""
        active = sorted(
            (st.seq, st.req.rid) for st in self.slots if st is not None)
        reqs = []
        for rid in sorted(self.requests):
            r = self.requests[rid]
            reqs.append({
                "rid": rid,
                "prompt": [int(t) for t in r.prompt],
                "max_new_tokens": int(r.max_new_tokens),
                "generated": [int(t) for t in r.generated],
                "deadline_ticks": r.deadline_ticks,
                "slo": r.slo,
                "prefill_len": int(r.prefill_len),
                "admitted_step": int(r.admitted_step),
                "finished_step": int(r.finished_step),
                "preemptions": int(r.preemptions),
                "submitted_tick": int(r.submitted_tick),
                "shed_reason": r.shed_reason,
                "deadline_hit": bool(r.deadline_hit),
            })
        return {
            "version": 1,
            "ticks": int(self.ticks),
            "step": int(self._step),
            "seed": self._seed,
            "next_rid": int(self._next_rid),
            "admit_seq": int(self._admit_seq),
            "counters": {k: int(v) for k, v in self.counters.items()},
            "requests": reqs,
            "queue": [r.rid for r in self.queue],
            "active": [rid for _, rid in active],
            "amax": [np.asarray(a, np.float32).tolist()
                     for a in _amax_leaves(self.caches)],
            "pool": self.pool.snapshot(),
        }

    def restore(self, state: dict) -> None:
        """Rebuild scheduler truth from a snapshot into THIS engine, which
        must be freshly constructed (a host crash destroyed the old
        process — device KV included — so restore starts from a clean pool
        and empty caches, not from snapshot-era block ids).

        In-flight requests requeue exactly like preemption victims
        (admission order first, then the snapshot's queue order): the
        ordinary ``_admit`` resume path recomputes each context and
        replays the last sampled token, so the continuation's tokens are
        bit-identical to an undisturbed run.  The quant-scale leaves are
        written back *before* any recompute — see :func:`_set_amax_leaves`
        for why that pins the BitStopper scale trajectory."""
        if self.requests or self.ticks or self.pool.live_blocks():
            raise RuntimeError(
                "restore() needs a freshly constructed engine (the crash "
                "destroyed the old one; device KV is recomputed, not "
                "re-mapped)")
        if state.get("version") != 1:
            raise ValueError(f"unknown snapshot version "
                             f"{state.get('version')!r}")
        self.ticks = int(state["ticks"])
        self._step = int(state["step"])
        self._next_rid = int(state["next_rid"])
        self._admit_seq = int(state["admit_seq"])
        if state["seed"] is not None:
            self.begin(int(state["seed"]))
        self.counters.update(state["counters"])
        # Pool bookkeeping counters carry across the crash so benchmark
        # accounting stays cumulative; the allocator itself restarts empty
        # (every restored block is re-claimed through the resume path).
        self.pool.peak_live_blocks = int(state["pool"]["peak_live_blocks"])
        self.pool.alloc_count = int(state["pool"]["alloc_count"])
        self.requests = {}
        for d in state["requests"]:
            self.requests[d["rid"]] = Request(
                prompt=np.asarray(d["prompt"], np.int32),
                max_new_tokens=d["max_new_tokens"],
                generated=list(d["generated"]),
                rid=d["rid"],
                deadline_ticks=d["deadline_ticks"],
                slo=d["slo"],
                prefill_len=d["prefill_len"],
                admitted_step=d["admitted_step"],
                finished_step=d["finished_step"],
                preemptions=d["preemptions"],
                submitted_tick=d["submitted_tick"],
                shed_reason=d["shed_reason"],
                deadline_hit=d["deadline_hit"],
            )
        self.caches = _set_amax_leaves(self.caches, state["amax"])
        if self._rules is not None:
            # Re-commit the restored leaves to their mesh placement: the
            # scale injection above rebuilt host-side arrays.
            from repro.sharding.rules import cache_shardings
            self.caches = jax.device_put(
                self.caches, cache_shardings(self._rules, self.caches))
        # Crash-time slot occupants re-admit first (they were admitted
        # before anything still queued), in admission order; then the
        # queue in its snapshot order.  ``_admit`` distinguishes fresh
        # vs resumed requests by ``len(generated)`` as usual.
        for rid in list(state["active"]) + list(state["queue"]):
            self.queue.append(self.requests[rid])

    # ------------------------------------------------------------------
    # KV memory hierarchy: swap-to-host preemption + persistent prefix
    # store (docs/serving.md "Memory hierarchy")
    # ------------------------------------------------------------------

    def _swap_out(self, slot: int, exclusive: list[int], L: int,
                  req: Request) -> None:
        """Device→host copy of a preemption victim's exclusively-owned
        blocks into the swap pool, keyed by rid.  The record carries the
        f32 K/V/pos rows, the packed ``kq`` plane rows, and the
        swap-time quant-scale leaves — enough for :meth:`_swap_in` to
        re-materialize by splice with zero recompute.  Any failure
        (injected swap_fail, a non-contiguous exclusive run, budget
        refusal) just skips the record: the recompute-resume path is
        always the correct fallback."""
        if (self.chaos is not None
                and self.chaos.fire("swap_fail", self.ticks)):
            # The device→host copy died mid-flight: the partial record
            # is discarded and the victim resumes by recompute.
            self.counters["swap_fallbacks"] += 1
            return
        excl = set(exclusive)
        n_used = -(-L // self._page)
        pairs = [(j, int(self.table[slot, j])) for j in range(n_used)
                 if int(self.table[slot, j]) in excl]
        if not pairs:
            return        # every token-bearing block is shared: resume
                          # re-maps them from the registry for free
        js = [j for j, _ in pairs]
        if js != list(range(js[0], n_used)):
            # Shared blocks interleaved past the first exclusive one —
            # the record could not splice to a contiguous tail.
            self.counters["swap_fallbacks"] += 1
            return
        bids = [b for _, b in pairs]
        layers = extract_block_rows(self.caches, bids, planes=True)
        amax = [np.asarray(a, np.float32) for a in _amax_leaves(self.caches)]
        rec = {"js": js, "length": int(L), "layers": layers, "amax": amax}
        nbytes = (sum(int(a.nbytes) for lay in layers for a in lay.values())
                  + sum(int(a.nbytes) for a in amax))
        if self._swap.put(req.rid, rec, nbytes):
            self.counters["swap_outs"] += 1
        else:
            self.counters["swap_fallbacks"] += 1

    def _rehydrate(self, req: Request, row: np.ndarray, ctx: np.ndarray,
                   m: int, cached_len: int, resumed: bool) -> int:
        """Admission-time hierarchy lookup: after the context blocks are
        claimed, try to fill them from a host swap record (exact resume),
        else from the host/disk prefix store.  Returns the new cached
        length (``cached_len`` unchanged when nothing applies)."""
        new_len = None
        if self._swap is not None:
            new_len = self._swap_in(req, row, ctx, m, resumed)
        if new_len is None and (self._prefix_host is not None
                                or self.scfg.prefix_store_dir is not None):
            new_len = self._store_inject(req, row, ctx, m, resumed)
        if new_len is None:
            return cached_len
        if self._rules is not None:
            # Re-commit: the host-side splice rebuilt pool leaves.
            from repro.sharding.rules import cache_shardings
            self.caches = jax.device_put(
                self.caches, cache_shardings(self._rules, self.caches))
        return new_len

    def _swap_in(self, req: Request, row: np.ndarray, ctx: np.ndarray,
                 m: int, resumed: bool) -> int | None:
        """Re-materialize a swapped-out victim by scattering its host
        record into the freshly claimed blocks.

        Bit-identity argument: every swapped value was previously written
        by THIS engine, so the current (monotone) quant scales already
        cover it — the recompute reference would trigger no scale growth,
        and the swap-in must not apply the scale rule at all.  For the
        packed planes: if no scale grew since swap-out, the stored ``kq``
        rows splice verbatim (they ARE what incremental maintenance
        holds); if a scale did grow, the reference's growth event
        whole-pool-requanted, so repacking just the spliced blocks under
        the current scales reproduces its bytes exactly."""
        rec = self._swap.take(req.rid)
        if rec is None:
            return None
        L, js = rec["length"], rec["js"]
        if not resumed or js[0] != m or L > len(ctx):
            # The registry shifted under the record (prefix blocks it
            # relied on were evicted), or the record predates a state
            # this admission no longer matches: recompute instead.
            self.counters["swap_fallbacks"] += 1
            return None
        bids = [int(row[j]) for j in range(m, m + len(js))]
        layers = rec["layers"]
        cur = _amax_leaves(self.caches)
        same_scales = (len(cur) == len(rec["amax"]) and all(
            np.array_equal(np.asarray(a, np.float32), b)
            for a, b in zip(cur, rec["amax"])))
        if same_scales:
            self.caches = splice_block_rows(self.caches, bids, layers)
        else:
            stripped = [{k: v for k, v in lay.items() if k != "kq"}
                        for lay in layers]
            self.caches = splice_block_rows(self.caches, bids, stripped)
            self.caches = repack_block_planes(self.caches, bids)
        # Registration parity with the recompute reference: full blocks
        # publish under their chain keys exactly as _prefill_tick would
        # have while recomputing [m*page, L).
        bs = self._page
        for j in range(m, L // bs):
            key = tuple(int(t) for t in ctx[:(j + 1) * bs])
            self.pool.register(key, int(row[j]))
        self.counters["swap_ins"] += 1
        self.counters["swap_in_tokens"] += L - m * bs
        return L

    def _store_inject(self, req: Request, row: np.ndarray, ctx: np.ndarray,
                      m: int, resumed: bool) -> int | None:
        """Warm a request's context from the prefix store: walk the chain
        of full context blocks past the device-registry match, fetching
        host-tier records then disk records, splice the covered rows and
        replay the quant-scale rule host-side with chunk-group boundaries
        exactly matching the chunked-prefill recompute reference
        (``docs/serving.md`` has the losslessness argument).  Injection
        stops at the largest chunk boundary fully covered by stored
        blocks; a fresh request always leaves >= 1 token to prefill (its
        forward samples the first new token)."""
        bs = self._page
        Lc = len(ctx)
        tier, sdir = self._prefix_host, self.scfg.prefix_store_dir
        recs = []
        j = m
        while (j + 1) * bs <= Lc:
            key = tuple(int(t) for t in ctx[:(j + 1) * bs])
            rec = None
            if tier is not None:
                got = tier.get(key)
                if got is not None:
                    rec = got["layers"]
            if rec is None and sdir is not None:
                from repro.checkpoint.store import load_prefix_record
                rec = load_prefix_record(sdir, key)
            if rec is None:
                break
            recs.append(rec)
            j += 1
        if not recs:
            return None
        base = m * bs
        cov = (m + len(recs)) * bs
        # Largest admissible chunk-group boundary e_k = min(base +
        # k*chunk, Lc) covered by the stored blocks; resumed requests may
        # reach Lc (zero prefill chunks), fresh ones must stop short.
        if resumed and cov >= Lc:
            inject_end = Lc
        else:
            hi = min(cov, Lc - 1)
            inject_end = base + ((hi - base) // self._chunk) * self._chunk
        if inject_end <= base:
            return None
        jend = -(-inject_end // bs)
        recs = recs[:jend - m]
        # Merge the per-block records into one extract_block_rows-shaped
        # layer list (rows axis: 1 for stacked layers, else 0; the pos
        # plane is 2 ranks slimmer than k/v).
        merged = []
        for li in range(len(recs[0])):
            merged.append({
                f: np.concatenate(
                    [np.asarray(r[li][f]) for r in recs],
                    axis=np.asarray(recs[0][li][f]).ndim
                    - (2 if f == "pos" else 4))
                for f in ("k", "v", "pos")})
        bids = [int(row[j]) for j in range(m, jend)]
        self.caches = splice_block_rows(self.caches, bids, merged)
        # Replay the scale rule per chunk group — the stored values may
        # be new to THIS engine (cold start), and growth is trajectory-
        # dependent, so the groups mirror the recompute chunks exactly.
        groups = []
        s = base
        while s < inject_end:
            e = min(s + self._chunk, inject_end)
            wins = []
            for jj in range(s // bs, -(-e // bs)):
                wins.append((jj - m, max(s, jj * bs) - jj * bs,
                             min(e, (jj + 1) * bs) - jj * bs))
            groups.append(wins)
            s = e
        self.caches, k_grew = apply_inject_amax_rule(self.caches, merged,
                                                     groups)
        if k_grew:
            # The reference's last growth event whole-pool-requants.
            self.caches = requant_plane_pools(self.caches)
        else:
            self.caches = repack_block_planes(self.caches, bids)
        for jj in range(m, inject_end // bs):
            key = tuple(int(t) for t in ctx[:(jj + 1) * bs])
            self.pool.register(key, int(row[jj]))
        self.counters["prefix_store_hits"] += len(recs)
        self.counters["prefix_store_tokens"] += inject_end - base
        return inject_end

    def _on_prefix_evict(self, key: tuple, bid: int) -> None:
        """KVBlockPool evict hook: a parked registered block is being
        stolen for reuse — copy its rows down the hierarchy (host tier,
        cascading to disk) before the new owner overwrites them."""
        if getattr(self, "caches", None) is None:
            return
        layers = extract_block_rows(self.caches, [bid])
        rec = {"chain": key, "layers": layers}
        nbytes = sum(int(a.nbytes) for lay in layers for a in lay.values())
        self.counters["prefix_spills"] += 1
        if self._prefix_host is not None:
            self._prefix_host.put(key, rec, nbytes)
        else:
            self._spill_prefix_record(key, rec, nbytes)

    def _spill_prefix_record(self, key, rec, nbytes) -> None:
        """Bottom of the cascade: persist a prefix record to the disk
        store (atomic stage-then-promote; an injected
        ``checkpoint_interrupt`` drops the record, leaving a GC-able
        staging orphan and the store's previous contents intact)."""
        sdir = self.scfg.prefix_store_dir
        if sdir is None:
            return
        from repro.checkpoint.store import save_prefix_record
        try:
            save_prefix_record(sdir, list(key), rec["layers"],
                               interrupt=self._store_interrupt)
        except CheckpointInterrupted:
            self.counters["prefix_store_interrupts"] += 1

    def _store_interrupt(self) -> None:
        if (self.chaos is not None
                and self.chaos.fire("checkpoint_interrupt", self.ticks)):
            raise CheckpointInterrupted(
                f"prefix-store write killed at tick {self.ticks}")

    def flush_prefixes(self) -> int:
        """Persist every registered prefix block (and every host-tier
        record) to the prefix store — the graceful-shutdown half of
        cross-restart warm starts.  First-writer-wins: chains already in
        the store are no-ops.  Returns the number of records written or
        confirmed present."""
        sdir = self.scfg.prefix_store_dir
        if sdir is None:
            raise RuntimeError(
                "flush_prefixes() needs ServeConfig.prefix_store_dir")
        from repro.checkpoint.store import save_prefix_record
        n = 0
        for key, bid in self.pool.registered_items():
            layers = extract_block_rows(self.caches, [bid])
            try:
                save_prefix_record(sdir, list(key), layers,
                                   interrupt=self._store_interrupt)
                n += 1
            except CheckpointInterrupted:
                self.counters["prefix_store_interrupts"] += 1
        if self._prefix_host is not None:
            for key, rec in self._prefix_host.items():
                try:
                    save_prefix_record(sdir, list(key), rec["layers"],
                                       interrupt=self._store_interrupt)
                    n += 1
                except CheckpointInterrupted:
                    self.counters["prefix_store_interrupts"] += 1
        return n

    def memory_report(self) -> dict:
        """Bytes resident at every tier of the KV memory hierarchy.
        :meth:`kv_bytes_resident` stays device-only by contract; host and
        disk tiers report separately so no token's bytes are ever
        double-counted across tiers (the sanitizer cross-checks each
        host tier's internal ledger)."""
        rep = {
            "device_bytes": int(self.kv_bytes_resident(peak=False)),
            "device_bytes_peak": int(self.kv_bytes_resident(peak=True)),
            "host_swap_bytes": (int(self._swap.bytes_used)
                                if self._swap is not None else 0),
            "host_swap_bytes_peak": (int(self._swap.peak_bytes)
                                     if self._swap is not None else 0),
            "host_prefix_bytes": (int(self._prefix_host.bytes_used)
                                  if self._prefix_host is not None else 0),
            "host_prefix_bytes_peak": (int(self._prefix_host.peak_bytes)
                                       if self._prefix_host is not None
                                       else 0),
            "disk_prefix_bytes": 0,
        }
        if self.scfg.prefix_store_dir is not None:
            from repro.checkpoint.store import prefix_store_bytes
            rep["disk_prefix_bytes"] = int(
                prefix_store_bytes(self.scfg.prefix_store_dir))
        return rep

    # ------------------------------------------------------------------
    # oversubscription: victim preemption + lossless requeue
    # ------------------------------------------------------------------

    def _freeable_blocks(self, slot: int) -> int:
        """Pool capacity preempting this slot would release: exclusively-
        held table entries (refcount-1 blocks free outright or park in the
        evictable LRU) plus its un-materialized reservation units.  Entries
        another table also maps (refcount > 1) only drop a reference."""
        st = self.slots[slot]
        n = st.blocks_reserved
        for j in range(self._mb):
            bid = int(self.table[slot, j])
            if bid and self.pool.refcount(bid) == 1:
                n += 1
        return n

    _SLO_RANK = {"besteffort": 0, "standard": 1, "strict": 2}

    def _select_victim(self, needy: int) -> int | None:
        """Pick the slot to preempt so ``needy`` can claim a block.

        SLO class and deadline slack dominate: besteffort slots are
        victimized before standard before strict, and within a class the
        request with the MOST ticks of deadline slack is victimized first
        (it can best afford the resume recompute).  With neither SLO
        classes nor deadlines in play those keys are constant and the
        policy reduces to its pre-QoS behavior: ``fewest_tokens``
        victimizes the request with the least generated output (cheapest
        recompute, closest to vLLM's default); ``lifo`` victimizes the
        newest admission (oldest requests never starve).  Slots whose
        preemption would free nothing are never chosen."""
        cands = [i for i, st in enumerate(self.slots)
                 if st is not None and i != needy
                 and self._freeable_blocks(i) > 0]
        if not cands:
            return None

        def vkey(i):
            st = self.slots[i]
            req = st.req
            ddl = self._deadline_of(req)
            slack = (float("inf") if ddl is None
                     else req.submitted_tick + ddl - self.ticks)
            if self.scfg.preempt_policy == "lifo":
                pol = (-st.seq,)
            else:
                pol = (len(req.generated), -st.seq)
            return (self._SLO_RANK.get(req.slo, 1), -slack) + pol

        return min(cands, key=vkey)

    def _preempt(self, slot: int) -> None:
        """Evict a running request to reclaim its blocks, requeueing it for
        a lossless resume.  Exclusively-owned blocks free outright
        (``KVBlockPool.preempt``); shared/registered prefix blocks drop one
        reference — they stay live under other tables or park resurrectable
        in the LRU, so the resume re-maps them for free and recomputes only
        the unshared tail via chunked prefill.  The request's ``generated``
        tokens are kept: sampling keys are a pure function of (seed, rid,
        token index), so the resumed continuation is bit-identical to an
        uncontended run."""
        st = self.slots[slot]
        req = st.req
        L = int(self.lengths[slot])
        exclusive, shared, dropped = [], [], 0
        for j in range(self._mb):
            bid = int(self.table[slot, j])
            if not bid:
                continue
            if (self.pool.refcount(bid) == 1
                    and not self.pool.is_registered(bid)):
                exclusive.append(bid)
                # Only tokens in forcibly-freed blocks are dropped from
                # cache; tokens in shared/registered blocks stay resident
                # (or parked) and re-map for free on resume.
                dropped += max(0, min(L - j * self._page, self._page))
            else:
                shared.append(bid)
        # Swap-to-host: copy the victim's exclusive blocks to a host
        # record BEFORE they free (and before the sanitizer poisons
        # them), so resume can splice instead of recompute.  Victims
        # still mid-first-prefill (nothing generated) resume as fresh
        # admissions and need no record.
        if self._swap is not None and req.generated and exclusive:
            self._swap_out(slot, exclusive, L, req)
        self.pool.preempt(exclusive)
        for bid in shared:
            self.pool.decref(bid)
        self.pool.cancel_reservation(st.blocks_reserved)
        self.table[slot] = 0
        self.counters["preempt_dropped_tokens"] += dropped
        self.lengths[slot] = 0
        self.last_token[slot] = 0
        self.slots[slot] = None
        if slot in self._prefill_fifo:
            self._prefill_fifo.remove(slot)
        req.preemptions += 1
        self.counters["preemptions"] += 1
        self.counters["preempt_freed_blocks"] += len(exclusive)
        # Preempted requests resume ahead of never-admitted arrivals (they
        # were admitted first), ordered by submission among themselves —
        # a later-preempted request must not jump an earlier one already
        # waiting at the head.
        pos = 0
        for r in self.queue:
            if r.preemptions > 0 and r.rid < req.rid:
                pos += 1
            else:
                break
        self.queue.insert(pos, req)

    def _claim_block(self, slot: int, j: int, optional: bool = False) -> int:
        """Materialize the physical block behind table entry j — out of the
        slot's admission reservation when one remains, else (oversubscribed
        admission only) from the pool's spare capacity, preempting victims
        until a block is claimable.

        ``optional`` marks a speculative draft-block claim: those never
        preempt (the caller pre-checks spare capacity and truncates the
        draft when there is none), so the injected pool-dry consult is
        skipped here — the spec tick consults it itself and answers with a
        draft truncation, exactly what real dryness does at that point."""
        st = self.slots[slot]
        if st.blocks_reserved > 0:
            bid = self.pool.alloc(reserved=True)
            st.blocks_reserved -= 1
        else:
            if not self.scfg.oversubscribe:
                raise RuntimeError(
                    "paged scheduler invariant violated: slot "
                    f"{slot} needs a decode block but has no reservation")
            # Injected pool-dry (serving/chaos.py): force one preemption
            # cycle even though the pool is not actually exhausted —
            # exercises the lossless preempt/resume machinery at scripted
            # points.  If no victim exists the forced dryness is dropped
            # rather than wedging a healthy pool.
            if (not optional and self.chaos is not None
                    and self.chaos.fire("pool_dry", self.ticks)):
                victim = self._select_victim(needy=slot)
                if victim is not None:
                    self._preempt(victim)
                    self.counters["forced_preemptions"] += 1
            while self.pool.available() < 1:
                victim = self._select_victim(needy=slot)
                if victim is None:
                    raise RuntimeError(
                        "oversubscribed pool wedged: no preemptable victim "
                        f"can free a block for slot {slot}")
                self._preempt(victim)
            bid = self.pool.alloc()
        self.table[slot, j] = bid
        return bid

    def _plain_decode_tick(self, active: list[int]) -> None:
        """One non-speculative decode step over every prefilled slot.

        Precondition: every active row's next-write block was already
        materialized by ``step()`` (also true on the speculative bailout
        replay — the mandatory claims precede the table snapshot and only
        optional draft blocks roll back).  Claiming here instead could
        preempt mid-tick, which the spec path must never do."""
        for i in active:
            assert self.table[i, int(self.lengths[i]) // self._page] != 0, \
                f"slot {i} reached decode without its next-write block"
        # Rows still prefilling (or empty) decode at the pad sentinel: their
        # q/k/v are zeroed and the cache write is dropped.
        positions = np.full((len(self.slots), 1), POS_SENTINEL, np.int32)
        for i in active:
            positions[i, 0] = self.lengths[i]
        tokens = jnp.asarray(self.last_token[:, None])
        caches = _attach_tables(self.caches, self.table, self.lengths)
        logits, self.caches = self._guarded_decode(
            self.params, tokens, caches, jnp.asarray(positions))
        rids = [st.req.rid if st is not None else 0 for st in self.slots]
        counts = [len(st.req.generated) if st is not None else 0
                  for st in self.slots]
        toks = self._sample_rows(logits, rids, counts)
        self.counters["decode_steps"] += 1
        self.counters["decode_slot_steps"] += len(self.slots)
        self.counters["decode_kv_tokens"] += sum(
            int(self.lengths[i]) + 1 for i in active)
        for i in active:
            req = self.slots[i].req
            req.generated.append(int(toks[i]))
            self.counters["decode_tokens"] += 1
            self.lengths[i] += 1
            self.last_token[i] = toks[i]
            self._maybe_evict(i, int(toks[i]))

    def _return_draft_blocks(self, slot: int,
                             blocks: list[tuple[int, int, bool]]) -> None:
        """Return unused speculative blocks to the pool the way they came:
        a block claimed from the slot's admission reservation rolls back
        WITH its reservation unit restored (and re-credited to the slot);
        a block claimed from oversubscribed *spare* capacity frees outright
        — re-reserving it would earmark shared spare capacity to this slot
        and push other slots into needless preemptions."""
        reserved = [bid for _, bid, r in blocks if r]
        spare = [bid for _, bid, r in blocks if not r]
        if reserved:
            self.pool.rollback(reserved)
            self.slots[slot].blocks_reserved += len(reserved)
        if spare:
            self.pool.rollback(spare, reserve=False)

    # ------------------------------------------------------------------
    # speculative decode: propose -> one Sq=k+1 verify -> accept/rollback
    # ------------------------------------------------------------------

    def _spec_decode_tick(self, active: list[int]) -> None:
        """One speculative decode step: draft, verify, accept, roll back.

        Losslessness argument, in scheduler terms: query i of slot b runs
        at absolute position ``lengths[b] + i`` against exactly the KV set
        (and quant scales — see the growth bailout) the non-speculative
        engine would have at that point, and its token is sampled under
        the same (seed, rid, token-index) key.  Token i+1 is only kept if
        draft i+1 *equals* the token the target just sampled, i.e. iff the
        non-speculative engine would have fed the same input — so the
        first divergence truncates acceptance and everything after it is
        rolled back untouched."""
        k = self._spec_k
        # Injected (or real) drafter death degrades the tick, never the
        # trace: an empty draft set falls through to the plain decode
        # below — speculation only ever changes forward count, so a dead
        # drafter costs throughput, not tokens.
        drafter_down = (self.chaos is not None
                        and self.chaos.fire("drafter_fail", self.ticks))
        if drafter_down:
            self.counters["drafter_failures"] += 1
        drafts: dict[int, list[int]] = {}
        for i in active:
            req = self.slots[i].req
            # A draft beyond the request's remaining budget could out-run
            # the admission reservation; cap so written positions stay
            # within the non-speculative worst case.
            cap = min(k, req.max_new_tokens - len(req.generated) - 1)
            if drafter_down or cap <= 0:
                drafts[i] = []
                continue
            ctx = np.concatenate([np.asarray(req.prompt, np.int32),
                                  np.asarray(req.generated, np.int32)])
            try:
                drafts[i] = [int(t)
                             for t in self._drafter.propose(ctx, cap)][:cap]
            except Exception:
                # A real drafter exception: pluggable drafters are allowed
                # to die without taking the engine down — this slot just
                # decodes plain this tick.
                self.counters["drafter_failures"] += 1
                drafts[i] = []
        if not any(drafts[i] for i in active):
            # Nothing proposed anywhere (cold n-gram cache, budget tails):
            # a verify pass would just be a slow plain tick.
            self._plain_decode_tick(active)
            return
        self.counters["spec_ticks"] += 1

        # Snapshot for the growth bailout: jax caches are immutable, so
        # keeping the references IS the device-state snapshot; the host
        # table is copied before speculative block materialization.
        caches_snap = self.caches
        table_snap = self.table.copy()

        Sq = k + 1
        B = len(self.slots)
        tokens = np.zeros((B, Sq), np.int32)
        positions = np.full((B, Sq), POS_SENTINEL, np.int32)
        # (table index, block, claimed-from-reservation) per slot — the
        # reservation flag decides how an unused block returns to the pool.
        new_blocks: dict[int, list[tuple[int, int, bool]]] = {}
        for i in active:
            st = self.slots[i]
            row = [int(self.last_token[i])] + drafts[i]
            base = int(self.lengths[i])
            new_blocks[i] = []
            # The row's first block was claimed in step(); blocks past it
            # are *optional* — they only hold draft tokens.  Claim them
            # from the slot's reservation or the pool's spare capacity,
            # NEVER by preemption (evicting a live request for tokens that
            # may be rejected is a losing trade): when the pool is tight
            # the draft is truncated to the blocks it could get.  An
            # injected pool-dry on an unreserved claim here takes the
            # real-dryness path — the draft truncates; forcing a
            # preemption at this point would evict an *active* slot in
            # the middle of its own speculative tick.
            for j in range(base // self._page + 1,
                           (base + len(row) - 1) // self._page + 1):
                if self.table[i, j] != 0:
                    continue
                reserved = st.blocks_reserved > 0
                forced_dry = (not reserved and self.chaos is not None
                              and self.chaos.fire("pool_dry", self.ticks))
                if not forced_dry and (
                        reserved or (self.scfg.oversubscribe
                                     and self.pool.available() >= 1)):
                    new_blocks[i].append(
                        (j, self._claim_block(i, j, optional=True),
                         reserved))
                else:
                    keep = j * self._page - base
                    row = row[:keep]
                    drafts[i] = drafts[i][:keep - 1]
                    break
            tokens[i, :len(row)] = row
            positions[i, :len(row)] = base + np.arange(len(row))

        caches = _attach_tables(self.caches, self.table, self.lengths)
        logits, new_caches, grew = self._guarded_verify(
            self.params, jnp.asarray(tokens), caches,
            jnp.asarray(positions))

        if bool(grew):
            # A draft-block token grew a pool-wide quant scale: earlier
            # queries were scored under a scale the non-speculative engine
            # would not have had yet.  Discard the whole speculative step
            # and replay it plain (which handles growth natively).
            self.caches = caches_snap
            self.table = table_snap
            for i in active:
                self._return_draft_blocks(i, new_blocks[i])
            self.counters["spec_bailouts"] += 1
            self._plain_decode_tick(active)
            return

        self.caches = new_caches
        # Sample every query position under its non-speculative key:
        # row (i, x) uses token index len(generated_i) + x.
        rids = np.zeros((B, Sq), np.int32)
        counts = np.zeros((B, Sq), np.int32)
        for i in active:
            rids[i, :] = self.slots[i].req.rid
            counts[i, :] = len(self.slots[i].req.generated) + np.arange(Sq)
        toks = self._sample_rows(logits.reshape(B * Sq, -1),
                                 rids.reshape(-1),
                                 counts.reshape(-1)).reshape(B, Sq)
        self.counters["decode_steps"] += 1
        self.counters["decode_slot_steps"] += len(self.slots)
        self.counters["decode_kv_tokens"] += sum(
            int(self.lengths[i]) + 1 + len(drafts[i]) for i in active)

        for i in active:
            st = self.slots[i]
            req = st.req
            d = drafts[i]
            t = toks[i]
            a = 0
            while a < len(d) and d[a] == int(t[a]):
                a += 1
            emitted = [int(t[x]) for x in range(a + 1)]
            if self.scfg.eos_id is not None and self.scfg.eos_id in emitted:
                emitted = emitted[:emitted.index(self.scfg.eos_id) + 1]
            emitted = emitted[:req.max_new_tokens - len(req.generated)]
            req.generated.extend(emitted)
            self.counters["decode_tokens"] += len(emitted)
            self.counters["spec_proposed"] += len(d)
            self.counters["spec_accepted"] += a
            self.lengths[i] += len(emitted)
            self.last_token[i] = emitted[-1]
            # Roll back the rejected tail: blocks whose every slot is past
            # the new fill level hold no live token — return them to the
            # pool and restore the reservation they were claimed from.
            # Only this tick's allocations can sit past the fill level,
            # so prompt/prefix-shared blocks are structurally out of reach
            # (kv_pool.rollback additionally enforces it).
            last_j = (int(self.lengths[i]) - 1) // self._page
            stale = [blk for blk in new_blocks[i] if blk[0] > last_j]
            for j, _, _ in stale:
                self.table[i, j] = 0
            self._return_draft_blocks(i, stale)
            self._maybe_evict(i, emitted[-1])


# Public name: the paged continuous batcher IS the serving engine.
ServingEngine = PagedEngine


class StaticBucketEngine:
    """The previous engine: one same-length batch at a time, re-padded per
    batch, shared cursor.  Kept as the baseline for
    ``benchmarks/serve_throughput.py`` and for A/B-ing the scheduler."""

    def __init__(self, cfg: ModelConfig, params,
                 scfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg

        def prefill_fn(params, tokens, caches):
            S = tokens.shape[1]
            logits, caches, _ = T.forward(params, tokens, cfg, caches=caches,
                                          positions=jnp.arange(S))
            return logits[:, -1], caches

        def decode_fn(params, token, caches, pos):
            logits, caches, _ = T.forward(
                params, token, cfg, caches=caches, positions=pos[None])
            return logits[:, -1], caches

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

    def init_caches(self, batch: int):
        dt = (jnp.bfloat16 if self.scfg.cache_dtype == "bfloat16"
              else jnp.float32)
        return T.init_caches(self.cfg, batch, self.scfg.max_len, dt)

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1)

    def generate(self, requests: list[Request], seed: int = 0):
        """Serve requests bucketed by prompt length, one batch at a time."""
        base_key = jax.random.PRNGKey(seed)
        buckets: dict[int, list[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        for bi, (_, batch) in enumerate(sorted(buckets.items())):
            self._generate_batch(batch, jax.random.fold_in(base_key, bi))
        return requests

    def _generate_batch(self, requests: list[Request], key):
        prompts = jnp.asarray(np.stack([r.prompt for r in requests]))
        B, S = prompts.shape
        caches = self.init_caches(B)
        logits, caches = self._prefill(self.params, prompts, caches)
        max_new = max(r.max_new_tokens for r in requests)
        token = self._sample(logits, jax.random.fold_in(key, 0))
        for r, t in zip(requests, np.asarray(token)):
            r.generated.append(int(t))
        for i in range(1, max_new):
            logits, caches = self._decode(
                self.params, token[:, None], caches,
                jnp.asarray(S + i - 1, jnp.int32))
            token = self._sample(logits, jax.random.fold_in(key, i))
            for r, t in zip(requests, np.asarray(token)):
                if len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(t))
        return requests


# ---------------------------------------------------------------------------
# measured sparsity of one prompt's prefill attention (layer 0)
# ---------------------------------------------------------------------------


def _prompt_sparsity(cfg: ModelConfig, params, prompt: np.ndarray):
    from repro.core.block_adaptation import block_bitstopper_attention
    from repro.models import layers as L
    from repro.models.attention import attention_block_shape

    x = L.embed(params["embed"], jnp.asarray(prompt)[None]).astype(
        cfg.activation_dtype)
    p0 = _first_attn_params(params, cfg)
    if p0 is None:
        return {}
    from repro.models.layers import linear, rope
    acfg = cfg.attn_config(False)
    pos = jnp.arange(x.shape[1])
    q = rope(linear(p0["wq"], x), pos[None], acfg.rope_theta)
    k = rope(linear(p0["wk"], x), pos[None], acfg.rope_theta)
    v = linear(p0["wv"], x)
    G = acfg.n_heads // acfg.n_kv_heads
    kr = jnp.repeat(k, G, axis=2).swapaxes(1, 2)
    vr = jnp.repeat(v, G, axis=2).swapaxes(1, 2)
    qt = q.swapaxes(1, 2)
    S = qt.shape[-2]
    # Small q-tiles: a kv block stops fetching planes only when EVERY
    # query row in the tile agrees, so tall tiles can't terminate.  The
    # same pad-to-tile-multiple rule as the serving forward path (public
    # helper) — padding is fully masked, and blocks with no unmasked pair
    # are excluded from the traffic means rather than counted as free.
    bq, pad_q = attention_block_shape(S, 8)
    bk, pad_k = attention_block_shape(S, 16)
    mask2d = jnp.tril(jnp.ones((S, S), bool))
    if pad_q or pad_k:
        mask2d = jnp.pad(mask2d, ((0, pad_q), (0, pad_k)))
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        kr = jnp.pad(kr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    res = block_bitstopper_attention(
        qt, kr, vr, cfg=cfg.bitstopper, block_q=bq, block_k=bk, mask=mask2d)
    rounds = np.asarray(res.stats.rounds_per_block, np.float64)
    alive = np.asarray(res.stats.block_alive)
    surv = np.asarray(res.stats.survivors)[..., :S, :S]
    n_qt, n_kb = rounds.shape[-2], rounds.shape[-1]
    live = np.asarray(mask2d).reshape(n_qt, bq, n_kb, bk).any((1, 3))
    live = np.broadcast_to(live, rounds.shape)
    return {
        "prompt_len": int(prompt.shape[0]),
        "mean_rounds": float(rounds[live].mean()),
        "plane_fraction": float(rounds[live].mean() / cfg.bitstopper.bits),
        "block_alive_fraction": float(alive[live].mean()),
        "survivor_fraction": float(surv.mean()),
        "n_blocks": int(live.sum()),
        "n_pairs": int(surv.size),
    }


def _first_attn_params(params, cfg: ModelConfig):
    for si, (unit, reps) in enumerate(cfg.segments):
        for i, spec in enumerate(unit):
            if spec.mixer in ("attn", "local_attn"):
                seg = params[f"seg{si}"]
                blk = seg[f"b{i}"] if isinstance(seg, dict) else seg[0][f"b{i}"]
                p = blk["attn"]
                if cfg.scan_layers and reps > 1 and isinstance(seg, dict):
                    p = jax.tree_util.tree_map(lambda a: a[0], p)
                return p
    return None
