"""Continuous-batching serving engine with decode-specialized BitStopper.

BitStopper is an *inference* accelerator: this engine is where the paper's
technique is deployed.  The scheduler is a continuous batcher (vLLM-style,
minus paging of individual blocks):

* a FIFO **request queue** with admission into a fixed set of decode
  **slots** — each slot is one row of a per-slot KV cache
  (``init_caches(..., per_slot=True)``: per-row write cursors and
  slot→position maps), so requests of *different* lengths share one decode
  batch without re-padding;
* **prefill/decode interleaving**: whenever a slot frees up the next queued
  request is prefilled (one bucketed-length forward) and its KV inserted
  into the freed slot, then joins the in-flight decode batch on the very
  next step;
* **eviction** on ``max_new_tokens`` or EOS frees the slot immediately.

Decode runs the single-query BitStopper fast path
(``besf_attention_decode``): all bit-plane contributions in one fused
integer contraction, per-round LATS logic reduced to elementwise ops.

Sampling is deterministic under a passed-in PRNG seed: every sampling event
uses ``fold_in(base_key, tick)`` — no hidden global state, and re-serving
the same trace with the same seed reproduces every token.

``sparsity_report()`` returns measured plane-fetch / survivor statistics
both aggregated and **per request**, feeding the Fig. 12/13 benchmarks
with served-traffic numbers.

``StaticBucketEngine`` preserves the previous static length-bucketed
batcher as the baseline that ``benchmarks/serve_throughput.py`` compares
against.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.attention import POS_SENTINEL
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512                # KV capacity per slot
    max_slots: int = 4                # concurrent decode batch width
    prefill_bucket: int = 16          # prompts pad up to a multiple of this
    temperature: float = 0.0          # 0 = greedy
    cache_dtype: str = "float32"
    eos_id: int | None = None         # optional early stop token


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                # [S] int32
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)
    rid: int = -1                     # assigned at submit()
    # per-request accounting, filled by the engine
    prefill_len: int = 0
    admitted_step: int = -1
    finished_step: int = -1


def _supported(cfg: ModelConfig) -> None:
    mixers = {spec.mixer for unit, _ in cfg.segments for spec in unit}
    bad = mixers - {"attn", "local_attn"}
    if bad:
        raise ValueError(
            f"continuous batching serves attention models only "
            f"(per-slot KV cache); config has mixers {sorted(bad)}")


class ContinuousBatchingEngine:
    """Request-level continuous batching over a per-slot KV cache."""

    def __init__(self, cfg: ModelConfig, params,
                 scfg: ServeConfig = ServeConfig()):
        _supported(cfg)
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._dtype = (jnp.bfloat16 if scfg.cache_dtype == "bfloat16"
                       else jnp.float32)

        def prefill_fn(params, tokens, caches, positions, last_idx):
            # tokens/positions [1, Sp] (bucket-padded; pads hold the
            # sentinel position and are dropped by the cache write).
            logits, caches, _ = T.forward(params, tokens, cfg, caches=caches,
                                          positions=positions)
            last = jax.lax.dynamic_slice_in_dim(logits, last_idx, 1, axis=1)
            return last[:, 0], caches

        def decode_fn(params, tokens, caches, positions):
            # tokens/positions [B, 1] — B slots, each at its own position.
            logits, caches, _ = T.forward(params, tokens, cfg, caches=caches,
                                          positions=positions)
            return logits[:, -1], caches

        def insert_fn(big, small, slot):
            def ins(b, s):
                # The slot (batch) axis is the first one where the engine
                # cache (max_slots wide) and the batch-1 prefill cache
                # differ; with max_slots == 1 every axis matches and the
                # insert is a whole-cache replacement.
                axis = next((i for i, (x, y) in
                             enumerate(zip(b.shape, s.shape)) if x != y),
                            None)
                if axis is None:
                    return s.astype(b.dtype)
                starts = tuple(slot if i == axis else 0
                               for i in range(b.ndim))
                return jax.lax.dynamic_update_slice(
                    b, s.astype(b.dtype), starts)

            return jax.tree_util.tree_map(ins, big, small)

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self._insert = jax.jit(insert_fn)

        B = scfg.max_slots
        self.caches = T.init_caches(cfg, B, scfg.max_len, self._dtype,
                                    per_slot=True)
        # Reused on every admission: jax arrays are immutable and prefill
        # is functional, so one empty 1-slot cache serves all requests.
        self._empty_slot = T.init_caches(cfg, 1, scfg.max_len, self._dtype,
                                         per_slot=True)
        self.slots: list[Request | None] = [None] * B
        self.queue: collections.deque[Request] = collections.deque()
        self.lengths = np.zeros((B,), np.int32)       # tokens in each slot
        self.last_token = np.zeros((B,), np.int32)    # next decode input
        self._next_rid = 0
        self._step = 0
        self._tick = 0                                # sampling-event counter
        self._base_key = jax.random.PRNGKey(0)
        self.counters = {"prefill_tokens": 0, "decode_tokens": 0,
                         "decode_steps": 0, "decode_slot_steps": 0,
                         "requests_finished": 0}

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> Request:
        L = len(req.prompt)
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if L + req.max_new_tokens > self.scfg.max_len:
            raise ValueError(
                f"request needs {L}+{req.max_new_tokens} tokens, "
                f"max_len={self.scfg.max_len}")
        req.rid = self._next_rid
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _sample(self, logits: jax.Array) -> jax.Array:
        """Deterministic sampling: key derived from (base_key, tick)."""
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        key = jax.random.fold_in(self._base_key, self._tick)
        self._tick += 1
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1)

    def _bucketed(self, L: int) -> int:
        b = self.scfg.prefill_bucket
        return min(self.scfg.max_len, -(-L // b) * b)

    def _admit(self) -> None:
        while self.queue and None in self.slots:
            slot = self.slots.index(None)
            req = self.queue.popleft()
            L = len(req.prompt)
            Sp = self._bucketed(L)
            tokens = np.zeros((1, Sp), np.int32)
            tokens[0, :L] = np.asarray(req.prompt, np.int32)
            positions = np.full((1, Sp), POS_SENTINEL, np.int32)
            positions[0, :L] = np.arange(L, dtype=np.int32)

            last_logits, small = self._prefill(
                self.params, jnp.asarray(tokens), self._empty_slot,
                jnp.asarray(positions), jnp.asarray(L - 1, jnp.int32))
            self.caches = self._insert(self.caches, small,
                                       jnp.asarray(slot, jnp.int32))

            tok = int(np.asarray(self._sample(last_logits))[0])
            req.generated.append(tok)
            req.prefill_len = L
            req.admitted_step = self._step
            self.counters["prefill_tokens"] += L
            self.slots[slot] = req
            self.lengths[slot] = L
            self.last_token[slot] = tok
            self._maybe_evict(slot, tok)

    def _maybe_evict(self, slot: int, tok: int) -> None:
        req = self.slots[slot]
        if req is None:
            return
        done = len(req.generated) >= req.max_new_tokens
        if self.scfg.eos_id is not None and tok == self.scfg.eos_id:
            done = True
        if done:
            req.finished_step = self._step
            self.counters["requests_finished"] += 1
            self.slots[slot] = None

    def step(self) -> bool:
        """One scheduler tick: admit from the queue, then one decode step
        over every active slot.  Returns False when there is no work."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return bool(self.queue)
        self._step += 1
        tokens = jnp.asarray(self.last_token[:, None])
        positions = jnp.asarray(self.lengths[:, None])
        logits, self.caches = self._decode(
            self.params, tokens, self.caches, positions)
        toks = np.asarray(self._sample(logits), np.int32)
        self.counters["decode_steps"] += 1
        self.counters["decode_slot_steps"] += len(self.slots)
        for i in active:
            req = self.slots[i]
            req.generated.append(int(toks[i]))
            self.counters["decode_tokens"] += 1
            self.lengths[i] += 1
            self.last_token[i] = toks[i]
            self._maybe_evict(i, int(toks[i]))
        return True

    def run(self, seed: int = 0) -> None:
        """Drain queue + slots to completion, deterministically under seed."""
        self._base_key = jax.random.PRNGKey(seed)
        self._tick = 0
        while self.queue or any(r is not None for r in self.slots):
            self.step()

    def generate(self, requests: list[Request], seed: int = 0):
        """Serve a list of requests (arbitrary prompt lengths) to
        completion; returns the same list with ``generated`` filled."""
        for r in requests:
            self.submit(r)
        self.run(seed)
        return requests

    # ------------------------------------------------------------------
    # measured-traffic reporting
    # ------------------------------------------------------------------

    def sparsity_report(self, prompts) -> dict[str, Any]:
        """Measured BitStopper traffic, per request and aggregated.

        ``prompts``: 2-D int array [B, S] or a list of 1-D int arrays of
        arbitrary (per-request) lengths.  Each request's prefill attention
        at the first attention layer is run through the block-granular
        semantic model; returns mean planes fetched per (q, kv-block),
        plane fraction vs dense 12-bit, block-level V-fetch fraction and
        token survivor fraction — aggregated under the legacy keys, plus a
        ``per_request`` list for served-traffic benchmarks."""
        if isinstance(prompts, np.ndarray) and prompts.ndim == 2:
            prompts = list(prompts)
        per_request = []
        for p in prompts:
            rep = _prompt_sparsity(self.cfg, self.params, np.asarray(p))
            if rep:
                per_request.append(rep)
        if not per_request:
            return {}
        # Weighted aggregation: a long prompt has many more (q-tile,
        # kv-block) units and (q, k) pairs than a short one — an
        # unweighted mean over requests would let short prompts skew the
        # traffic headline.
        blocks = np.array([r["n_blocks"] for r in per_request], np.float64)
        pairs = np.array([r["n_pairs"] for r in per_request], np.float64)

        def wmean(key, w):
            vals = np.array([r[key] for r in per_request], np.float64)
            return float((vals * w).sum() / w.sum())

        agg = {
            "mean_rounds": wmean("mean_rounds", blocks),
            "plane_fraction": wmean("plane_fraction", blocks),
            "block_alive_fraction": wmean("block_alive_fraction", blocks),
            "survivor_fraction": wmean("survivor_fraction", pairs),
            "per_request": per_request,
        }
        return agg


# Public name: the continuous batcher IS the serving engine.
ServingEngine = ContinuousBatchingEngine


class StaticBucketEngine:
    """The previous engine: one same-length batch at a time, re-padded per
    batch, shared cursor.  Kept as the baseline for
    ``benchmarks/serve_throughput.py`` and for A/B-ing the scheduler."""

    def __init__(self, cfg: ModelConfig, params,
                 scfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg

        def prefill_fn(params, tokens, caches):
            S = tokens.shape[1]
            logits, caches, _ = T.forward(params, tokens, cfg, caches=caches,
                                          positions=jnp.arange(S))
            return logits[:, -1], caches

        def decode_fn(params, token, caches, pos):
            logits, caches, _ = T.forward(
                params, token, cfg, caches=caches, positions=pos[None])
            return logits[:, -1], caches

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

    def init_caches(self, batch: int):
        dt = (jnp.bfloat16 if self.scfg.cache_dtype == "bfloat16"
              else jnp.float32)
        return T.init_caches(self.cfg, batch, self.scfg.max_len, dt)

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1)

    def generate(self, requests: list[Request], seed: int = 0):
        """Serve requests bucketed by prompt length, one batch at a time."""
        base_key = jax.random.PRNGKey(seed)
        buckets: dict[int, list[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        for bi, (_, batch) in enumerate(sorted(buckets.items())):
            self._generate_batch(batch, jax.random.fold_in(base_key, bi))
        return requests

    def _generate_batch(self, requests: list[Request], key):
        prompts = jnp.asarray(np.stack([r.prompt for r in requests]))
        B, S = prompts.shape
        caches = self.init_caches(B)
        logits, caches = self._prefill(self.params, prompts, caches)
        max_new = max(r.max_new_tokens for r in requests)
        token = self._sample(logits, jax.random.fold_in(key, 0))
        for r, t in zip(requests, np.asarray(token)):
            r.generated.append(int(t))
        for i in range(1, max_new):
            logits, caches = self._decode(
                self.params, token[:, None], caches,
                jnp.asarray(S + i - 1, jnp.int32))
            token = self._sample(logits, jax.random.fold_in(key, i))
            for r, t in zip(requests, np.asarray(token)):
                if len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(t))
        return requests


# ---------------------------------------------------------------------------
# measured sparsity of one prompt's prefill attention (layer 0)
# ---------------------------------------------------------------------------


def _prompt_sparsity(cfg: ModelConfig, params, prompt: np.ndarray):
    from repro.core.block_adaptation import block_bitstopper_attention
    from repro.models import layers as L
    from repro.models.attention import _divisor_block

    x = L.embed(params["embed"], jnp.asarray(prompt)[None]).astype(
        cfg.activation_dtype)
    p0 = _first_attn_params(params, cfg)
    if p0 is None:
        return {}
    from repro.models.layers import linear, rope
    acfg = cfg.attn_config(False)
    pos = jnp.arange(x.shape[1])
    q = rope(linear(p0["wq"], x), pos[None], acfg.rope_theta)
    k = rope(linear(p0["wk"], x), pos[None], acfg.rope_theta)
    v = linear(p0["wv"], x)
    G = acfg.n_heads // acfg.n_kv_heads
    kr = jnp.repeat(k, G, axis=2).swapaxes(1, 2)
    vr = jnp.repeat(v, G, axis=2).swapaxes(1, 2)
    qt = q.swapaxes(1, 2)
    # Small q-tiles: a kv block stops fetching planes only when EVERY
    # query row in the tile agrees, so tall tiles can't terminate.
    res = block_bitstopper_attention(
        qt, kr, vr, cfg=cfg.bitstopper,
        block_q=_divisor_block(qt.shape[-2], 8),
        block_k=_divisor_block(kr.shape[-2], 16),
        causal=True)
    rounds = np.asarray(res.stats.rounds_per_block, np.float64)
    alive = np.asarray(res.stats.block_alive)
    surv = np.asarray(res.stats.survivors)
    return {
        "prompt_len": int(prompt.shape[0]),
        "mean_rounds": float(rounds.mean()),
        "plane_fraction": float(rounds.mean() / cfg.bitstopper.bits),
        "block_alive_fraction": float(alive.mean()),
        "survivor_fraction": float(surv.mean()),
        "n_blocks": int(rounds.size),
        "n_pairs": int(surv.size),
    }


def _first_attn_params(params, cfg: ModelConfig):
    for si, (unit, reps) in enumerate(cfg.segments):
        for i, spec in enumerate(unit):
            if spec.mixer in ("attn", "local_attn"):
                seg = params[f"seg{si}"]
                blk = seg[f"b{i}"] if isinstance(seg, dict) else seg[0][f"b{i}"]
                p = blk["attn"]
                if cfg.scan_layers and reps > 1 and isinstance(seg, dict):
                    p = jax.tree_util.tree_map(lambda a: a[0], p)
                return p
    return None
