"""Deterministic fault injection + crash/restore harness for serving.

Chaos testing for the paged engine, built on one rule: **every fault is a
pure function of the scripted plan and the engine's tick counter** — no
wall clock, no ambient randomness, no flakiness.  A :class:`FaultPlan` is
a list of ``(kind, tick)`` events (hand-scripted or derived from a seed);
a :class:`FaultInjector` fires each event at the first consultation of
its kind at-or-after its tick, exactly once.  Replaying the same trace
under the same plan reproduces the same faults at the same points, which
is what lets tests assert *bit-identical tokens* across a fault storm.

Fault kinds and where they bite (`docs/robustness.md` has the model):

* ``pool_dry``      — a mid-decode block claim is forced to preempt a
                      victim even though the pool is not actually dry
                      (exercises lossless preemption at scripted points;
                      ``PagedEngine._claim_block``).
* ``kernel_fail``   — the fused paged decode/verify kernel raises
                      :class:`KernelFault`; the engine's circuit breaker
                      degrades to the gather fallback (bit-identical) and
                      retries the same tick.
* ``drafter_fail``  — the speculative drafter raises; the tick falls back
                      to a plain decode (losslessness is unconditional —
                      speculation only ever changes forward count).
* ``checkpoint_interrupt`` — a snapshot write dies after staging, before
                      the atomic promote: the store must never expose the
                      torn snapshot and GC must reclaim the orphan.  The
                      same seam interrupts prefix-store spills
                      (``PagedEngine`` catches it and drops the record;
                      the staged orphan is GC'd).
* ``swap_fail``     — the device→host copy of a preemption victim's
                      blocks dies mid-swap-out: the engine discards the
                      partial record and the victim falls back to the
                      recompute-resume path (bit-identical by the PR-5
                      losslessness guarantee).
* ``crash``         — the host dies between ticks; the harness rebuilds a
                      fresh engine and :meth:`PagedEngine.restore`\\ s the
                      latest snapshot.  Served tokens must be (and are
                      tested) bit-identical to an undisturbed run.  Host
                      swap records die with the host (they are RAM), so
                      restored victims also recompute.

The injector lives in the *harness*, outside the engine, so it survives a
``crash`` — replayed ticks after a restore do not re-fire consumed events
(a real re-run of the same wall of faults would not re-crash at a point
the previous incarnation already crashed at).

This module is imported by ``serving/engine.py`` and must stay free of
top-level serving imports (and of wall-clock reads — the
``repo-tick-wallclock`` lint rule enforces the latter for all of
``serving/``).
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

KINDS = ("pool_dry", "kernel_fail", "drafter_fail",
         "checkpoint_interrupt", "swap_fail", "crash")


class KernelFault(RuntimeError):
    """Fused-kernel failure (injected or real): the decode/verify call
    died.  Caught by the engine's circuit breaker, which degrades to the
    pure-JAX gather fallback and retries — tokens never change."""


class DrafterFault(RuntimeError):
    """Speculative drafter failure: the proposal step died.  The tick
    degenerates to a plain decode; no tokens are lost."""


class HostCrash(RuntimeError):
    """Simulated whole-host death between scheduler ticks.  Raised by the
    harness (never caught by the engine): everything the engine held —
    device KV included — is gone; recovery is a fresh engine +
    :meth:`PagedEngine.restore` from the latest snapshot."""


class CheckpointInterrupted(RuntimeError):
    """A snapshot write was killed after staging but before the atomic
    promote (``checkpoint/store.py``).  The previous snapshot must remain
    the visible latest; the staging orphan is GC'd."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: fires at the first consultation of ``kind`` at
    tick >= ``tick``, then is consumed."""
    kind: str
    tick: int

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"kinds are {KINDS}")
        if self.tick < 0:
            raise ValueError(f"fault tick must be >= 0, got {self.tick}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable scripted sequence of faults.  Deterministic by
    construction: events are (kind, tick) pairs with no time-of-day or
    randomness at fire time — :meth:`from_seed` derives a plan from a
    seed *once*, and the derived plan is plain data."""
    events: tuple[FaultEvent, ...] = ()

    @classmethod
    def scripted(cls, events) -> "FaultPlan":
        """Build from ``(kind, tick)`` pairs (or FaultEvents)."""
        evs = tuple(e if isinstance(e, FaultEvent) else FaultEvent(*e)
                    for e in events)
        return cls(events=evs)

    @classmethod
    def from_seed(cls, seed: int, n_events: int, max_tick: int,
                  kinds=KINDS) -> "FaultPlan":
        """Derive a plan from a seed: ``n_events`` faults with kinds and
        ticks drawn from a seeded ``np.random.default_rng`` —
        reproducible forever, independent of interpreter hash seeds and
        wall clock."""
        rng = np.random.default_rng(seed)
        kinds = tuple(kinds)
        evs = tuple(FaultEvent(kinds[int(rng.integers(len(kinds)))],
                               int(rng.integers(max_tick + 1)))
                    for _ in range(n_events))
        return cls(events=evs)

    def to_json(self) -> str:
        return json.dumps([[e.kind, e.tick] for e in self.events])

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls.scripted(json.loads(text))


class FaultInjector:
    """Consumes a :class:`FaultPlan` against the engine's tick counter.

    ``fire(kind, tick)`` returns True iff an unconsumed event of ``kind``
    has armed (``event.tick <= tick``); the event is then consumed and
    logged.  Consultation order is fixed by the engine's deterministic
    schedule, so the full fired log is a pure function of (plan, trace).
    """

    def __init__(self, plan: FaultPlan | None = None):
        self.plan = plan or FaultPlan()
        self._pending: list[FaultEvent] = sorted(
            self.plan.events, key=lambda e: (e.tick, e.kind))
        self.fired: list[tuple[str, int, int]] = []  # (kind, armed, fired-at)

    def fire(self, kind: str, tick: int) -> bool:
        for i, ev in enumerate(self._pending):
            if ev.kind == kind and ev.tick <= tick:
                del self._pending[i]
                self.fired.append((kind, ev.tick, tick))
                return True
        return False

    def pending(self) -> list[tuple[str, int]]:
        return [(e.kind, e.tick) for e in self._pending]

    def report(self) -> dict:
        by_kind: dict[str, int] = {}
        for kind, _, _ in self.fired:
            by_kind[kind] = by_kind.get(kind, 0) + 1
        return {"fired": list(self.fired),
                "fired_by_kind": by_kind,
                "unfired": self.pending()}


def serve_with_chaos(make_engine, requests, seed: int = 0,
                     plan: FaultPlan | None = None,
                     snapshot_dir: str | None = None,
                     snapshot_every: int | None = None):
    """Drive a serving trace under a fault plan, with crash recovery.

    ``make_engine`` is a zero-arg factory for a fresh :class:`PagedEngine`
    (a crash destroys the old one — device KV and all).  Requests are
    submitted up front; a snapshot is taken immediately (so a crash at any
    tick has something to restore) and then every ``snapshot_every`` ticks
    through the engine's own cadence knob.  ``crash`` events raise
    :class:`HostCrash` between ticks; recovery rebuilds the engine and
    restores the latest snapshot — generated-so-far tokens come from the
    snapshot, in-flight requests requeue through the lossless PR-5 resume
    path, and the continuation re-samples under the same
    ``(seed, rid, token index)`` keys, so the final token streams are
    bit-identical to an undisturbed run.

    Returns ``(requests, report)``: the engine's request objects sorted by
    rid (after a crash these are *restored* objects, not the caller's),
    and a dict of fault/snapshot/restore accounting.
    """
    from repro.checkpoint.store import (gc_staging, load_snapshot,
                                        save_snapshot)

    injector = FaultInjector(plan)
    engine = make_engine()
    engine.chaos = injector
    every = (engine.scfg.snapshot_every if snapshot_every is None
             else snapshot_every)
    for r in requests:
        engine.submit(r)
    engine.begin(seed)
    report = {"crashes": 0, "restores": 0, "snapshots_taken": 0,
              "snapshots_interrupted": 0, "staging_reclaimed": 0}

    def take_snapshot():
        if snapshot_dir is None:
            return
        state = engine.snapshot()

        def interrupt():
            if injector.fire("checkpoint_interrupt", engine.ticks):
                raise CheckpointInterrupted(
                    f"snapshot write killed at tick {engine.ticks}")

        try:
            save_snapshot(state, snapshot_dir, step=engine.ticks,
                          interrupt=interrupt)
            report["snapshots_taken"] += 1
        except CheckpointInterrupted:
            report["snapshots_interrupted"] += 1
            # The orphaned staging dir is reclaimable immediately here:
            # this harness is the only writer, so nothing is in flight.
            report["staging_reclaimed"] += len(
                gc_staging(snapshot_dir, grace=0.0))

    take_snapshot()
    while engine.pending():
        try:
            if injector.fire("crash", engine.ticks):
                raise HostCrash(f"host died at tick {engine.ticks}")
            engine.step()
        except HostCrash:
            report["crashes"] += 1
            if snapshot_dir is None:
                raise          # nothing to restore from: the crash is fatal
            engine = make_engine()
            engine.chaos = injector
            state, _ = load_snapshot(snapshot_dir)
            engine.restore(state)
            report["restores"] += 1
            continue
        if (snapshot_dir is not None and every
                and engine.ticks % every == 0):
            take_snapshot()

    out = [engine.requests[rid] for rid in sorted(engine.requests)]
    report.update(injector.report())
    report["engine_counters"] = dict(engine.counters)
    return out, report
