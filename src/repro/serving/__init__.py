"""Serving: prefill/decode engine with BitStopper sparse attention."""

from repro.serving.engine import ServeConfig, ServingEngine  # noqa: F401
