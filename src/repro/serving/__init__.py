"""Serving: paged continuous-batching engine with BitStopper sparse decode."""

from repro.serving.chaos import (  # noqa: F401
    CheckpointInterrupted,
    DrafterFault,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    HostCrash,
    KernelFault,
    serve_with_chaos,
)
from repro.serving.engine import (  # noqa: F401
    ContinuousBatchingEngine,
    InsufficientBlocks,
    PagedEngine,
    Prefix,
    Request,
    ServeConfig,
    ServingEngine,
    StaticBucketEngine,
)
from repro.serving.kv_pool import KVBlockPool  # noqa: F401
from repro.serving.speculative import (  # noqa: F401
    Drafter,
    DraftModelDrafter,
    NGramDrafter,
    make_drafter,
)
