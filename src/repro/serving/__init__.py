"""Serving: continuous-batching engine with BitStopper sparse decode."""

from repro.serving.engine import (  # noqa: F401
    ContinuousBatchingEngine,
    Request,
    ServeConfig,
    ServingEngine,
    StaticBucketEngine,
)
