"""Paged KV-cache block pool: allocator, refcounts, prefix sharing.

The device side of the paged cache is a batch-free
``[pool_blocks, page_size, Hkv, D]`` K/V pool per attention layer
(:func:`repro.models.attention.init_cache` with a ``PagedLayout``).  This
module is the **host-side** half: which physical block backs which logical
block of which request.  It is pure Python/bookkeeping — no jax — so the
scheduler can consult it between device steps at zero dispatch cost.

Design (vLLM-style, sized for this repro):

* **Free-list allocator.**  Physical block 0 is reserved as the *null
  block* (backs unused table entries; never written, never allocated).
* **Refcounted blocks.**  A block may appear in several requests' block
  tables at once — copy-on-write prefix sharing.  Only *full* blocks of
  prompt tokens are ever shared, and shared blocks are never rewritten
  (a request's partially-filled tail block is always exclusively owned),
  so "copy-on-write" never actually needs to copy: a request that would
  diverge from a shared block simply allocates its own.
* **Prefix registry.**  Full prompt blocks are registered under the chain
  key of *all* tokens up to the block's end, so a lookup hit guarantees
  the entire prefix matches (no hash-collision false sharing — keys are
  the token tuples themselves).  When the last reference to a registered
  block drops, the block parks in an LRU *cached* pool instead of the
  free list: a later request with the same prefix can resurrect it, and
  allocation pressure evicts the oldest cached block first.
* **Reservations.**  Admission control reserves blocks up front; an
  unreserved :meth:`alloc` never dips into outstanding reservations.  In
  the default (fully-reserved) mode the scheduler reserves the worst-case
  block count for a request (``prompt + max_new_tokens``, minus
  shared-prefix hits), so mid-decode allocation can never fail.  Under
  **oversubscription** (``ServeConfig.oversubscribe``) the scheduler
  reserves only the prompt blocks plus one decode block and handles
  mid-decode exhaustion with victim preemption: :meth:`preempt` returns a
  victim's exclusively-owned blocks to the free list while its shared /
  registered prefix blocks merely drop a reference (parking in the LRU
  cache, resurrectable), so a requeued victim resumes the shared prefix
  for free and recomputes only the unshared tail.  The request lifecycle
  this module backs is documented in ``docs/serving.md``.
* **Host tiers.**  :class:`SwapPool` is the budgeted host-RAM rung of the
  memory hierarchy below the device pool (``docs/serving.md`` "Memory
  hierarchy"): opaque byte-accounted records keyed by request id
  (swap-to-host preemption) or prefix chain key (the warm prefix tier
  above the disk store).  ``evict_cb`` on :meth:`KVBlockPool.alloc`'s
  LRU eviction is the spill trigger — it fires while the evicted block's
  device content is still intact, so the engine can copy it down a tier
  before the new owner overwrites it.
"""

from __future__ import annotations

import collections


class KVBlockPool:
    """Host-side block allocator for the paged serving KV cache."""

    def __init__(self, pool_blocks: int, page_size: int,
                 prefix_sharing: bool = True, evict_cb=None):
        if pool_blocks < 2:
            raise ValueError("pool_blocks must be >= 2 (block 0 is the "
                             f"reserved null block), got {pool_blocks}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.pool_blocks = pool_blocks
        self.page_size = page_size
        self.prefix_sharing = prefix_sharing
        # Fired as evict_cb(key, bid) when alloc() steals a parked
        # registered block — BEFORE the new owner can write it, so the
        # caller may still extract the block's device content (the prefix
        # spill path).  Must not call back into the pool.
        self.evict_cb = evict_cb
        self._free: collections.deque[int] = collections.deque(
            range(1, pool_blocks))
        self._ref: dict[int, int] = {}            # live block -> refcount
        self._cached: collections.OrderedDict[tuple, int] = \
            collections.OrderedDict()             # LRU: key -> parked block
        self._registry: dict[tuple, int] = {}     # prefix key -> block
        self._key_of: dict[int, tuple] = {}       # registered block -> key
        self._reserved = 0
        self.peak_live_blocks = 0
        self.alloc_count = 0

    # ------------------------------------------------------------------
    # capacity accounting
    # ------------------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable blocks (everything but the null block)."""
        return self.pool_blocks - 1

    def live_blocks(self) -> int:
        return len(self._ref)

    def available(self) -> int:
        """Blocks an admission could still reserve: free + evictable-cached
        minus outstanding reservations."""
        return len(self._free) + len(self._cached) - self._reserved

    def saturation(self) -> float:
        """Committed fraction of capacity: 1 - available / capacity.
        Counts live blocks AND outstanding reservations (capacity already
        promised is just as unavailable as capacity in use) — the signal
        the engine's load-shedding watermark thresholds on."""
        return 1.0 - self.available() / self.capacity

    def snapshot(self) -> dict:
        """Full allocator state as JSON-serializable plain data, for the
        engine's crash snapshot.  Restore does NOT reinstate it — after a
        host crash the device KV behind these block ids is gone, so a
        restored engine re-claims blocks through the resume path against a
        fresh pool — but persisting it keeps the snapshot a faithful,
        inspectable record of crash-time occupancy (and carries the
        bookkeeping counters across)."""
        return {
            "pool_blocks": self.pool_blocks,
            "page_size": self.page_size,
            "prefix_sharing": self.prefix_sharing,
            "free": list(self._free),
            "ref": {str(bid): n for bid, n in sorted(self._ref.items())},
            "cached": [[list(key), bid]
                       for key, bid in self._cached.items()],
            "registry": [[list(key), bid]
                         for key, bid in sorted(self._registry.items())],
            "reserved": self._reserved,
            "peak_live_blocks": self.peak_live_blocks,
            "alloc_count": self.alloc_count,
        }

    def reserve(self, n: int) -> None:
        if n < 0:
            raise ValueError(f"cannot reserve {n} blocks")
        if n > self.available():
            raise RuntimeError(
                f"reserve({n}): only {self.available()} blocks available")
        self._reserved += n

    def cancel_reservation(self, n: int) -> None:
        if n > self._reserved:
            raise RuntimeError(
                f"cancel_reservation({n}) exceeds outstanding "
                f"{self._reserved}")
        self._reserved -= n

    # ------------------------------------------------------------------
    # allocation / refcounting
    # ------------------------------------------------------------------

    def _track_peak(self) -> None:
        self.peak_live_blocks = max(self.peak_live_blocks, len(self._ref))

    def alloc(self, reserved: bool = False) -> int:
        """Claim a block (refcount 1).  ``reserved=True`` consumes one unit
        of a prior :meth:`reserve`; an unreserved alloc only succeeds when
        a block exists *beyond* outstanding reservations — it must never
        consume capacity another request was promised."""
        if not reserved and self.available() < 1:
            raise RuntimeError(
                f"unreserved alloc: {len(self._free) + len(self._cached)} "
                f"block(s) uncommitted but {self._reserved} reserved — an "
                f"unreserved alloc may not consume a reservation")
        if self._free:
            bid = self._free.popleft()
        elif self._cached:
            key, bid = self._cached.popitem(last=False)   # evict LRU
            del self._registry[key]
            del self._key_of[bid]
            if self.evict_cb is not None:
                # Device content of `bid` is still intact here (the new
                # owner has not written yet; sanitizer poisoning also
                # runs after this returns) — last chance to spill it.
                self.evict_cb(key, bid)
        else:
            raise RuntimeError("KV block pool exhausted")
        if reserved:
            self.cancel_reservation(1)
        self._ref[bid] = 1
        self.alloc_count += 1
        self._track_peak()
        return bid

    def incref(self, bid: int) -> None:
        self._ref[bid] += 1

    def refcount(self, bid: int) -> int:
        """Live references to a block (0: free, parked, or unknown)."""
        return self._ref.get(bid, 0)

    def is_registered(self, bid: int) -> bool:
        """True iff the block is published in the prefix registry (a full
        prompt block other requests may map; it parks rather than frees)."""
        return bid in self._key_of

    def decref(self, bid: int) -> None:
        """Drop one reference; the last drop frees the block — to the LRU
        cached pool if it is a registered prefix block, else the free
        list."""
        n = self._ref[bid] - 1
        if n > 0:
            self._ref[bid] = n
            return
        del self._ref[bid]
        key = self._key_of.get(bid)
        if key is not None and self.prefix_sharing:
            self._cached[key] = bid               # parked, resurrectable
            self._cached.move_to_end(key)
        else:
            if key is not None:
                del self._registry[key]
                del self._key_of[bid]
            self._free.append(bid)

    def rollback(self, bids: list[int], reserve: bool = True) -> None:
        """Return speculative tail blocks to the pool, atomically restoring
        the reservation they were claimed from.

        Speculative decoding materializes blocks for draft-token positions
        out of the request's admission reservation; when the drafts are
        rejected, those blocks hold no live token and must come back — with
        the reservation units re-created so the request's worst-case
        guarantee (mid-decode allocation can never fail) still holds.
        ``reserve=False`` skips the re-reservation: an oversubscribed
        engine claims draft blocks from *spare* (unreserved) capacity, and
        re-reserving those on rollback would earmark shared spare capacity
        to one slot, starving the others into needless preemptions.

        Rolled-back blocks must be **exclusively owned, unregistered**
        scratch: a refcount > 1 block is mapped by another request's table
        and a registered block is a published prompt prefix — rolling
        either back would yank KV out from under a reader (the engine never
        rolls past the prompt/shared boundary; this guards the invariant).
        """
        self._free_exclusive(bids, "rollback")
        # Freed blocks are available again by construction, so re-reserving
        # them cannot fail.
        if reserve:
            self._reserved += len(bids)

    def _free_exclusive(self, bids: list[int], verb: str) -> None:
        """Shared mechanics of :meth:`rollback` and :meth:`preempt`: free
        exclusively-owned, unregistered blocks to the free list.  Validates
        every bid BEFORE mutating anything — a guard firing mid-loop must
        not leave the pool half-reclaimed."""
        for bid in bids:
            if self._ref.get(bid) != 1:
                raise RuntimeError(
                    f"{verb} of block {bid} with refcount "
                    f"{self._ref.get(bid)}: only exclusively-owned blocks "
                    f"may be reclaimed (shared blocks outlive the {verb})")
            if bid in self._key_of:
                raise RuntimeError(
                    f"{verb} of registered prefix block {bid}: published "
                    f"prefix blocks park via decref, never free forcibly")
        for bid in bids:
            del self._ref[bid]
            self._free.append(bid)

    def preempt(self, bids: list[int]) -> None:
        """Forcibly reclaim a preemption victim's exclusively-owned blocks.

        Unlike :meth:`rollback` these blocks held *live* tokens (the victim
        recomputes them on resume via chunked prefill) and no reservation
        is re-created — the scheduler cancels the victim's remaining
        reservation separately and the freed capacity is exactly what the
        preemption exists to hand to other requests.

        Shared and registered blocks must NOT come through here: a
        refcount > 1 block is mapped by another request's table and a
        registered block is a published prompt prefix — both must survive
        the victim (the scheduler ``decref``\\ s them instead, parking
        registered blocks in the LRU cache so resume re-maps them for
        free).  Validation runs before any mutation, so a refused call
        leaves the pool untouched.
        """
        self._free_exclusive(bids, "preempt")

    # ------------------------------------------------------------------
    # prefix sharing
    # ------------------------------------------------------------------

    def register(self, key: tuple, bid: int) -> None:
        """Publish a fully-written prompt block under its prefix chain key.
        First writer wins; re-registration under the same key is a no-op
        (the content is identical by construction)."""
        if not self.prefix_sharing or key in self._registry:
            return
        self._registry[key] = bid
        self._key_of[bid] = key

    def lookup(self, key: tuple) -> int | None:
        """Find a block holding exactly this prefix chunk.  A hit takes a
        reference (resurrecting the block from the cached pool if its last
        owner already finished) — the caller owns the reference."""
        if not self.prefix_sharing:
            return None
        bid = self._registry.get(key)
        if bid is None:
            return None
        if bid in self._ref:
            self.incref(bid)
        else:
            del self._cached[key]
            self._ref[bid] = 1
            self._track_peak()
        return bid

    def registered_items(self) -> list[tuple[tuple, int]]:
        """All published prefix blocks as ``(chain key, bid)`` pairs in
        deterministic (sorted-key) order — live and parked alike.  Every
        registered block is a fully-written prompt block that is never
        rewritten, so its device content is always safe to copy down a
        tier (``PagedEngine.flush_prefixes``)."""
        return sorted(self._registry.items())


class SwapPool:
    """Budgeted host-RAM tier of opaque swap/spill records.

    Pure bookkeeping, like the allocator above (no jax/numpy — the
    ``repo-allocator-device-ops`` lint applies): records are opaque to
    the pool and byte-sized by the caller, so device arrays, numpy trees
    and pickled prefix payloads all fit through the same accounting.
    Insertion order doubles as LRU order (:meth:`get` touches).

    Two policies, selected by ``evict_cb``:

    * ``evict_cb=None`` — a :meth:`put` that does not fit is **refused**
      (returns False) and the caller falls back a tier (swap-to-host
      preemption: the victim recomputes on resume, exactly the pre-swap
      behavior).
    * ``evict_cb=f`` — a put that does not fit first evicts
      least-recently-used records, handing each to ``f(key, record,
      nbytes)`` (the warm prefix tier: cold records spill down to the
      disk store instead of vanishing).
    """

    def __init__(self, budget_bytes: int = 0, evict_cb=None):
        if budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self.evict_cb = evict_cb
        self._records: collections.OrderedDict = collections.OrderedDict()
        self._nbytes: dict = {}
        self.bytes_used = 0
        self.peak_bytes = 0
        self.put_count = 0
        self.evict_count = 0
        self.refused_count = 0

    def __contains__(self, key) -> bool:
        return key in self._records

    def __len__(self) -> int:
        return len(self._records)

    def keys(self) -> list:
        return list(self._records)

    def nbytes_of(self, key) -> int:
        return self._nbytes.get(key, 0)

    def put(self, key, record, nbytes: int) -> bool:
        """Admit a record under the byte budget.  Replaces any existing
        record under the same key.  Returns False (refused, nothing
        stored) when the record cannot fit and there is no ``evict_cb``
        to make room."""
        nbytes = int(nbytes)
        if nbytes < 0:
            raise ValueError(f"record nbytes must be >= 0, got {nbytes}")
        if key in self._records:
            self.drop(key)
        if nbytes > self.budget_bytes or (
                self.evict_cb is None
                and self.bytes_used + nbytes > self.budget_bytes):
            self.refused_count += 1
            return False
        while self.bytes_used + nbytes > self.budget_bytes:
            old_key, old_rec = self._records.popitem(last=False)  # LRU
            old_n = self._nbytes.pop(old_key)
            self.bytes_used -= old_n
            self.evict_count += 1
            self.evict_cb(old_key, old_rec, old_n)
        self._records[key] = record
        self._nbytes[key] = nbytes
        self.bytes_used += nbytes
        self.peak_bytes = max(self.peak_bytes, self.bytes_used)
        self.put_count += 1
        return True

    def get(self, key):
        """Peek a record (None on miss); a hit is an LRU touch."""
        rec = self._records.get(key)
        if rec is not None:
            self._records.move_to_end(key)
        return rec

    def take(self, key):
        """Remove and return a record (None on miss).  The swap path uses
        this: a resume consumes its record exactly once."""
        if key not in self._records:
            return None
        rec = self._records.pop(key)
        self.bytes_used -= self._nbytes.pop(key)
        return rec

    def drop(self, key) -> None:
        self.take(key)

    def items(self) -> list:
        """(key, record) pairs, LRU-oldest first — a point-in-time copy
        (safe to mutate the pool while iterating it)."""
        return list(self._records.items())
