"""The assigned input-shape suites (identical across all 10 LM archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), NOT ``train_step``.  ``long_500k`` requires
sub-quadratic attention: it runs only for archs with
``ModelConfig.sub_quadratic`` (mamba2, recurrentgemma) and is recorded as a
documented skip for the pure full-attention archs (DESIGN.md section 4).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeSuite] = {
    "train_4k": ShapeSuite("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSuite("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSuite("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSuite("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg) -> list[ShapeSuite]:
    """Shape suites that are well-defined for this arch (long_500k gating)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return out
