"""Architecture registry: exact assigned configs + reduced smoke variants.

Every entry is the config from the assignment block (public literature),
buildable with ``get_config(name)`` and selectable via ``--arch`` in the
launch scripts.  ``reduced_config(name)`` shrinks the same *family
structure* (same block pattern, same mixer kinds, tiny dims) for CPU smoke
tests; the full configs are exercised only through the dry-run.
"""

from __future__ import annotations

from repro.models.config import BlockSpec, ModelConfig, uniform_segments


def _dense(name, n_layers, d_model, heads, kv, d_ff, vocab, head_dim=None,
           qkv_bias=False, act="swiglu", norm="rms", family="dense",
           frontend=None, window=None, tie=False):
    return ModelConfig(
        name=name, family=family, d_model=d_model, vocab=vocab,
        segments=uniform_segments(n_layers),
        n_heads=heads, n_kv_heads=kv, head_dim=head_dim or d_model // heads,
        d_ff=d_ff, qkv_bias=qkv_bias, act=act, norm=norm, frontend=frontend,
        window=window, tie_embeddings=tie,
    )


# --------------------------------------------------------------------------
# The 10 assigned architectures
# --------------------------------------------------------------------------


def musicgen_medium():
    """[audio] decoder-only over EnCodec tokens [arXiv:2306.05284]."""
    return _dense("musicgen-medium", 48, 1536, 24, 24, 6144, 2048,
                  act="gelu", norm="ln", family="audio", frontend="audio",
                  tie=True)


def stablelm_12b():
    return _dense("stablelm-12b", 40, 5120, 32, 8, 13824, 100352,
                  qkv_bias=True, norm="ln")


def stablelm_1_6b():
    return _dense("stablelm-1.6b", 24, 2048, 32, 32, 5632, 100352,
                  qkv_bias=True, norm="ln")


def qwen2_5_14b():
    return _dense("qwen2.5-14b", 48, 5120, 40, 8, 13824, 152064,
                  qkv_bias=True)


def granite_20b():
    """MQA (kv=1): the largest relative K-traffic win for BitStopper."""
    return _dense("granite-20b", 52, 6144, 48, 1, 24576, 49152)


def recurrentgemma_2b():
    """Hybrid: (rglru, rglru, local_attn) pattern, window 2048 [arXiv:2402.19427]."""
    unit = (BlockSpec("rglru"), BlockSpec("rglru"), BlockSpec("local_attn"))
    tail = (BlockSpec("rglru"), BlockSpec("rglru"))
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", d_model=2560, vocab=256000,
        segments=((unit, 8), (tail, 1)),          # 26 layers
        n_heads=10, n_kv_heads=1, head_dim=256, d_ff=7680,
        act="geglu", lru_width=2560, window=2048,
        tie_embeddings=True, sub_quadratic=True,
    )


def mamba2_130m():
    """Attention-free SSD; BitStopper inapplicable (DESIGN.md section 4)."""
    return ModelConfig(
        name="mamba2-130m", family="ssm", d_model=768, vocab=50280,
        segments=uniform_segments(24, "ssm", "none"),
        ssm_state=128, ssm_head_dim=64, ssm_expand=2,
        tie_embeddings=True, sub_quadratic=True,
    )


def qwen2_moe_a2_7b():
    """4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", d_model=2048, vocab=151936,
        segments=uniform_segments(24, "attn", "moe"),
        n_heads=16, n_kv_heads=16, head_dim=128, qkv_bias=True,
        n_routed=60, top_k=4, d_expert=1408, n_shared=4, d_shared=5632,
    )


def deepseek_v3_671b():
    """MLA + 1 shared + 256 routed top-8 + MTP [arXiv:2412.19437].
    First 3 layers dense FFN, remaining 58 MoE."""
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", d_model=7168, vocab=129280,
        segments=(
            ((BlockSpec("mla", "dense"),), 3),
            ((BlockSpec("mla", "moe"),), 58),
        ),
        n_heads=128, d_ff=18432,
        q_rank=1536, kv_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        n_routed=256, top_k=8, d_expert=2048, n_shared=1, d_shared=2048,
        mtp=True,
        dtype="bfloat16", param_dtype="bfloat16", remat="dots",
    )


def llava_next_34b():
    """[vlm] backbone only; anyres patch embeddings stubbed."""
    return _dense("llava-next-34b", 60, 7168, 56, 8, 20480, 64000,
                  family="vlm", frontend="vision")


def paper_opt1_3b():
    """OPT-1.3B — the paper's own algorithm-eval model (RoPE instead of
    learned positions; noted in DESIGN.md)."""
    return _dense("paper-opt1.3b", 24, 2048, 32, 32, 8192, 50272,
                  qkv_bias=True, act="gelu", norm="ln", tie=True)


ARCHS = {
    "musicgen-medium": musicgen_medium,
    "stablelm-12b": stablelm_12b,
    "stablelm-1.6b": stablelm_1_6b,
    "qwen2.5-14b": qwen2_5_14b,
    "granite-20b": granite_20b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "mamba2-130m": mamba2_130m,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "llava-next-34b": llava_next_34b,
    "paper-opt1.3b": paper_opt1_3b,
}


def list_archs() -> list[str]:
    return list(ARCHS)


# Families the launch scripts know how to shape-check (dry-run input
# specs and frontend stubs key off these).
FAMILIES = frozenset({"dense", "moe", "ssm", "hybrid", "audio", "vlm"})


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = ARCHS[name]()
    if overrides:
        cfg = cfg.replace(**overrides)
    if cfg.family not in FAMILIES:
        raise ValueError(
            f"{cfg.name}: unknown family {cfg.family!r} "
            f"(expected one of {sorted(FAMILIES)})")
    return cfg


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: same block pattern,
    few layers, narrow dims, small vocab/experts."""
    cfg = get_config(name)
    # Shrink segments: keep the pattern units, cut repeats to <= 2.
    segments = tuple((unit, min(reps, 2)) for unit, reps in cfg.segments)
    heads = min(cfg.n_heads, 4) or 4
    kv = max(1, min(cfg.n_kv_heads, heads))
    if cfg.n_kv_heads == cfg.n_heads:
        kv = heads
    kw = dict(
        segments=segments,
        d_model=64, vocab=256, d_ff=128 if cfg.d_ff else 0,
        n_heads=heads, n_kv_heads=kv, head_dim=16,
        window=8 if cfg.window else None,
        lru_width=64 if cfg.lru_width else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        dtype="float32", param_dtype="float32", remat="none",
    )
    if cfg.n_routed:
        # capacity_factor high enough that tiny test batches never drop —
        # dropping is a large-scale statistical effect, not a unit-test one.
        kw.update(n_routed=8, top_k=min(cfg.top_k, 2), d_expert=32,
                  n_shared=min(cfg.n_shared, 1),
                  d_shared=64 if cfg.n_shared else 0,
                  moe_capacity_factor=8.0)
    if cfg.kv_rank:
        kw.update(q_rank=32, kv_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                  v_head_dim=16, head_dim=0)
    return cfg.replace(**kw)
