"""Assigned architectures (+ the paper's own eval model) as selectable configs."""

from repro.configs.registry import get_config, list_archs, reduced_config  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeSuite  # noqa: F401
