"""Injectable clocks for the serving stack.

``serving/`` (including ``serving/frontdoor/``) is wall-clock-free by
lint rule (``repo-tick-wallclock``): anything that needs real time gets
a clock *injected* from here, the same pattern ``EngineWatchdog`` uses.
Two implementations share the one-method protocol (a zero-arg callable
returning monotonic seconds):

* :class:`SystemClock` — wraps ``time.monotonic`` for production.
* :class:`ManualClock` — a deterministic clock the caller advances
  explicitly; tests and CI ``--check`` gates use it so wall-clock→tick
  SLA mapping is a pure function (never actual wall clock).

Both expose ``granularity``: the coarsest interval the clock can
meaningfully resolve.  The SLA mapper quantizes client deadlines up to
granularity multiples before converting to ticks, so a deadline can
never round *down* below what the client asked for.
"""

from __future__ import annotations

import time


class SystemClock:
    """Monotonic wall clock (production).  ``granularity`` is the
    interval below which scheduling jitter makes finer deadlines
    meaningless, not the hardware timer resolution."""

    def __init__(self, granularity: float = 1e-3):
        if granularity <= 0.0:
            raise ValueError(f"granularity must be > 0, got {granularity}")
        self.granularity = granularity

    def __call__(self) -> float:
        return time.monotonic()


class ManualClock:
    """Deterministic clock: time moves only when the test/bench calls
    :meth:`advance`.  Makes everything downstream of a clock injection
    (SLA mapping, tick-duration EMAs, watchdog deadlines) replayable
    bit-for-bit."""

    def __init__(self, start: float = 0.0, granularity: float = 1e-3):
        if granularity <= 0.0:
            raise ValueError(f"granularity must be > 0, got {granularity}")
        self.now = float(start)
        self.granularity = granularity

    def advance(self, dt: float) -> float:
        if dt < 0.0:
            raise ValueError(f"time cannot move backwards (dt={dt})")
        self.now += dt
        return self.now

    def __call__(self) -> float:
        return self.now
