"""Fault tolerance for 1000+ node runs (simulated on CPU; the policies are
the deliverable — a real deployment swaps the heartbeat transport).

* :class:`ClusterMonitor` — heartbeat table; a node missing ``timeout``
  seconds of beats is declared failed.  In this container failures are
  *injected* (tests/benchmarks call ``inject_failure``), which exercises
  the same code path a gRPC heartbeat service would drive.
* :class:`ElasticMeshManager` — given the surviving device count, rebuilds
  the largest valid (data, model) mesh (model axis preserved — TP degree is
  a property of the checkpointed layout; data axis shrinks), and re-shards
  the train state from checkpoint onto the new mesh.
* :class:`StragglerPolicy` — per-step deadline from an EMA of step times;
  a shard exceeding ``k * ema`` is marked a straggler.  Mitigation in data
  loading: every shard can deterministically regenerate any other shard's
  batch (see data/pipeline.py), so reassignment is metadata-only.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np


class ClusterMonitor:
    def __init__(self, n_nodes: int, timeout: float = 30.0):
        self.n_nodes = n_nodes
        self.timeout = timeout
        now = time.monotonic()
        self._last_beat = {i: now for i in range(n_nodes)}
        self._failed: set[int] = set()

    def heartbeat(self, node: int, t: float | None = None):
        if node not in self._failed:
            self._last_beat[node] = t if t is not None else time.monotonic()

    def inject_failure(self, node: int):
        self._failed.add(node)
        self._last_beat[node] = -float("inf")

    def recover(self, node: int):
        self._failed.discard(node)
        self._last_beat[node] = time.monotonic()

    def failed_nodes(self, now: float | None = None) -> set[int]:
        now = now if now is not None else time.monotonic()
        out = set(self._failed)
        for node, beat in self._last_beat.items():
            if now - beat > self.timeout:
                out.add(node)
        return out

    def healthy_count(self) -> int:
        return self.n_nodes - len(self.failed_nodes())


@dataclasses.dataclass
class ElasticDecision:
    data: int
    model: int
    dropped_nodes: int

    @property
    def devices(self) -> int:
        return self.data * self.model


class ElasticMeshManager:
    """Largest valid mesh from surviving devices, preserving the TP degree."""

    def __init__(self, model_parallel: int, devices_per_node: int = 4):
        self.model_parallel = model_parallel
        self.devices_per_node = devices_per_node

    def decide(self, healthy_nodes: int) -> ElasticDecision:
        devices = healthy_nodes * self.devices_per_node
        tp = self.model_parallel
        if devices < tp:
            raise RuntimeError(
                f"{devices} devices cannot host model-parallel degree {tp}")
        data = devices // tp
        return ElasticDecision(data=data, model=tp,
                               dropped_nodes=0)

    def rebuild_mesh(self, decision: ElasticDecision, devices=None):
        devices = devices if devices is not None else jax.devices()
        usable = decision.data * decision.model
        import numpy as _np
        arr = _np.array(devices[:usable]).reshape(decision.data,
                                                  decision.model)
        from jax.sharding import Mesh
        return Mesh(arr, ("data", "model"))


class StragglerPolicy:
    """EMA-deadline detection + deterministic shard reassignment."""

    def __init__(self, slack: float = 2.5, ema_alpha: float = 0.1):
        self.slack = slack
        self.ema_alpha = ema_alpha
        self.ema: float | None = None

    def observe(self, step_time: float):
        if self.ema is None:
            self.ema = step_time
        else:
            self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * step_time

    def deadline(self) -> float | None:
        return None if self.ema is None else self.slack * self.ema

    def is_straggler(self, step_time: float) -> bool:
        d = self.deadline()
        return d is not None and step_time > d

    @staticmethod
    def reassign_shard(failed_shard: int, healthy_shards: list[int],
                       step: int) -> int:
        """Deterministic donor for a straggler's data shard (all hosts agree
        without communication: pure function of (step, failed_shard))."""
        return healthy_shards[(failed_shard + step) % len(healthy_shards)]
