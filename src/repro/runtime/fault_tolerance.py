"""Fault tolerance for 1000+ node runs (simulated on CPU; the policies are
the deliverable — a real deployment swaps the heartbeat transport).

* :class:`ClusterMonitor` — heartbeat table; a node missing ``timeout``
  seconds of beats is declared failed.  In this container failures are
  *injected* (tests/benchmarks call ``inject_failure``), which exercises
  the same code path a gRPC heartbeat service would drive.
* :class:`ElasticMeshManager` — given the surviving device count, rebuilds
  the largest valid (data, model) mesh (model axis preserved — TP degree is
  a property of the checkpointed layout; data axis shrinks), and re-shards
  the train state from checkpoint onto the new mesh.
* :class:`StragglerPolicy` — per-step deadline from an EMA of step times;
  a shard exceeding ``k * ema`` is marked a straggler.  Mitigation in data
  loading: every shard can deterministically regenerate any other shard's
  batch (see data/pipeline.py), so reassignment is metadata-only.
* :class:`EngineWatchdog` — the serving-side consumer of
  :class:`StragglerPolicy`: wraps ``PagedEngine.step()`` and raises
  :class:`StuckTickError` when a tick blows past the EMA deadline (a hung
  kernel or wedged scheduler stalls the whole engine otherwise).  The
  watchdog lives HERE, not in ``serving/``: engine tick paths are
  tick-indexed and wall-clock-free by lint rule (``repo-tick-wallclock``,
  docs/robustness.md), so the one component that legitimately reads a
  clock wraps the engine from outside — with the clock injected, so tests
  never assert on real ``time.monotonic``.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np


class ClusterMonitor:
    def __init__(self, n_nodes: int, timeout: float = 30.0,
                 clock=time.monotonic):
        self.n_nodes = n_nodes
        self.timeout = timeout
        self._clock = clock
        now = self._clock()
        self._last_beat = {i: now for i in range(n_nodes)}
        self._failed: set[int] = set()

    def heartbeat(self, node: int, t: float | None = None):
        if node not in self._failed:
            self._last_beat[node] = t if t is not None else self._clock()

    def inject_failure(self, node: int):
        self._failed.add(node)
        self._last_beat[node] = -float("inf")

    def recover(self, node: int):
        self._failed.discard(node)
        self._last_beat[node] = self._clock()

    def failed_nodes(self, now: float | None = None) -> set[int]:
        now = now if now is not None else self._clock()
        out = set(self._failed)
        for node, beat in self._last_beat.items():
            if now - beat > self.timeout:
                out.add(node)
        return out

    def healthy_count(self) -> int:
        return self.n_nodes - len(self.failed_nodes())


@dataclasses.dataclass
class ElasticDecision:
    data: int
    model: int
    dropped_nodes: int

    @property
    def devices(self) -> int:
        return self.data * self.model


class ElasticMeshManager:
    """Largest valid mesh from surviving devices, preserving the TP degree."""

    def __init__(self, model_parallel: int, devices_per_node: int = 4):
        self.model_parallel = model_parallel
        self.devices_per_node = devices_per_node

    def decide(self, healthy_nodes: int) -> ElasticDecision:
        devices = healthy_nodes * self.devices_per_node
        tp = self.model_parallel
        if devices < tp:
            raise RuntimeError(
                f"{devices} devices cannot host model-parallel degree {tp}")
        data = devices // tp
        return ElasticDecision(data=data, model=tp,
                               dropped_nodes=0)

    def rebuild_mesh(self, decision: ElasticDecision, devices=None):
        devices = devices if devices is not None else jax.devices()
        usable = decision.data * decision.model
        import numpy as _np
        arr = _np.array(devices[:usable]).reshape(decision.data,
                                                  decision.model)
        from jax.sharding import Mesh
        return Mesh(arr, ("data", "model"))


class StragglerPolicy:
    """EMA-deadline detection + deterministic shard reassignment."""

    def __init__(self, slack: float = 2.5, ema_alpha: float = 0.1):
        self.slack = slack
        self.ema_alpha = ema_alpha
        self.ema: float | None = None

    def observe(self, step_time: float):
        if self.ema is None:
            self.ema = step_time
        else:
            self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * step_time

    def deadline(self) -> float | None:
        return None if self.ema is None else self.slack * self.ema

    def is_straggler(self, step_time: float) -> bool:
        d = self.deadline()
        return d is not None and step_time > d

    @staticmethod
    def reassign_shard(failed_shard: int, healthy_shards: list[int],
                       step: int) -> int:
        """Deterministic donor for a straggler's data shard (all hosts agree
        without communication: pure function of (step, failed_shard))."""
        return healthy_shards[(failed_shard + step) % len(healthy_shards)]


class StuckTickError(RuntimeError):
    """A serving engine tick exceeded the watchdog's EMA deadline — a
    hung kernel, a wedged allocator loop, anything that stalls the tick.
    The process supervisor's cue to kill and restore from the latest
    crash snapshot (docs/robustness.md)."""


class EngineWatchdog:
    """Stuck-tick watchdog for a serving engine.

    Wraps ``engine.step()``: each tick is timed, fed to a
    :class:`StragglerPolicy` EMA, and compared against the policy's
    deadline (``slack * ema``).  A tick that blows the deadline raises
    :class:`StuckTickError` — the ONLY wall-clock-driven decision in the
    serving stack, which is why it wraps the engine from ``runtime/``
    instead of living in a tick path (serving/ is wall-clock-free by
    lint).  The clock is injected so tests drive it with a fake counter
    and never assert against real ``time.monotonic``.

    ``warmup`` ticks are observed but never flagged: the first ticks of a
    serve are jit compiles, orders of magnitude slower than steady state,
    and must seed the EMA without tripping it."""

    def __init__(self, engine, policy: StragglerPolicy | None = None,
                 clock=time.monotonic, warmup: int = 8):
        if warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {warmup}")
        self.engine = engine
        self.policy = policy if policy is not None else StragglerPolicy()
        self.clock = clock
        self.warmup = warmup
        self.ticks_seen = 0
        self.last_tick_time: float | None = None

    def step(self) -> bool:
        t0 = self.clock()
        alive = self.engine.step()
        dt = self.clock() - t0
        self.last_tick_time = dt
        self.ticks_seen += 1
        # Check against the deadline BEFORE this tick joins the EMA: a
        # monster tick must not dilute the very deadline meant to catch it.
        if (self.ticks_seen > self.warmup
                and self.policy.is_straggler(dt)):
            raise StuckTickError(
                f"engine tick {self.ticks_seen} took {dt:.4f}s, deadline "
                f"{self.policy.deadline():.4f}s "
                f"(ema {self.policy.ema:.4f}s x slack "
                f"{self.policy.slack})")
        self.policy.observe(dt)
        return alive

    def run(self, seed: int = 0) -> None:
        """Drain the engine under watchdog supervision (the watchdog's
        analogue of ``engine.run``)."""
        self.engine.begin(seed)
        while self.engine.pending():
            self.step()
