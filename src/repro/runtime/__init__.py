"""Cluster runtime: failure detection, elastic re-meshing, stragglers,
the serving stuck-tick watchdog, and injectable clocks."""

from repro.runtime.clock import ManualClock, SystemClock  # noqa: F401
from repro.runtime.fault_tolerance import (  # noqa: F401
    ClusterMonitor, ElasticMeshManager, EngineWatchdog, StragglerPolicy,
    StuckTickError,
)
