"""Cluster runtime: failure detection, elastic re-meshing, stragglers."""

from repro.runtime.fault_tolerance import (  # noqa: F401
    ClusterMonitor, ElasticMeshManager, StragglerPolicy,
)
