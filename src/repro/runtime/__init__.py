"""Cluster runtime: failure detection, elastic re-meshing, stragglers,
and the serving stuck-tick watchdog."""

from repro.runtime.fault_tolerance import (  # noqa: F401
    ClusterMonitor, ElasticMeshManager, EngineWatchdog, StragglerPolicy,
    StuckTickError,
)
