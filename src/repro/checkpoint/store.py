"""Checkpointing: flattened-pytree npz shards, atomic promote, async save.

Layout:  <dir>/step_<N>/shard_<i>.npz + MANIFEST.json
* **atomic** — written to ``step_<N>.tmp`` then ``os.replace``d, so a crash
  mid-save never corrupts the latest checkpoint; resume scans for the
  newest directory with a valid manifest.
* **async**  — ``save_async`` snapshots to host memory synchronously
  (cheap) and writes in a background thread, overlapping I/O with the next
  training steps (step-level fault-tolerance requirement).
* **sharded** — leaves are split round-robin across ``n_shards`` files so
  multi-host writers could each own a subset; on one host it bounds file
  size.  Structure (treedef) is stored in the manifest via leaf paths, so
  loading is resilient to unrelated code motion.

The same stage-then-promote discipline backs **serving crash snapshots**
(:func:`save_snapshot` / :func:`load_snapshot`): a single JSON document
per step (``PagedEngine.snapshot()``), with an ``interrupt`` seam for
deterministic fault injection between stage and promote — a reader can
never observe a torn snapshot, and :func:`gc_staging` reclaims orphans
(docs/robustness.md).
"""

from __future__ import annotations

import json
import os
import tempfile
import threading

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in kp) for kp, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves


def _stage(directory: str, step: int) -> str:
    """Create a staging dir for one atomic write.  Unique tmp dir per
    save: concurrent writers of the same step (async saver racing a sync
    one) must not share a staging directory, or the loser's os.replace
    finds its tmp already promoted away.  mkdtemp creates 0700; restore
    umask-derived permissions since this inode is promoted to the final
    directory (shared readers must list it)."""
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=f"step_{step:08d}.tmp.")
    umask = os.umask(0)
    os.umask(umask)
    os.chmod(tmp, 0o777 & ~umask)
    return tmp


def _promote(tmp: str, final: str) -> None:
    """Atomically publish a fully-written staging dir.  Readers either
    see the previous complete state or the new one — never a torn write;
    a crash before this point leaves only an orphaned ``.tmp`` dir
    (reclaimed by :func:`gc_staging`)."""
    import shutil
    if os.path.exists(final):
        # ignore_errors: a concurrent re-save of the same step may be
        # removing the same tree; whoever's replace lands next wins.
        shutil.rmtree(final, ignore_errors=True)
    try:
        os.replace(tmp, final)
    except OSError:
        if not os.path.isdir(final):
            raise        # real I/O failure: keep the staging dir, surface it
        # A concurrent writer promoted the same step between our rmtree and
        # replace; its copy is equivalent — drop our staging copy.
        shutil.rmtree(tmp, ignore_errors=True)


def save_checkpoint(tree, directory: str, step: int, n_shards: int = 4):
    paths, leaves = _leaf_paths(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = _stage(directory, step)
    shards: list[dict] = [dict() for _ in range(n_shards)]
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        shards[i % n_shards][p] = np.asarray(leaf)
    for si, shard in enumerate(shards):
        # npz keys cannot contain '/': escape.
        np.savez(os.path.join(tmp, f"shard_{si}.npz"),
                 **{k.replace("/", "__"): v for k, v in shard.items()})
    manifest = {"step": step, "n_shards": n_shards, "paths": paths}
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    _promote(tmp, final)
    return final


def save_snapshot(obj, directory: str, step: int, interrupt=None) -> str:
    """Atomically persist one JSON-serializable object (an engine crash
    snapshot — ``PagedEngine.snapshot()``) under the same
    stage-then-promote discipline as checkpoints: ``step_<N>/`` with a
    MANIFEST.json, so :func:`latest_step` and GC treat snapshots and
    checkpoints uniformly.

    ``interrupt`` is the fault-injection seam (serving/chaos.py): called
    after the staging write completes but *before* the atomic promote.
    If it raises, the write dies exactly where a host crash mid-save
    would — the staging dir is orphaned, the previously promoted snapshot
    remains the visible latest, and no reader can ever observe the torn
    write.  :func:`gc_staging` reclaims the orphan."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = _stage(directory, step)
    with open(os.path.join(tmp, "snapshot.json"), "w") as f:
        json.dump(obj, f)
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump({"step": step, "kind": "snapshot"}, f)
    if interrupt is not None:
        interrupt()
    _promote(tmp, final)
    return final


def load_snapshot(directory: str, step: int | None = None):
    """Load a :func:`save_snapshot` object.  step=None → latest promoted
    (staging orphans are invisible: :func:`latest_step` skips ``.tmp``).
    Returns ``(obj, step)``."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no snapshot under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "snapshot.json")) as f:
        return json.load(f), step


def gc_staging(directory: str, grace: float = 600.0) -> list[str]:
    """Reclaim ``.tmp`` staging dirs orphaned by a crashed or interrupted
    writer (unique mkdtemp names are never reused, so nothing else will).
    ``grace`` guards in-flight saves by mtime age; a single-writer caller
    that *knows* its own write just died may pass 0.  Returns the names
    reclaimed."""
    import shutil
    import time
    if not os.path.isdir(directory):
        return []
    reclaimed = []
    for n in os.listdir(directory):
        if n.startswith("step_") and ".tmp" in n:
            p = os.path.join(directory, n)
            try:
                if time.time() - os.path.getmtime(p) >= grace:
                    shutil.rmtree(p, ignore_errors=True)
                    reclaimed.append(n)
            except OSError:
                pass
    return reclaimed


def load_checkpoint(tree_like, directory: str, step: int | None = None):
    """Restore into the structure of ``tree_like``.  step=None → latest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    data: dict[str, np.ndarray] = {}
    for si in range(manifest["n_shards"]):
        with np.load(os.path.join(d, f"shard_{si}.npz")) as z:
            for k in z.files:
                data[k.replace("__", "/")] = z[k]
    paths, leaves = _leaf_paths(tree_like)
    new_leaves = []
    for p, ref in zip(paths, leaves):
        arr = data[p]
        new_leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
                s = int(name.split("_")[1])
                best = s if best is None or s > best else best
    return best


class CheckpointManager:
    """Async save + retention.  ``wait()`` before process exit."""

    def __init__(self, directory: str, keep: int = 3, n_shards: int = 4):
        self.directory = directory
        self.keep = keep
        self.n_shards = n_shards
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, tree, step: int):
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now
        self.wait()

        def worker():
            save_checkpoint(host_tree, self.directory, step, self.n_shards)
            self._gc()

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def save_sync(self, tree, step: int):
        save_checkpoint(jax.tree_util.tree_map(np.asarray, tree),
                        self.directory, step, self.n_shards)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, tree_like, step: int | None = None):
        return load_checkpoint(tree_like, self.directory, step)

    def _gc(self):
        import shutil
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp" not in n)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
        # Staging dirs orphaned by a crash — reclaim once safely older
        # than any in-flight save could be.
        gc_staging(self.directory, grace=600.0)
