"""Checkpointing: flattened-pytree npz shards, atomic promote, async save.

Layout:  <dir>/step_<N>/shard_<i>.npz + MANIFEST.json
* **atomic** — written to ``step_<N>.tmp`` then ``os.replace``d, so a crash
  mid-save never corrupts the latest checkpoint; resume scans for the
  newest directory with a valid manifest.
* **async**  — ``save_async`` snapshots to host memory synchronously
  (cheap) and writes in a background thread, overlapping I/O with the next
  training steps (step-level fault-tolerance requirement).
* **sharded** — leaves are split round-robin across ``n_shards`` files so
  multi-host writers could each own a subset; on one host it bounds file
  size.  Structure (treedef) is stored in the manifest via leaf paths, so
  loading is resilient to unrelated code motion.

The same stage-then-promote discipline backs **serving crash snapshots**
(:func:`save_snapshot` / :func:`load_snapshot`): a single JSON document
per step (``PagedEngine.snapshot()``), with an ``interrupt`` seam for
deterministic fault injection between stage and promote — a reader can
never observe a torn snapshot, and :func:`gc_staging` reclaims orphans
(docs/robustness.md).

It also backs the **persistent prefix store** (:func:`save_prefix_record`
/ :func:`load_prefix_record`): one promoted ``prefix_<digest>/`` dir per
registered prefix block — an npz of the block's f32 K/V/pos rows plus a
MANIFEST carrying the *full* token chain, which loads verify exactly
(keys are the token tuples themselves, so a digest collision can never
false-share KV; same rule as the in-pool registry).  A restarted or
scaled-out engine warms its prefix cache from this store instead of
re-prefilling system prompts (``docs/serving.md`` "Memory hierarchy").
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in kp) for kp, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves


def _stage_named(directory: str, name: str) -> str:
    """Create a staging dir for one atomic write of ``<directory>/<name>``.
    Unique tmp dir per save: concurrent writers of the same target (async
    saver racing a sync one) must not share a staging directory, or the
    loser's os.replace finds its tmp already promoted away.  mkdtemp
    creates 0700; restore umask-derived permissions since this inode is
    promoted to the final directory (shared readers must list it)."""
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=f"{name}.tmp.")
    umask = os.umask(0)
    os.umask(umask)
    os.chmod(tmp, 0o777 & ~umask)
    return tmp


def _stage(directory: str, step: int) -> str:
    return _stage_named(directory, f"step_{step:08d}")


def _promote(tmp: str, final: str) -> None:
    """Atomically publish a fully-written staging dir.  Readers either
    see the previous complete state or the new one — never a torn write;
    a crash before this point leaves only an orphaned ``.tmp`` dir
    (reclaimed by :func:`gc_staging`)."""
    import shutil
    if os.path.exists(final):
        # ignore_errors: a concurrent re-save of the same step may be
        # removing the same tree; whoever's replace lands next wins.
        shutil.rmtree(final, ignore_errors=True)
    try:
        os.replace(tmp, final)
    except OSError:
        if not os.path.isdir(final):
            raise        # real I/O failure: keep the staging dir, surface it
        # A concurrent writer promoted the same step between our rmtree and
        # replace; its copy is equivalent — drop our staging copy.
        shutil.rmtree(tmp, ignore_errors=True)


def save_checkpoint(tree, directory: str, step: int, n_shards: int = 4):
    paths, leaves = _leaf_paths(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = _stage(directory, step)
    shards: list[dict] = [dict() for _ in range(n_shards)]
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        shards[i % n_shards][p] = np.asarray(leaf)
    for si, shard in enumerate(shards):
        # npz keys cannot contain '/': escape.
        np.savez(os.path.join(tmp, f"shard_{si}.npz"),
                 **{k.replace("/", "__"): v for k, v in shard.items()})
    manifest = {"step": step, "n_shards": n_shards, "paths": paths}
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    _promote(tmp, final)
    return final


def save_snapshot(obj, directory: str, step: int, interrupt=None) -> str:
    """Atomically persist one JSON-serializable object (an engine crash
    snapshot — ``PagedEngine.snapshot()``) under the same
    stage-then-promote discipline as checkpoints: ``step_<N>/`` with a
    MANIFEST.json, so :func:`latest_step` and GC treat snapshots and
    checkpoints uniformly.

    ``interrupt`` is the fault-injection seam (serving/chaos.py): called
    after the staging write completes but *before* the atomic promote.
    If it raises, the write dies exactly where a host crash mid-save
    would — the staging dir is orphaned, the previously promoted snapshot
    remains the visible latest, and no reader can ever observe the torn
    write.  :func:`gc_staging` reclaims the orphan."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = _stage(directory, step)
    with open(os.path.join(tmp, "snapshot.json"), "w") as f:
        json.dump(obj, f)
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump({"step": step, "kind": "snapshot"}, f)
    if interrupt is not None:
        interrupt()
    _promote(tmp, final)
    return final


def load_snapshot(directory: str, step: int | None = None):
    """Load a :func:`save_snapshot` object.  step=None → latest promoted
    (staging orphans are invisible: :func:`latest_step` skips ``.tmp``).
    Returns ``(obj, step)``."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no snapshot under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "snapshot.json")) as f:
        return json.load(f), step


def gc_staging(directory: str, grace: float = 600.0) -> list[str]:
    """Reclaim ``.tmp`` staging dirs orphaned by a crashed or interrupted
    writer (unique mkdtemp names are never reused, so nothing else will).
    ``grace`` guards in-flight saves by mtime age; a single-writer caller
    that *knows* its own write just died may pass 0.  Returns the names
    reclaimed."""
    import shutil
    import time
    if not os.path.isdir(directory):
        return []
    reclaimed = []
    for n in os.listdir(directory):
        if ((n.startswith("step_") or n.startswith("prefix_"))
                and ".tmp" in n):
            p = os.path.join(directory, n)
            try:
                if time.time() - os.path.getmtime(p) >= grace:
                    shutil.rmtree(p, ignore_errors=True)
                    reclaimed.append(n)
            except OSError:
                pass
    return reclaimed


# ---------------------------------------------------------------------------
# persistent prefix store (the disk rung of the serving memory hierarchy)
# ---------------------------------------------------------------------------

def _prefix_digest(chain) -> str:
    payload = json.dumps([int(t) for t in chain]).encode()
    return hashlib.sha256(payload).hexdigest()[:32]


def prefix_record_name(chain) -> str:
    """Directory name for one stored prefix block.  The digest is only a
    filename: the full chain lives in the MANIFEST and loads verify it
    exactly, so a collision can at worst miss — never false-share."""
    return f"prefix_{_prefix_digest(chain)}"


def save_prefix_record(directory: str, chain, layers,
                       interrupt=None) -> str:
    """Atomically persist one registered prefix block under its token
    chain key.

    ``layers`` is a list (one entry per paged attention layer) of dicts
    of host arrays — the block's f32 ``k``/``v`` rows and ``pos`` plane,
    exactly as :func:`repro.models.attention.extract_block_rows` emits
    them for a single block.  Packed ``kq`` planes and amax scales are
    deliberately NOT stored: the loading engine re-derives its own quant
    grid through the ordinary amax write rule, so a record is valid
    forever regardless of what the writing engine's scales were.

    First writer wins: re-saving an already-promoted chain is a no-op
    (content under the same chain is identical by construction — same
    rule as the in-pool registry).  ``interrupt`` is the deterministic
    fault seam (``checkpoint_interrupt`` chaos events), called after the
    staging write but before the atomic promote: if it raises, the torn
    record is an invisible ``.tmp`` orphan for :func:`gc_staging`.
    """
    chain = [int(t) for t in chain]
    name = prefix_record_name(chain)
    final = os.path.join(directory, name)
    if os.path.isdir(final):
        return final
    tmp = _stage_named(directory, name)
    flat = {}
    for i, layer in enumerate(layers):
        for field, arr in layer.items():
            flat[f"L{i}__{field}"] = np.asarray(arr)
    np.savez(os.path.join(tmp, "record.npz"), **flat)
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump({"kind": "prefix", "chain": chain,
                   "n_layers": len(layers)}, f)
    if interrupt is not None:
        interrupt()
    _promote(tmp, final)
    return final


def load_prefix_record(directory: str, chain):
    """Load the layer arrays stored for ``chain``, or None on a miss.
    The MANIFEST's full token chain must match exactly — a digest
    collision (or a half-matching store) reads as a miss, never as
    another prefix's KV."""
    chain = [int(t) for t in chain]
    d = os.path.join(directory, prefix_record_name(chain))
    manifest_path = os.path.join(d, "MANIFEST.json")
    if not os.path.isfile(manifest_path):
        return None
    with open(manifest_path) as f:
        manifest = json.load(f)
    if manifest.get("kind") != "prefix" or manifest.get("chain") != chain:
        return None
    layers = [dict() for _ in range(manifest["n_layers"])]
    with np.load(os.path.join(d, "record.npz")) as z:
        for k in z.files:
            li, field = k.split("__", 1)
            layers[int(li[1:])][field] = z[k]
    return layers


def list_prefix_records(directory: str) -> list[list[int]]:
    """Token chains of every promoted prefix record (staging orphans are
    invisible), in deterministic digest order."""
    if not os.path.isdir(directory):
        return []
    chains = []
    for n in sorted(os.listdir(directory)):
        if not n.startswith("prefix_") or ".tmp" in n:
            continue
        manifest_path = os.path.join(directory, n, "MANIFEST.json")
        if not os.path.isfile(manifest_path):
            continue
        with open(manifest_path) as f:
            manifest = json.load(f)
        if manifest.get("kind") == "prefix":
            chains.append(list(manifest["chain"]))
    return chains


def prefix_store_bytes(directory: str) -> int:
    """On-disk payload bytes of all promoted prefix records (the
    ``disk_prefix_bytes`` field of ``PagedEngine.memory_report``)."""
    if not os.path.isdir(directory):
        return 0
    total = 0
    for n in os.listdir(directory):
        if not n.startswith("prefix_") or ".tmp" in n:
            continue
        p = os.path.join(directory, n, "record.npz")
        try:
            total += os.path.getsize(p)
        except OSError:
            pass
    return total


def load_checkpoint(tree_like, directory: str, step: int | None = None):
    """Restore into the structure of ``tree_like``.  step=None → latest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    data: dict[str, np.ndarray] = {}
    for si in range(manifest["n_shards"]):
        with np.load(os.path.join(d, f"shard_{si}.npz")) as z:
            for k in z.files:
                data[k.replace("__", "/")] = z[k]
    paths, leaves = _leaf_paths(tree_like)
    new_leaves = []
    for p, ref in zip(paths, leaves):
        arr = data[p]
        new_leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
                s = int(name.split("_")[1])
                best = s if best is None or s > best else best
    return best


class CheckpointManager:
    """Async save + retention.  ``wait()`` before process exit."""

    def __init__(self, directory: str, keep: int = 3, n_shards: int = 4):
        self.directory = directory
        self.keep = keep
        self.n_shards = n_shards
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, tree, step: int):
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now
        self.wait()

        def worker():
            save_checkpoint(host_tree, self.directory, step, self.n_shards)
            self._gc()

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def save_sync(self, tree, step: int):
        save_checkpoint(jax.tree_util.tree_map(np.asarray, tree),
                        self.directory, step, self.n_shards)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, tree_like, step: int | None = None):
        return load_checkpoint(tree_like, self.directory, step)

    def _gc(self):
        import shutil
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp" not in n)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
        # Staging dirs orphaned by a crash — reclaim once safely older
        # than any in-flight save could be.
        gc_staging(self.directory, grace=600.0)
