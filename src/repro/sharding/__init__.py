"""Logical-axis sharding: rules mapping named axes → mesh axes (GSPMD)."""

from repro.sharding.api import (  # noqa: F401
    MeshRules, constrain, current_rules, use_rules,
)
