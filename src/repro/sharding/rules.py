"""Concrete parallelism layouts for the production meshes.

Parameter placement (path-pattern → logical axes; first match wins) and the
activation axis map, composing:

* **DP**    — batch over ("pod", "data")
* **FSDP**  — parameters' embed axis over "data" (ZeRO-3: jit inserts
              all-gathers before use, reduce-scatters after backward)
* **TP**    — heads / ffn / vocab / expert axes over "model" (Megatron split)
* **EP**    — MoE expert axis over "model" (divisibility decides EP vs
              expert-TP per config — see models/moe.py)
* **SP**    — optional: sequence axis over "model" between blocks (long ctx)

Indivisible dims fall back to replication automatically (api.MeshRules).
"""

from __future__ import annotations

from repro.sharding.api import MeshRules

# Path-pattern parameter rules.  Axis names refer to AXIS_MAP keys below.
PARAM_RULES: tuple[tuple[str, tuple], ...] = (
    # Embeddings / unembeddings: vocab sharded over model (TP), embed over fsdp.
    (r"embed/table$", ("vocab", "fsdp")),
    (r"unembed/w$", ("fsdp", "vocab")),
    (r"mtp_head/w$", ("fsdp", "vocab")),
    # Attention projections: heads over model, d_model over fsdp.
    (r"attn/wq/w$", ("fsdp", "heads", None)),
    (r"attn/wk/w$", ("fsdp", "kv_heads", None)),
    (r"attn/wv/w$", ("fsdp", "kv_heads", None)),
    (r"attn/wq/b$", ("heads", None)),
    (r"attn/w[kv]/b$", ("kv_heads", None)),
    (r"attn/wo/w$", ("heads_flat", "fsdp")),
    # MLA projections (deepseek): latent ranks replicated, heads over model.
    (r"mla/wq_a/w$", ("fsdp", None)),
    (r"mla/wq_b/w$", (None, "heads", None)),
    (r"mla/wkv_a/w$", ("fsdp", None)),
    (r"mla/wkv_b/w$", (None, "heads", None)),
    (r"mla/wo/w$", ("heads_flat", "fsdp")),
    # Dense MLPs: ffn over model (Megatron).
    (r"(mlp|ffn|shared)/wi(_gate|_up)?/w$", ("fsdp", "ffn")),
    (r"(mlp|ffn|shared)/wo/w$", ("ffn", "fsdp")),
    # MoE experts: expert axis over model (EP) when divisible, else the
    # per-expert ffn axis picks up "model" via moe.py's expert-TP path.
    (r"moe/router/w$", ("fsdp", None)),
    (r"moe/wi(_gate|_up)?$", ("expert", "expert_dmodel", "expert_ffn")),
    (r"moe/wo$", ("expert", "expert_ffn", "expert_dmodel")),
    # SSM (mamba2): inner channels over model.
    (r"ssm/in_proj/w$", ("fsdp", "ffn")),
    (r"ssm/out_proj/w$", ("ffn", "fsdp")),
    (r"ssm/(conv_w|conv_b|A_log|D|dt_bias)$", ("ffn",)),
    (r"ssm/norm/scale$", ("ffn",)),
    # RG-LRU (recurrentgemma): recurrent width over model.
    (r"rglru/(in_x|in_gate)/w$", ("fsdp", "ffn")),
    (r"rglru/out/w$", ("ffn", "fsdp")),
    (r"rglru/(a_param|conv_w|conv_b)$", ("ffn",)),
    (r"rglru/(rg|ig)/w$", (None, "ffn", None)),
    # Norm scales replicated.
    (r"(scale|bias)$", (None,)),
)

# Logical-axis → mesh-axis maps.
AXIS_MAP_1POD = {
    "batch": "data",
    "fsdp": "data",
    "embed": None,
    "seq": None,
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": "model",
    # attention output entering wo: keep heads sharded so the wo matmul is
    # the Megatron partial-product + psum against the heads_flat-sharded
    # weight.  (The serving map replicates this axis instead — bit-identity.)
    "heads_out": "model",
    "ffn": "model",
    "vocab": "model",
    "expert": "model",
    "expert_ffn": None,
    "seq_sp": "model",
    # decode KV caches are sharded along the *sequence* axis over "model"
    # (kv-head counts like 8 or 1 don't divide a 16-way axis; sequence
    # always does) — GSPMD turns the softmax/PV over the sharded axis into
    # small logit collectives instead of gathering the cache.
    "kv_seq": "model",
}

# Decode-cache leaf-name → logical axes (rank WITHOUT the scan-stack axis;
# a leading None is prepended automatically for stacked caches).
CACHE_RULES: dict[str, tuple] = {
    "k": ("batch", "kv_seq", None, None),
    "v": ("batch", "kv_seq", None, None),
    "c_kv": ("batch", "kv_seq", None),
    "k_pe": ("batch", "kv_seq", None),
    "conv": ("batch", None, "ffn"),
    "ssm": ("batch", None, None, None),
    "h": ("batch", "ffn"),
    "pos": ("kv_seq",),
    "length": (),
}

# Paged block-pool caches (models/attention.py init_cache(paged=...)) reuse
# some contiguous leaf names ("k", "v", "pos") at *pool* shapes, so they get
# their own rule table, selected by the presence of the "table" leaf in the
# same cache dict.  Pools are KV-head-sharded over "model" (per-head BESF
# attention needs no softmax collectives); the page axis is replicated so the
# host-side KVBlockPool allocator stays device-agnostic — one logical block
# id space, block tables and fill levels replicated across "model".  Slots
# ("batch") shard over "data".  Indivisible dims (MQA's single KV head on a
# multi-way model axis) silently replicate via MeshRules.pspec.
PAGED_CACHE_RULES: dict[str, tuple] = {
    "k": (None, None, "kv_heads", None),          # [nb, bs, Hkv, D]
    "v": (None, None, "kv_heads", None),
    "kq": (None, None, None, "kv_heads", None),   # [nb, bits, bs/8, Hkv, D]
    "k_amax": ("kv_heads",),                      # [Hkv]
    "v_amax": ("kv_heads",),
    "pos": (None, None),                          # [nb, bs] fill levels
    "table": ("batch", None),                     # [slots, MB] block tables
    "length": ("batch",),                         # [slots]
}


def cache_pspecs(rules: MeshRules, cache_tree):
    """PartitionSpec tree for a decode-cache pytree.

    Handles scan stacking (a leading axis is replicated) and routes paged
    cache dicts — recognised by their "table" leaf — through
    PAGED_CACHE_RULES, since paged pool leaves reuse contiguous leaf names
    at different geometries."""

    def spec_for(axes, leaf):
        if leaf.ndim == len(axes) + 1:           # scan-stacked
            axes = (None,) + axes
        return rules.pspec(axes, leaf.shape)

    def walk(node):
        if isinstance(node, dict):
            table = PAGED_CACHE_RULES if "table" in node else CACHE_RULES
            out = {}
            for name, sub in node.items():
                if isinstance(sub, (dict, list, tuple)):
                    out[name] = walk(sub)
                elif table.get(name) is None:
                    out[name] = rules.pspec([None] * sub.ndim, sub.shape)
                else:
                    out[name] = spec_for(table[name], sub)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(sub) for sub in node)
        return rules.pspec([None] * node.ndim, node.shape)

    return walk(cache_tree)

def cache_shardings(rules: MeshRules, cache_tree):
    """NamedSharding tree (device_put-ready) for a decode-cache pytree."""
    from jax.sharding import NamedSharding
    specs = cache_pspecs(rules, cache_tree)

    def walk(spec_node, cache_node):
        if isinstance(cache_node, dict):
            return {k: walk(spec_node[k], cache_node[k]) for k in cache_node}
        if isinstance(cache_node, (list, tuple)):
            return type(cache_node)(
                walk(s, c) for s, c in zip(spec_node, cache_node))
        return NamedSharding(rules.mesh, spec_node)

    return walk(specs, cache_tree)


# Inference-only axis map for the mesh-sharded paged serving engine
# (ServeConfig.mesh).  Deliberately narrower than the training map: only
# axes whose sharding is pure data movement are mapped — slots ("batch")
# over "data", attention heads over "model" (per-head BESF + the paged
# pools, see PAGED_CACHE_RULES).  Every axis that any float *contraction*
# runs over (ffn hidden, flattened heads into wo, embed, vocab, kv_seq)
# stays replicated: sharding a contraction dim makes GSPMD psum partial
# products, which reassociates float adds and breaks the standing
# bit-identity invariant (sharded serving == single-device, docs/serving.md).
AXIS_MAP_SERVE = {
    "batch": "data",
    "fsdp": None,
    "embed": None,
    "seq": None,
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": None,
    "heads_out": None,
    "ffn": None,
    "vocab": None,
    "expert": None,
    "expert_ffn": None,
    "expert_dmodel": None,
    "seq_sp": None,
    "kv_seq": None,
}


def make_serve_rules(mesh) -> MeshRules:
    """MeshRules for bit-identical mesh-sharded serving (PagedEngine).

    Parameters are replicated (``param_rules=()`` — serving has no
    optimizer state to shard, and replicated weights keep every matmul's
    contraction in single-device summation order); activations shard over
    slots ("data") and attention heads ("model") only."""
    return MeshRules(mesh=mesh, axis_map=dict(AXIS_MAP_SERVE),
                     param_rules=())


AXIS_MAP_MULTIPOD = dict(AXIS_MAP_1POD, batch=("pod", "data"))


def make_rules(mesh, *, sequence_parallel: bool = False,
               fsdp: bool = True, moe_ep: bool | None = None,
               n_routed: int = 0, moe_resident: bool = False) -> MeshRules:
    """``moe_ep``: EP-able configs store expert weights with the hidden dim
    FSDP-sharded over "data" (gathered just-in-time by the MoE shard_map);
    expert-TP configs store the hidden dim over "model" permanently.  When
    left None it is derived from ``n_routed`` divisibility."""
    multi_pod = "pod" in mesh.shape
    axis_map = dict(AXIS_MAP_MULTIPOD if multi_pod else AXIS_MAP_1POD)
    if sequence_parallel:
        axis_map["seq"] = "model"
    if not fsdp:
        axis_map["fsdp"] = None
    if moe_ep is None:
        tp = mesh.shape.get("model", 1)
        moe_ep = bool(n_routed) and n_routed % tp == 0
    # EP:        wi [E→model, D, H→data]   (H gathered just-in-time)
    # expert-TP: wi [E, D→data, H→model]   (D gathered just-in-time)
    # Either way expert weights/optimizer state are ~(data×model)-sharded.
    axis_map["expert_ffn"] = "data" if moe_ep else "model"
    axis_map["expert_dmodel"] = None if moe_ep else "data"
    if not moe_ep:
        axis_map["expert"] = None
    if moe_resident:
        # decode: experts fully sharded over data×model, weights resident
        # (storage layout == the resident shard_map's in_specs).
        axis_map["expert"] = ("data", "model")
        axis_map["expert_ffn"] = None
        axis_map["expert_dmodel"] = None
    return MeshRules(mesh=mesh, axis_map=axis_map, param_rules=PARAM_RULES)
