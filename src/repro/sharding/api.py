"""Logical-axis → mesh-axis sharding rules (MaxText-style, mesh-agnostic).

Models name their activation axes logically (``constrain(x, "batch", None,
"embed")``); a :class:`MeshRules` context maps those names onto physical mesh
axes and inserts GSPMD sharding constraints.  With no active rules the model
runs unsharded — smoke tests on one CPU device never touch jax device state.

Key behaviours:
* **divisibility fallback** — a logical axis whose dim is not divisible by
  the product of its mapped mesh axes is silently replicated (e.g. granite's
  single KV head over a 16-way model axis).
* **composed axes** — a logical name may map to a tuple of mesh axes
  (``"batch" → ("pod", "data")``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import re
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: list["MeshRules"] = []


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    axis_map: dict[str, Any]            # logical name -> mesh axis | tuple | None
    param_rules: tuple[tuple[str, tuple], ...] = ()   # (path regex, logical axes)

    # -- axis resolution ---------------------------------------------------

    def _mesh_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        return math.prod(self.mesh.shape[a] for a in mesh_axes)

    def pspec(self, logical_axes: Sequence[str | None],
              shape: Sequence[int] | None = None) -> P:
        """PartitionSpec for the given logical axes (with divisibility check
        when ``shape`` is provided)."""
        entries = []
        for i, name in enumerate(logical_axes):
            mesh_axes = self.axis_map.get(name) if name else None
            if mesh_axes is not None and shape is not None:
                if shape[i] % self._mesh_size(mesh_axes) != 0:
                    mesh_axes = None          # replicate indivisible dims
            entries.append(mesh_axes)
        return P(*entries)

    def sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(logical_axes, shape))

    # -- parameter trees ----------------------------------------------------

    def param_pspec(self, path: str, shape: Sequence[int]) -> P:
        for pattern, axes in self.param_rules:
            if re.search(pattern, path):
                if len(shape) > len(axes):
                    # scan-over-layers stacking (and conv kernel dims)
                    # prepend unsharded leading axes so the rule's names
                    # line up with the parameter's trailing dims.
                    axes = (None,) * (len(shape) - len(axes)) + tuple(axes)
                return self.pspec(axes, shape)
        return P()

    def tree_pspecs(self, tree):
        """PartitionSpec tree for a parameter pytree (by '/'-joined path)."""
        def leaf_spec(path, leaf):
            pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            return self.param_pspec(pstr, leaf.shape)
        return jax.tree_util.tree_map_with_path(leaf_spec, tree)

    def tree_shardings(self, tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self.tree_pspecs(tree)
        )


def current_rules() -> MeshRules | None:
    return _ACTIVE[-1] if _ACTIVE else None


@contextlib.contextmanager
def use_rules(rules: MeshRules | None):
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Sharding constraint by logical axis names; no-op without active rules."""
    rules = current_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        return x
    spec = rules.pspec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
