"""Fused Sq-tiled paged BESF verify Pallas kernel — speculative decoding's
draft-block scorer.

This is the multi-query generalization of ``kernels/paged_decode.py``:
instead of one query per serving slot, a slot brings an Sq-token **draft
block** ([last sampled token, draft 1, ..., draft k]) and every query must
be scored exactly as the Sq=1 decode kernel would have scored it at that
position.  The payoff over running the decode kernel Sq times is the
paper's stage-fusion argument applied across the draft block:

* **One plane DMA per (page, round) for the whole block.**  The packed
  bit-plane page is fetched when *any* query's LATS state still wants it
  (union liveness) and then consumed by every live query — the prediction
  traffic of verifying k draft tokens is amortized to ~1x the Sq=1 cost
  instead of k+1 separate fetches.
* **Per-query LATS, bit for bit.**  Liveness, margins, prefix-max lower
  bounds, plane counts and survivors are tracked per (query, head):
  observables match the pure-JAX oracle
  ``core/besf.py:besf_attention_verify_paged`` — which routes each (slot,
  query) through the very ``_paged_decode_row`` the Sq=1 paths share —
  bit for bit (tested).  A query whose pages all died keeps its state
  frozen even while its neighbours keep fetching.
* **Causal intra-draft masking via per-query fill levels.**  Query i at
  absolute position p sees cached tokens ``t_pos <= p`` — earlier draft
  tokens (already scattered into the pool by the batched cache write) but
  never later ones.  Padding queries (a slot that proposed fewer than k
  drafts) ride along with fill level 0: every page is dead for them, they
  fetch nothing.
* **Early-terminated V, shared.**  A page's V is DMA'd once if at least
  one query has survivors; each query's online-softmax epilogue is
  predicated on its *own* survivors, exactly like the oracle.

Over-accumulation note: a query whose page died keeps receiving plane
deltas into the shared partial-score scratch (the plane was fetched for a
live neighbour).  This is unobservable — the oracle proves it: pruned
candidates' partials feed neither thresholds (frozen ``keep``), nor
``mlow`` (gated on the query's own page liveness), nor logits (survivors
require all ``bits`` rounds, in which case both versions accumulated every
plane).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quantization as qlib
from repro.core.besf import BitStopperConfig, PagedVerifyOutput, \
    paged_decode_prep
from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _paged_verify_kernel(
    # scalar-prefetch (SMEM)
    tables_ref,             # [B, MB] int32 — logical -> physical page
    # VMEM-blocked operands
    lengths_ref,            # [1, Sq] int32 — per-query fill level
    qpos_ref,               # [1, Sq] int32 — per-query absolute position
    q_ref,                  # [1, Sq*Hq, D] int32 — quantized draft queries
    mmin_ref,               # [bits, 1, Sq*Hq] f32 — LATS margin LUT (min)
    mmax_ref,               # [bits, 1, Sq*Hq] f32 — LATS margin LUT (max)
    st_ref,                 # [1, Sq*Hq] f32 — scale_total per (query, head)
    ar_ref,                 # [1, Sq*Hq] f32 — alpha * radius_int
    vs_ref,                 # [1, Hkv] f32 — V quant scale per KV head
    # HBM (manually DMA'd) pools
    kq_hbm,                 # [P, bits, bs8, Hkv, D] uint8 bit-plane pool
    v_hbm,                  # [P, bs, Hkv, Dv] V pool
    # outputs
    out_ref,                # [1, Sq*Hq, Dv]
    rounds_ref,             # [1, Sq, 1] int32 — planes fetched per query
    surv_ref,               # [1, Sq*Hq, bs] int8
    # scratch
    plane_ref,              # [2, bs8, Hkv, D] uint8 (double buffer)
    v_ref,                  # [bs, Hkv, Dv]
    partial_ref,            # [Sq*Hq, bs] int32
    mlow_ref,               # [Sq*Hq] f32 — LATS prefix max lower bound
    m_ref, l_ref, acc_ref,  # online softmax state, per (query, head)
    plane_sem, v_sem,       # DMA semaphores
    *,
    bits: int,
    page_size: int,
    n_queries: int,
    n_kv_heads: int,
    min_rounds: int,
    quantize_v: bool,
    window: int | None,
):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    bs = page_size
    bs8 = bs // 8
    Sq = n_queries
    SH = q_ref.shape[1]                                       # Sq * Hq
    Hq = SH // Sq
    D = q_ref.shape[2]
    G = Hq // n_kv_heads

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mlow_ref[...] = jnp.full_like(mlow_ref, NEG_INF)

    partial_ref[...] = jnp.zeros_like(partial_ref)

    phys = tables_ref[b, j]

    # Per-query validity of this page's token slots: causal against each
    # query's own position AND its own fill level (padding queries carry
    # length 0, making every page dead for them).
    t_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (Sq, bs), 1)
    q_pos = qpos_ref[0][:, None]                              # [Sq, 1]
    length = lengths_ref[0][:, None]
    valid_q = (t_pos <= q_pos) & (t_pos < length)             # [Sq, bs]
    if window is not None:
        valid_q &= t_pos > q_pos - window
    valid_b = jnp.repeat(valid_q, Hq, axis=0)                 # [Sq*Hq, bs]
    blk0_q = jnp.any(valid_q, axis=-1)                        # [Sq]

    alpha_radius = ar_ref[0]                                  # [Sq*Hq]
    qg = q_ref[0].astype(jnp.float32).reshape(Sq, n_kv_heads, G, D)

    def plane_weight(r):
        mag = jax.lax.shift_left(jnp.int32(1),
                                 (bits - 1 - r).astype(jnp.int32))
        return jnp.where(r == 0, -mag, mag)

    def start_plane_copy(r, slot):
        pltpu.make_async_copy(
            kq_hbm.at[phys, r], plane_ref.at[slot], plane_sem.at[slot],
        ).start()

    def wait_plane_copy(slot):
        pltpu.make_async_copy(
            kq_hbm.at[0, 0],                       # shape donor only
            plane_ref.at[slot], plane_sem.at[slot],
        ).wait()

    # BAP prefetch: plane 0 moves once if ANY query can reach this page.
    @pl.when(jnp.any(blk0_q))
    def _prefetch_first():
        start_plane_copy(0, 0)

    def round_body(r, carry):
        tok_alive, blk_live_q, rounds_q, mlow = carry
        slot = jax.lax.rem(r, 2)
        # Per-query plane accounting: only queries whose page is still
        # live consumed this plane (the DMA itself is shared).
        rounds_new = rounds_q + blk_live_q.astype(jnp.int32)
        blk_live_b = jnp.repeat(blk_live_q, Hq)[:, None]      # [Sq*Hq, 1]

        @pl.when(jnp.any(blk_live_q))
        def _consume_plane():
            wait_plane_copy(slot)
            packed = plane_ref[slot].astype(jnp.int32)        # [bs8, Hkv, D]
            shifts = jax.lax.broadcasted_iota(
                jnp.int32, (bs8, 8, n_kv_heads, D), 1)
            unpacked = (packed[:, None] >> shifts) & 1
            plane = unpacked.reshape(bs, n_kv_heads, D).astype(jnp.float32)
            # f32 dot is exact (integers < 2^24); same einsum as the
            # oracle rows, evaluated once for the whole draft block.
            delta = jnp.einsum("skgd,tkd->skgt", qg, plane,
                               preferred_element_type=jnp.float32)
            # Dead-query over-accumulation is unobservable (see module
            # docstring) — no per-query gate needed on the partial.
            partial_ref[...] += (delta.astype(jnp.int32)
                                 * plane_weight(r)).reshape(SH, bs)

        partial = partial_ref[...].astype(jnp.float32)
        lower = partial + mmin_ref[r, 0][:, None]
        upper = partial + mmax_ref[r, 0][:, None]
        low_here = jnp.max(jnp.where(valid_b & tok_alive, lower, NEG_INF),
                           axis=-1)
        mlow_new = jnp.where(blk_live_b[:, 0],
                             jnp.maximum(mlow, low_here), mlow)
        eta = mlow_new - alpha_radius
        keep = tok_alive & (upper >= eta[:, None]) & valid_b
        keep = jnp.where(r < min_rounds - 1, tok_alive & valid_b, keep)
        keep = jnp.where(blk_live_b, keep, tok_alive)
        blk_new_q = jnp.where(
            blk_live_q,
            jnp.any(keep.reshape(Sq, Hq, bs), axis=(1, 2)), blk_live_q)

        # BAP: next plane requested as soon as any query still wants it.
        @pl.when(jnp.any(blk_new_q) & (r + 1 < bits))
        def _prefetch_next():
            start_plane_copy(r + 1, 1 - slot)

        return keep, blk_new_q, rounds_new, mlow_new

    tok_alive, _, rounds_q, mlow = jax.lax.fori_loop(
        0, bits, round_body,
        (valid_b, blk0_q, jnp.zeros((Sq,), jnp.int32), mlow_ref[...]),
    )
    mlow_ref[...] = mlow
    rounds_ref[0, :, 0] = rounds_q

    survived = tok_alive & jnp.repeat(rounds_q == bits, Hq)[:, None]
    surv_ref[...] = survived[None].astype(jnp.int8)

    any_surv_q = jnp.any(survived.reshape(Sq, Hq, bs), axis=(1, 2))  # [Sq]
    any_surv_b = jnp.repeat(any_surv_q, Hq)[:, None]          # [Sq*Hq, 1]

    @pl.when(jnp.any(any_surv_q))
    def _epilogue():
        logits = jnp.where(
            survived,
            partial_ref[...].astype(jnp.float32) * st_ref[0][:, None],
            NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.where(survived, jnp.exp(logits - m_new[:, None]), 0.0)
        corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_new = l_ref[...] * corr + jnp.sum(p, axis=-1)
        # One V DMA serves every query with survivors on this page.
        cp = pltpu.make_async_copy(v_hbm.at[phys], v_ref, v_sem)
        cp.start()
        cp.wait()
        v = v_ref[...].astype(jnp.float32)
        if quantize_v:
            vs = vs_ref[0][None, :, None]
            v_eff = (qlib.quantize_with_scale(v, vs, bits)
                     .astype(jnp.float32) * vs)
        else:
            v_eff = v
        upd = jnp.einsum("skgt,tkd->skgd",
                         p.reshape(Sq, n_kv_heads, G, bs), v_eff,
                         preferred_element_type=jnp.float32)
        acc_new = acc_ref[...] * corr[:, None] + upd.reshape(SH, -1)
        # Each query commits its softmax state only if IT had survivors —
        # the oracle's where(any_surv, new, old), per query.
        m_ref[...] = jnp.where(any_surv_b[:, 0], m_new, m_prev)
        l_ref[...] = jnp.where(any_surv_b[:, 0], l_new, l_ref[...])
        acc_ref[...] = jnp.where(any_surv_b, acc_new, acc_ref[...])

    @pl.when(j == nj - 1)
    def _finalize():
        out_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        )[None].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("cfg", "window", "interpret", "stats"))
def paged_bitstopper_verify(
    q: jax.Array,            # [B, Sq, Hq, D] — draft block per slot
    kq_pool: jax.Array,      # [P, bits, bs//8, Hkv, D] uint8 plane pool
    v_pool: jax.Array,       # [P, bs, Hkv, Dv] V pool
    table: jax.Array,        # [B, MB] int32 block tables
    lengths: jax.Array,      # [B, Sq] int32 per-query fill levels
    q_positions: jax.Array,  # [B, Sq] int32 per-query absolute positions
    k_amax: jax.Array,       # [Hkv] pool-wide running max|K|
    v_amax: jax.Array,       # [Hkv] pool-wide running max|V|
    cfg: BitStopperConfig = BitStopperConfig(),
    window: int | None = None,
    interpret: bool | None = None,
    stats: bool = True,
) -> PagedVerifyOutput:
    """Run the fused Sq-tiled BESF verify kernel over every serving slot.

    Bit-identical observables to ``besf_attention_verify_paged`` (per-query
    plane counts, survivors, V-fetch decisions, attention output) while
    sharing each page's plane/V DMAs across the draft block.
    ``stats=False`` (the serving hot path) shrinks the survivors store to
    one page tile per slot and returns ``survivors``/``v_fetched`` as
    None, like the decode kernel."""
    interpret = resolve_interpret(interpret)
    B, Sq, Hq, D = q.shape
    P, bits, bs8, Hkv, _ = kq_pool.shape
    bs = bs8 * 8
    MB = table.shape[1]
    Dv = v_pool.shape[-1]
    SH = Sq * Hq
    assert bits == cfg.bits and v_pool.shape[1] == bs

    # Shared host-side prep with the oracle: (slot, query) rows flatten to
    # B*Sq independent Sq=1 decodes as far as quantization is concerned.
    prep = paged_decode_prep(q.reshape(B * Sq, Hq, D), k_amax, v_amax,
                             Hkv, cfg)
    q_int, m_min, m_max, scale_total, alpha_radius, _, v_scale = prep
    q_int = q_int.reshape(B, SH, D)
    m_min = m_min.reshape(bits, B, SH)
    m_max = m_max.reshape(bits, B, SH)
    scale_total = scale_total.reshape(B, SH)
    alpha_radius = alpha_radius.reshape(B, SH)

    kernel = functools.partial(
        _paged_verify_kernel,
        bits=bits, page_size=bs, n_queries=Sq, n_kv_heads=Hkv,
        min_rounds=cfg.min_rounds, quantize_v=cfg.quantize_v,
        window=window,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                    # tables (DMA addressing)
        grid=(B, MB),
        in_specs=[
            pl.BlockSpec((1, Sq), lambda b, j, *_: (b, 0)),      # lengths
            pl.BlockSpec((1, Sq), lambda b, j, *_: (b, 0)),      # q_pos
            pl.BlockSpec((1, SH, D), lambda b, j, *_: (b, 0, 0)),  # q_int
            pl.BlockSpec((bits, 1, SH), lambda b, j, *_: (0, b, 0)),  # m_min
            pl.BlockSpec((bits, 1, SH), lambda b, j, *_: (0, b, 0)),  # m_max
            pl.BlockSpec((1, SH), lambda b, j, *_: (b, 0)),      # scale_total
            pl.BlockSpec((1, SH), lambda b, j, *_: (b, 0)),      # alpha*radius
            pl.BlockSpec((1, Hkv), lambda b, j, *_: (0, 0)),     # v_scale
            pl.BlockSpec(memory_space=pl.ANY),                   # kq pool
            pl.BlockSpec(memory_space=pl.ANY),                   # v pool
        ],
        out_specs=[
            pl.BlockSpec((1, SH, Dv), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, Sq, 1), lambda b, j, *_: (b, 0, j)),
            pl.BlockSpec((1, SH, bs),
                         (lambda b, j, *_: (b, 0, j)) if stats else
                         (lambda b, j, *_: (b, 0, 0))),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, bs8, Hkv, D), jnp.uint8),   # plane double buffer
            pltpu.VMEM((bs, Hkv, Dv), v_pool.dtype),   # v page
            pltpu.VMEM((SH, bs), jnp.int32),           # partial scores
            pltpu.VMEM((SH,), jnp.float32),            # LATS prefix max
            pltpu.VMEM((SH,), jnp.float32),            # m
            pltpu.VMEM((SH,), jnp.float32),            # l
            pltpu.VMEM((SH, Dv), jnp.float32),         # acc
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
    )
    out, rounds, surv = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, SH, Dv), jnp.float32),
            jax.ShapeDtypeStruct((B, Sq, MB), jnp.int32),
            jax.ShapeDtypeStruct((B, SH, (MB if stats else 1) * bs),
                                 jnp.int8),
        ],
        interpret=interpret,
    )(table.astype(jnp.int32),
      lengths.astype(jnp.int32), q_positions.astype(jnp.int32),
      q_int, m_min, m_max, scale_total, alpha_radius, v_scale[None],
      kq_pool, v_pool)
    out = out.reshape(B, Sq, Hq, Dv)
    if not stats:
        return PagedVerifyOutput(out=out, rounds=rounds, survivors=None,
                                 v_fetched=None)
    survivors = surv.reshape(B, Sq, Hq, MB * bs).astype(bool)
    v_fetched = survivors.reshape(B, Sq, Hq, MB, bs).any(axis=(2, 4))
    return PagedVerifyOutput(out=out, rounds=rounds, survivors=survivors,
                             v_fetched=v_fetched)
