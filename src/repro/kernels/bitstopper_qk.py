"""BitStopper fused attention Pallas TPU kernel.

One kernel fuses the paper's whole pipeline (stage fusion is the point):

  bit-plane QK score formation  +  LATS pruning  +  online-softmax * V

TPU adaptation of the ASIC design (see DESIGN.md section 2):

* K is stored as **bit-packed planes** ``uint8[bits, S/8, d]`` (8 tokens per
  byte along the sequence axis).  Planes live in HBM (``pl.ANY``) and are
  DMA'd **manually** per (kv-block, round) with ``pltpu.make_async_copy``
  guarded by the block-liveness predicate — a terminated block's remaining
  planes are *never fetched*.  This is the DMA-level analogue of the paper's
  early termination: with BlockSpec auto-pipelining the bytes would move
  regardless of ``pl.when``, so manual copies are essential, not stylistic.
* The V block is likewise fetched manually only if at least one token in the
  block survived all rounds (V-PU traffic early-terminated).
* The LATS running threshold uses the **prefix max lower bound** across the
  kv blocks seen so far (conservative superset of the paper's global max,
  see ``core/block_adaptation.py`` — the oracle this kernel must match).
* BAP (bit-level asynchronous processing) maps to DMA/compute overlap: the
  copy for plane r+1 of a *live* block is issued before plane r's matmul is
  consumed (double-buffered plane scratch), and the Pallas grid pipelines
  across q tiles.

Numerics are exact: plane matmuls are f32 (every intermediate an integer
< 2^24), accumulated into an int32 partial-score scratch — bit-identical to
the int32 oracle.

Grid: ``(n_q_tiles, n_kv_blocks)`` with kv innermost/sequential so the
online-softmax state and the LATS prefix max persist in VMEM scratch.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import margins as margins_lib
from repro.core import quantization as qlib
from repro.core.besf import BitStopperConfig
from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


class KernelOutput(NamedTuple):
    out: jax.Array          # [Sq, dv] attention output
    rounds: jax.Array       # [n_qt, n_kb] int32 — planes fetched per block
    survivors: jax.Array    # [Sq, Sk] int8 — token-level keep mask


def _bitstopper_kernel(
    # scalar-prefetch/SMEM operands
    scalar_ref,             # SMEM f32[2]: [scale_total, alpha*radius_int]
    # VMEM-blocked operands
    q_ref,                  # [block_q, d] int32
    mmin_ref,               # [bits, block_q] f32
    mmax_ref,               # [bits, block_q] f32
    # HBM (manually DMA'd) operands
    kp_hbm,                 # [bits, Sk//8, d] uint8 bit-packed planes
    v_hbm,                  # [Sk, dv] f32
    # outputs
    out_ref,                # [block_q, dv]
    rounds_ref,             # [1, 1] int32
    surv_ref,               # [block_q, block_k] int8
    # scratch
    plane_ref,              # [2, block_k//8, d] uint8 (double buffer)
    v_ref,                  # [block_k, dv] f32
    partial_ref,            # [block_q, block_k] int32
    m_ref, l_ref, acc_ref,  # online softmax state
    mlow_ref,               # [block_q] f32 — LATS prefix max lower bound
    plane_sem,              # DMA semaphores [2]
    v_sem,
    *,
    bits: int,
    block_q: int,
    block_k: int,
    min_rounds: int,
    causal: bool,
    q_offset: int,
):
    qi = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    d = q_ref.shape[-1]
    bk8 = block_k // 8

    scale_total = scalar_ref[0]
    alpha_radius = scalar_ref[1]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mlow_ref[...] = jnp.full_like(mlow_ref, NEG_INF)

    partial_ref[...] = jnp.zeros_like(partial_ref)

    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    # Validity mask of this tile (causal or full).
    if causal:
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        vmask = rows >= cols
        blk_reachable = k_start <= q_start + block_q - 1
    else:
        vmask = jnp.ones((block_q, block_k), bool)
        blk_reachable = ki >= 0  # trivially true, traced

    def plane_weight(r):
        # MSB(sign) first: w_0 = -2^(bits-1), w_r = 2^(bits-1-r).
        mag = jax.lax.shift_left(jnp.int32(1), (bits - 1 - r).astype(jnp.int32))
        return jnp.where(r == 0, -mag, mag)

    q_f32 = q_ref[...].astype(jnp.float32)

    def start_plane_copy(r, slot):
        pltpu.make_async_copy(
            kp_hbm.at[r, pl.ds(ki * bk8, bk8), :],
            plane_ref.at[slot],
            plane_sem.at[slot],
        ).start()

    def wait_plane_copy(slot):
        pltpu.make_async_copy(
            kp_hbm.at[0, pl.ds(ki * bk8, bk8), :],  # shape donor only
            plane_ref.at[slot],
            plane_sem.at[slot],
        ).wait()

    # BAP prefetch: plane 0 of a reachable block is requested up front.
    @pl.when(blk_reachable)
    def _prefetch_first():
        start_plane_copy(0, 0)

    def round_body(r, carry):
        tok_alive, blk_live, rounds, mlow = carry
        slot = jax.lax.rem(r, 2)

        @pl.when(blk_live)
        def _consume_plane():
            wait_plane_copy(slot)
            packed = plane_ref[slot].astype(jnp.int32)           # [bk8, d]
            shifts = jax.lax.broadcasted_iota(jnp.int32, (bk8, 8, d), 1)
            unpacked = (packed[:, None, :] >> shifts) & 1        # [bk8, 8, d]
            plane = unpacked.reshape(block_k, d).astype(jnp.float32)
            # f32 dot is exact here: every partial product is an integer
            # bounded by 2048 * d < 2^24.
            delta = jax.lax.dot_general(
                q_f32, plane, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            partial_ref[...] += delta.astype(jnp.int32) * plane_weight(r)

        # BAP: issue next plane's DMA as soon as this one is consumed, before
        # the pruning decision math (overlap fetch with LATS compute).
        partial = partial_ref[...].astype(jnp.float32)
        lower = partial + mmin_ref[r][:, None]
        upper = partial + mmax_ref[r][:, None]
        low_here = jnp.max(jnp.where(vmask & tok_alive, lower, NEG_INF), axis=-1)
        mlow_new = jnp.where(blk_live, jnp.maximum(mlow, low_here), mlow)
        eta = mlow_new - alpha_radius
        keep = tok_alive & (upper >= eta[:, None]) & vmask
        keep = jnp.where(r < min_rounds - 1, tok_alive & vmask, keep)
        keep = jnp.where(blk_live, keep, tok_alive)
        blk_new = jnp.where(blk_live, jnp.any(keep), blk_live)
        rounds_new = rounds + blk_live.astype(jnp.int32)

        @pl.when(blk_new & (r + 1 < bits))
        def _prefetch_next():
            start_plane_copy(r + 1, 1 - slot)

        return keep, blk_new, rounds_new, mlow_new

    tok0 = vmask
    blk0 = blk_reachable & jnp.any(vmask)
    tok_alive, blk_live, rounds, mlow = jax.lax.fori_loop(
        0, bits, round_body,
        (tok0, blk0, jnp.zeros((), jnp.int32), mlow_ref[...]),
    )
    mlow_ref[...] = mlow
    rounds_ref[0, 0] = rounds

    # Survivors: alive tokens of a block that completed every round hold
    # their exact INT12 scores (stage fusion: prediction work == execution).
    survived = tok_alive & (rounds == bits)
    surv_ref[...] = survived.astype(jnp.int8)

    @pl.when(jnp.any(survived))
    def _epilogue():
        logits = jnp.where(
            survived, partial_ref[...].astype(jnp.float32) * scale_total, NEG_INF
        )
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.where(survived, jnp.exp(logits - m_new[:, None]), 0.0)
        corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        # V fetched only for blocks with at least one survivor.
        cp = pltpu.make_async_copy(
            v_hbm.at[pl.ds(ki * block_k, block_k), :], v_ref, v_sem
        )
        cp.start()
        cp.wait()
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v_ref[...], preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        out_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(out_ref.dtype)


def _bitstopper_single(
    q_int: jax.Array,        # [Sq, d] int32
    k_packed: jax.Array,     # [bits, Sk//8, d] uint8
    v_eff: jax.Array,        # [Sk, dv] f32
    m_min: jax.Array,        # [bits, Sq] f32
    m_max: jax.Array,        # [bits, Sq] f32
    scalars: jax.Array,      # f32[2]: [scale_total, alpha*radius_int]
    *,
    cfg: BitStopperConfig,
    block_q: int,
    block_k: int,
    causal: bool,
    interpret: bool,
) -> KernelOutput:
    Sq, d = q_int.shape
    bits = cfg.bits
    Sk = k_packed.shape[1] * 8
    dv = v_eff.shape[-1]
    assert Sq % block_q == 0 and Sk % block_k == 0 and block_k % 8 == 0
    n_qt, n_kb = Sq // block_q, Sk // block_k
    grid = (n_qt, n_kb)

    kernel = functools.partial(
        _bitstopper_kernel,
        bits=bits,
        block_q=block_q,
        block_k=block_k,
        min_rounds=cfg.min_rounds,
        causal=causal,
        q_offset=Sk - Sq if causal else 0,
    )
    out, rounds, surv = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                      # scalars
            pl.BlockSpec((block_q, d), lambda qi, ki: (qi, 0)),         # q
            pl.BlockSpec((bits, block_q), lambda qi, ki: (0, qi)),      # m_min
            pl.BlockSpec((bits, block_q), lambda qi, ki: (0, qi)),      # m_max
            pl.BlockSpec(memory_space=pl.ANY),                          # k planes
            pl.BlockSpec(memory_space=pl.ANY),                          # v
        ],
        out_specs=[
            pl.BlockSpec((block_q, dv), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((1, 1), lambda qi, ki: (qi, ki)),
            pl.BlockSpec((block_q, block_k), lambda qi, ki: (qi, ki)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Sq, dv), v_eff.dtype),
            jax.ShapeDtypeStruct((n_qt, n_kb), jnp.int32),
            jax.ShapeDtypeStruct((Sq, Sk), jnp.int8),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, block_k // 8, d), jnp.uint8),    # plane double buffer
            pltpu.VMEM((block_k, dv), jnp.float32),         # v block
            pltpu.VMEM((block_q, block_k), jnp.int32),      # partial scores
            pltpu.VMEM((block_q,), jnp.float32),            # m
            pltpu.VMEM((block_q,), jnp.float32),            # l
            pltpu.VMEM((block_q, dv), jnp.float32),         # acc
            pltpu.VMEM((block_q,), jnp.float32),            # LATS prefix max
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(scalars, q_int, m_min, m_max, k_packed, v_eff)
    return KernelOutput(out=out, rounds=rounds, survivors=surv)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_q", "block_k", "causal", "interpret"),
)
def bitstopper_attention_kernel(
    q: jax.Array,            # [..., Sq, d] float
    k: jax.Array,            # [..., Sk, d] float
    v: jax.Array,            # [..., Sk, dv] float
    cfg: BitStopperConfig = BitStopperConfig(),
    block_q: int = 128,
    block_k: int = 128,
    causal: bool = False,
    interpret: bool | None = None,
) -> KernelOutput:
    """Quantize + pack + run the fused BitStopper kernel.

    Leading batch/head dims are vmapped.  ``interpret=None`` auto-resolves:
    compiled on TPU, interpreted (the CPU validation mode) everywhere else;
    an explicit bool forces either mode.
    """
    interpret = resolve_interpret(interpret)
    d = q.shape[-1]
    sm_scale = 1.0 / (d ** 0.5)
    bits = cfg.bits

    def prep_and_run(q2, k2, v2):
        q_int, qp = qlib.quantize(q2, bits)
        k_int, kp = qlib.quantize(k2, bits)
        planes = qlib.to_bitplanes(k_int, bits)
        k_packed = qlib.pack_planes_seq(planes)
        m_min, m_max = margins_lib.bit_margins(q_int, bits)
        scale_total = qp.scale * kp.scale * sm_scale
        radius_int = cfg.radius / scale_total
        scalars = jnp.stack([scale_total, cfg.alpha * radius_int]).astype(jnp.float32)
        if cfg.quantize_v:
            v_int, vp = qlib.quantize(v2, bits)
            v_eff = qlib.dequantize(v_int, vp)
        else:
            v_eff = v2.astype(jnp.float32)
        bq = min(block_q, q2.shape[0])
        return _bitstopper_single(
            q_int, k_packed, v_eff, m_min, m_max, scalars,
            cfg=cfg, block_q=bq, block_k=min(block_k, k2.shape[0]),
            causal=causal, interpret=interpret,
        )

    if q.ndim == 2:
        return prep_and_run(q, k, v)
    flat_q = q.reshape((-1,) + q.shape[-2:])
    flat_k = k.reshape((-1,) + k.shape[-2:])
    flat_v = v.reshape((-1,) + v.shape[-2:])
    res = jax.vmap(prep_and_run)(flat_q, flat_k, flat_v)
    shape = q.shape[:-2]
    return jax.tree_util.tree_map(
        lambda x: x.reshape(shape + x.shape[1:]), res
    )
