"""Fused paged BESF decode Pallas TPU kernel — serving's per-token hot path.

DESIGN — mapping BitStopper (BESF / LATS / BAP) onto paged-DMA decode
=====================================================================

The serving KV cache is a batch-free block pool: ``[pool_blocks, ...]``
physical pages addressed through per-slot block tables.  The old decode
path gathered each slot's dense logical view ``[B, max_blocks_per_req *
page_size, H, D]`` per layer per token and re-derived bit planes from
scratch — O(table width) HBM traffic regardless of how full a row is or
how early LATS terminates.  This kernel walks the *physical* pages
directly; no view is ever materialized:

* **Paging via scalar prefetch.**  Block tables and per-row fill levels
  ride in SMEM (``PrefetchScalarGridSpec``), so the kernel computes every
  DMA address itself: grid ``(slot, kv_page)`` with the page axis
  innermost/sequential.  A page past the row's fill level issues **no DMA
  at all** — per-step traffic scales with actual fill, not with the padded
  table width.
* **BESF at page granularity.**  K lives pre-quantized in the incremental
  bit-plane pool (``uint8[pool_blocks, bits, page_size//8, Hkv, D]``,
  packed 8 tokens/byte at cache-write time under the pool-wide running
  per-KV-head scale — see ``models/attention.py:_update_plane_pool``).
  Planes are DMA'd **manually, one plane per round**, guarded by the LATS
  liveness predicate: once every (head, token) candidate of a page is
  pruned, the page's remaining planes are *never fetched*.  This is the
  paper's bit-serial early termination, enforced at the DMA level — with
  BlockSpec auto-pipelining the bytes would move regardless of ``pl.when``.
* **LATS.**  Per query head, the pruning threshold uses the **prefix max
  lower bound** over the pages seen so far (the same conservative superset
  of the paper's global max as the prefill kernel, oracle'd by
  ``core/block_adaptation.py``); margins come from the per-(slot, head)
  INT12 query, computed host-side and streamed in as LUT rows.
* **Early-terminated V.**  A page's V is fetched only if at least one
  token survives all rounds — the V-PU half of the paper's traffic win.
* **BAP.**  Bit-level asynchronous processing maps to DMA/compute overlap:
  plane r+1 of a live page is requested (double-buffered plane scratch)
  before round r's pruning math runs, and the whole epilogue (softmax
  rescale + V matmul) is predicated off for survivor-free pages.

Numerics are exact: plane matmuls are f32 (every intermediate an integer
< 2^24) accumulated into an int32 partial-score scratch.  The pure-JAX
oracle this kernel must match bit for bit is
``core/besf.py:besf_attention_decode_paged`` — same page order, same
online-softmax op order, same pool-wide quant scales.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quantization as qlib
from repro.core.besf import BitStopperConfig, PagedDecodeOutput, \
    paged_decode_prep
from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _paged_decode_kernel(
    # scalar-prefetch (SMEM)
    tables_ref,             # [B, MB] int32 — logical -> physical page
    lengths_ref,            # [B] int32 — per-row fill level
    qpos_ref,               # [B] int32 — absolute query position
    # VMEM-blocked operands
    q_ref,                  # [1, Hq, D] int32 — quantized query
    mmin_ref,               # [bits, 1, Hq] f32 — LATS margin LUT (min)
    mmax_ref,               # [bits, 1, Hq] f32 — LATS margin LUT (max)
    st_ref,                 # [1, Hq] f32 — scale_total per head
    ar_ref,                 # [1, Hq] f32 — alpha * radius_int per head
    vs_ref,                 # [1, Hkv] f32 — V quant scale per KV head
    # HBM (manually DMA'd) pools
    kq_hbm,                 # [P, bits, bs8, Hkv, D] uint8 bit-plane pool
    v_hbm,                  # [P, bs, Hkv, Dv] V pool
    # outputs
    out_ref,                # [1, Hq, Dv]
    rounds_ref,             # [1, 1] int32
    surv_ref,               # [1, Hq, bs] int8
    # scratch
    plane_ref,              # [2, bs8, Hkv, D] uint8 (double buffer)
    v_ref,                  # [bs, Hkv, Dv]
    partial_ref,            # [Hq, bs] int32
    mlow_ref,               # [Hq] f32 — LATS prefix max lower bound
    m_ref, l_ref, acc_ref,  # online softmax state
    plane_sem, v_sem,       # DMA semaphores
    *,
    bits: int,
    page_size: int,
    n_kv_heads: int,
    min_rounds: int,
    quantize_v: bool,
    window: int | None,
):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    bs = page_size
    bs8 = bs // 8
    Hq = q_ref.shape[1]
    D = q_ref.shape[2]
    G = Hq // n_kv_heads

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mlow_ref[...] = jnp.full_like(mlow_ref, NEG_INF)

    partial_ref[...] = jnp.zeros_like(partial_ref)

    phys = tables_ref[b, j]
    length = lengths_ref[b]
    q_pos = qpos_ref[b]

    t_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    valid = (t_pos <= q_pos) & (t_pos < length)
    if window is not None:
        valid &= t_pos > q_pos - window
    valid_b = jnp.broadcast_to(valid[None], (Hq, bs))
    blk0 = jnp.any(valid)

    alpha_radius = ar_ref[0]                                  # [Hq]
    qg = q_ref[0].astype(jnp.float32).reshape(n_kv_heads, G, D)

    def plane_weight(r):
        mag = jax.lax.shift_left(jnp.int32(1), (bits - 1 - r).astype(jnp.int32))
        return jnp.where(r == 0, -mag, mag)

    def start_plane_copy(r, slot):
        pltpu.make_async_copy(
            kq_hbm.at[phys, r], plane_ref.at[slot], plane_sem.at[slot],
        ).start()

    def wait_plane_copy(slot):
        pltpu.make_async_copy(
            kq_hbm.at[0, 0],                       # shape donor only
            plane_ref.at[slot], plane_sem.at[slot],
        ).wait()

    # BAP prefetch: plane 0 of a reachable page is requested up front.
    @pl.when(blk0)
    def _prefetch_first():
        start_plane_copy(0, 0)

    def round_body(r, carry):
        tok_alive, blk_live, rounds, mlow = carry
        slot = jax.lax.rem(r, 2)
        rounds_new = rounds + blk_live.astype(jnp.int32)

        @pl.when(blk_live)
        def _consume_plane():
            wait_plane_copy(slot)
            packed = plane_ref[slot].astype(jnp.int32)        # [bs8, Hkv, D]
            shifts = jax.lax.broadcasted_iota(
                jnp.int32, (bs8, 8, n_kv_heads, D), 1)
            unpacked = (packed[:, None] >> shifts) & 1
            plane = unpacked.reshape(bs, n_kv_heads, D).astype(jnp.float32)
            # f32 dot is exact: every partial product is an integer bounded
            # by 2048 * D < 2^24.  Same einsum as the oracle, op for op.
            delta = jnp.einsum("kgd,tkd->kgt", qg, plane,
                               preferred_element_type=jnp.float32)
            partial_ref[...] += (delta.astype(jnp.int32)
                                 * plane_weight(r)).reshape(Hq, bs)

        partial = partial_ref[...].astype(jnp.float32)
        lower = partial + mmin_ref[r, 0][:, None]
        upper = partial + mmax_ref[r, 0][:, None]
        low_here = jnp.max(jnp.where(valid_b & tok_alive, lower, NEG_INF),
                           axis=-1)
        mlow_new = jnp.where(blk_live, jnp.maximum(mlow, low_here), mlow)
        eta = mlow_new - alpha_radius
        keep = tok_alive & (upper >= eta[:, None]) & valid_b
        keep = jnp.where(r < min_rounds - 1, tok_alive & valid_b, keep)
        keep = jnp.where(blk_live, keep, tok_alive)
        blk_new = jnp.where(blk_live, jnp.any(keep), blk_live)

        # BAP: the next plane's DMA is issued as soon as the liveness
        # verdict exists, overlapping with the next round's LATS math.
        @pl.when(blk_new & (r + 1 < bits))
        def _prefetch_next():
            start_plane_copy(r + 1, 1 - slot)

        return keep, blk_new, rounds_new, mlow_new

    tok_alive, _, rounds, mlow = jax.lax.fori_loop(
        0, bits, round_body,
        (valid_b, blk0, jnp.zeros((), jnp.int32), mlow_ref[...]),
    )
    mlow_ref[...] = mlow
    rounds_ref[0, 0] = rounds

    survived = tok_alive & (rounds == bits)
    surv_ref[...] = survived[None].astype(jnp.int8)

    @pl.when(jnp.any(survived))
    def _epilogue():
        logits = jnp.where(
            survived,
            partial_ref[...].astype(jnp.float32) * st_ref[0][:, None],
            NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.where(survived, jnp.exp(logits - m_new[:, None]), 0.0)
        corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        # V page fetched only when at least one token survived all rounds.
        cp = pltpu.make_async_copy(v_hbm.at[phys], v_ref, v_sem)
        cp.start()
        cp.wait()
        v = v_ref[...].astype(jnp.float32)
        if quantize_v:
            vs = vs_ref[0][None, :, None]
            v_eff = (qlib.quantize_with_scale(v, vs, bits)
                     .astype(jnp.float32) * vs)
        else:
            v_eff = v
        upd = jnp.einsum("kgt,tkd->kgd",
                         p.reshape(n_kv_heads, G, bs), v_eff,
                         preferred_element_type=jnp.float32)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + upd.reshape(Hq, v_eff.shape[-1]))
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        out_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        )[None].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("cfg", "window", "interpret", "stats"))
def paged_bitstopper_decode(
    q: jax.Array,            # [B, Hq, D] — one decode query per slot
    kq_pool: jax.Array,      # [P, bits, bs//8, Hkv, D] uint8 plane pool
    v_pool: jax.Array,       # [P, bs, Hkv, Dv] V pool
    table: jax.Array,        # [B, MB] int32 block tables
    lengths: jax.Array,      # [B] int32 fill levels
    q_positions: jax.Array,  # [B] int32 absolute query positions
    k_amax: jax.Array,       # [Hkv] pool-wide running max|K|
    v_amax: jax.Array,       # [Hkv] pool-wide running max|V|
    cfg: BitStopperConfig = BitStopperConfig(),
    window: int | None = None,
    interpret: bool | None = None,
    stats: bool = True,
) -> PagedDecodeOutput:
    """Run the fused paged BESF decode kernel over every serving slot.

    Bit-identical observables to ``besf_attention_decode_paged`` (the
    pure-JAX gather fallback): per-page plane counts, token survivors,
    V-fetch decisions, and the attention output.  ``interpret=None``
    auto-resolves per backend (compiled on TPU, interpreted elsewhere).

    ``stats=False`` (the serving hot path) shrinks the survivors output
    to a single page-wide tile per slot — every grid step overwrites the
    same block, so the per-step HBM store drops from ``B*Hq*MB*page``
    bytes to ``B*Hq*page`` — and returns ``survivors``/``v_fetched`` as
    None.  Tests and the traffic model use ``stats=True``."""
    interpret = resolve_interpret(interpret)
    B, Hq, D = q.shape
    P, bits, bs8, Hkv, _ = kq_pool.shape
    bs = bs8 * 8
    MB = table.shape[1]
    Dv = v_pool.shape[-1]
    assert bits == cfg.bits and v_pool.shape[1] == bs

    prep = paged_decode_prep(q, k_amax, v_amax, Hkv, cfg)
    q_int, m_min, m_max, scale_total, alpha_radius, _, v_scale = prep

    kernel = functools.partial(
        _paged_decode_kernel,
        bits=bits, page_size=bs, n_kv_heads=Hkv,
        min_rounds=cfg.min_rounds, quantize_v=cfg.quantize_v,
        window=window,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                    # tables, lengths, q_pos
        grid=(B, MB),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, j, *_: (b, 0, 0)),     # q_int
            pl.BlockSpec((bits, 1, Hq), lambda b, j, *_: (0, b, 0)),  # m_min
            pl.BlockSpec((bits, 1, Hq), lambda b, j, *_: (0, b, 0)),  # m_max
            pl.BlockSpec((1, Hq), lambda b, j, *_: (b, 0)),      # scale_total
            pl.BlockSpec((1, Hq), lambda b, j, *_: (b, 0)),      # alpha*radius
            pl.BlockSpec((1, Hkv), lambda b, j, *_: (0, 0)),     # v_scale
            pl.BlockSpec(memory_space=pl.ANY),                   # kq pool
            pl.BlockSpec(memory_space=pl.ANY),                   # v pool
        ],
        out_specs=[
            pl.BlockSpec((1, Hq, Dv), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j, *_: (b, j)),
            pl.BlockSpec((1, Hq, bs),
                         (lambda b, j, *_: (b, 0, j)) if stats else
                         (lambda b, j, *_: (b, 0, 0))),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, bs8, Hkv, D), jnp.uint8),   # plane double buffer
            pltpu.VMEM((bs, Hkv, Dv), v_pool.dtype),   # v page
            pltpu.VMEM((Hq, bs), jnp.int32),           # partial scores
            pltpu.VMEM((Hq,), jnp.float32),            # LATS prefix max
            pltpu.VMEM((Hq,), jnp.float32),            # m
            pltpu.VMEM((Hq,), jnp.float32),            # l
            pltpu.VMEM((Hq, Dv), jnp.float32),         # acc
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
    )
    out, rounds, surv = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Dv), jnp.float32),
            jax.ShapeDtypeStruct((B, MB), jnp.int32),
            jax.ShapeDtypeStruct((B, Hq, (MB if stats else 1) * bs),
                                 jnp.int8),
        ],
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32),
      q_positions.astype(jnp.int32),
      q_int, m_min, m_max, scale_total, alpha_radius, v_scale[None],
      kq_pool, v_pool)
    if not stats:
        return PagedDecodeOutput(out=out, rounds=rounds, survivors=None,
                                 v_fetched=None)
    survivors = surv.astype(bool)
    v_fetched = survivors.reshape(B, Hq, MB, bs).any(axis=(1, 3))
    return PagedDecodeOutput(out=out, rounds=rounds, survivors=survivors,
                             v_fetched=v_fetched)
