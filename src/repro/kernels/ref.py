"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert allclose against the
function of the same name here.  These are *definitional* implementations —
no tiling, no early exit — so their correctness is self-evident.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.block_adaptation import block_bitstopper_attention
from repro.core.besf import BitStopperConfig, besf_attention

NEG_INF = -1e30


@partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, causal: bool = False, sm_scale: float | None = None):
    """Dense softmax attention: the oracle for kernels/flash_attention.py."""
    d = q.shape[-1]
    if sm_scale is None:
        sm_scale = 1.0 / d ** 0.5
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * sm_scale
    if causal:
        Sq, Sk = q.shape[-2], k.shape[-2]
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)


def bitstopper_attention(q, k, v, cfg: BitStopperConfig = BitStopperConfig(),
                         block_q: int = 128, block_k: int = 128,
                         causal: bool = False):
    """Block-granular streaming BitStopper — the oracle for
    kernels/bitstopper_qk.py (identical semantics incl. prefix-max LATS)."""
    return block_bitstopper_attention(
        q, k, v, cfg=cfg, block_q=block_q, block_k=block_k, causal=causal
    )


def bitstopper_reference(q, k, v, cfg: BitStopperConfig = BitStopperConfig(),
                         causal: bool = False):
    """Paper-faithful per-token BESF (global-max LATS) — the algorithmic
    ground truth the block variant's survivors must be a superset of."""
    return besf_attention(q, k, v, cfg=cfg, causal=causal)


@partial(jax.jit, static_argnames=("causal",))
def decode_attention(q, k, v, causal: bool = False):
    """Single-query decode attention oracle (Sq == 1 specialization)."""
    return flash_attention(q, k, v, causal=False)
