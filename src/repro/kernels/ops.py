"""Public jit'd attention dispatch — the single entry point models use.

``attention(..., impl=...)`` selects between:

* ``"xla"``               — jnp reference (used by the distributed dry-run /
                            training graph: Pallas TPU kernels cannot lower
                            on the CPU backend of this container).
* ``"flash"``             — fused dense Pallas kernel (interpret on CPU).
* ``"bitstopper"``        — fused BESF+LATS Pallas kernel (interpret on CPU).
* ``"bitstopper_xla"``    — block-granular semantic model in pure jnp; same
                            outputs as the kernel, runs/lowrs everywhere.
                            This is what serving uses for sparsity stats on
                            CPU and what the dry-run lowers for TPU graphs.

``interpret=None`` (the default) auto-resolves per backend: the Pallas
kernels compile on TPU and interpret everywhere else — no flag needed on a
real deployment, and CPU CI keeps validating the same kernel bodies.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.besf import BitStopperConfig
from repro.kernels import ref as ref_lib
from repro.kernels.bitstopper_qk import bitstopper_attention_kernel
from repro.kernels.flash_attention import flash_attention_single

AttnImpl = Literal["xla", "flash", "bitstopper", "bitstopper_xla"]


def attention(
    q: jax.Array,                     # [..., Sq, d]
    k: jax.Array,                     # [..., Sk, d]
    v: jax.Array,                     # [..., Sk, dv]
    impl: AttnImpl = "xla",
    causal: bool = False,
    cfg: BitStopperConfig | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Attention output only (stats-carrying variants live in core/)."""
    if impl == "xla":
        return ref_lib.flash_attention(q, k, v, causal=causal)
    if impl == "flash":
        def single(q2, k2, v2):
            return flash_attention_single(
                q2, k2, v2, causal=causal,
                block_q=min(block_q, q2.shape[0]),
                block_k=min(block_k, k2.shape[0]),
                interpret=interpret,
            )
        if q.ndim == 2:
            return single(q, k, v)
        flat = lambda x: x.reshape((-1,) + x.shape[-2:])
        out = jax.vmap(single)(flat(q), flat(k), flat(v))
        return out.reshape(q.shape[:-2] + out.shape[1:])
    cfg = cfg or BitStopperConfig()
    if impl == "bitstopper":
        res = bitstopper_attention_kernel(
            q, k, v, cfg=cfg, block_q=block_q, block_k=block_k,
            causal=causal, interpret=interpret,
        )
        return res.out
    if impl == "bitstopper_xla":
        res = ref_lib.bitstopper_attention(
            q, k, v, cfg=cfg,
            block_q=min(block_q, q.shape[-2]), block_k=min(block_k, k.shape[-2]),
            causal=causal,
        )
        return res.out
    raise ValueError(f"unknown attention impl: {impl}")
