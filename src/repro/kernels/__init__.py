"""Pallas TPU kernels (validated interpret=True on CPU) + jnp oracles."""

from repro.kernels.ops import attention  # noqa: F401
