"""Fused flash-attention Pallas TPU kernel (dense baseline / training path).

Standard online-softmax tiling: grid = (q_blocks, kv_blocks); the kv axis is
the innermost (sequential) grid dimension so the running (m, l, acc) state
lives in VMEM scratch across kv steps.  Causal masking skips whole blocks
above the diagonal via ``pl.when``.  f32 accumulation, bf16-or-f32 inputs.

VMEM working set per step: q[Bq,d] + k[Bk,d] + v[Bk,dv] + acc[Bq,dv] +
scores[Bq,Bk] — with the default Bq=Bk=128, d=128 that is ~0.4 MB, far under
the ~16 MB v5e VMEM budget; MXU dims are 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # inputs
    out_ref,                        # output
    m_ref, l_ref, acc_ref,          # scratch
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    q_offset: int,
):
    qi = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q + q_offset
    k_start = ki * block_k
    # Causal: block live iff its first column can be visible to its last row.
    live = (k_start <= q_start + block_q - 1) if causal else (ki >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                                     # [Bq, Bk]
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        out_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "sm_scale", "interpret"),
)
def flash_attention_single(
    q: jax.Array,        # [Sq, d]
    k: jax.Array,        # [Sk, d]
    v: jax.Array,        # [Sk, dv]
    causal: bool = False,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    Sq, d = q.shape
    Sk, dv = v.shape
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    if sm_scale is None:
        sm_scale = 1.0 / d ** 0.5
    grid = (Sq // block_q, Sk // block_k)
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        q_offset=Sk - Sq if causal else 0,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((block_k, d), lambda qi, ki: (ki, 0)),
            pl.BlockSpec((block_k, dv), lambda qi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dv), lambda qi, ki: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((Sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
