"""Kernel runtime policy helpers shared by every Pallas entry point."""

from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` kernel argument.

    ``None`` means *auto*: compile the kernel iff the default JAX backend is
    a TPU, interpret everywhere else (the CPU containers this repo tests on
    cannot lower Pallas TPU kernels).  Passing an explicit bool always wins —
    e.g. forcing ``interpret=True`` on TPU to debug a kernel.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
