"""Multi-head attention: MHA / GQA / MQA, RoPE, sliding windows, KV cache.

The *score path* is pluggable (``impl``):

* ``"xla"``            — chunked online-softmax attention in pure jnp (the
                         training / dry-run path; GSPMD-partitionable, peak
                         memory O(chunk^2) instead of O(S^2)).
* ``"bitstopper_xla"`` — the paper's predictor-free dynamic-sparse attention
                         (block-granular semantic model; serving path).
* ``"bitstopper"``     — fused Pallas kernel (interpret on CPU, compiled on TPU).
* ``"flash"``          — dense fused Pallas kernel.

GQA is computed *grouped* (no KV repetition) on the xla path; the BitStopper
paths repeat KV heads since the sparsity decision is per query head (each
query row owns its LATS threshold, exactly like a PE lane in the paper).

KV cache: slots carry their absolute position (``pos``); sliding-window
layers may use a **ring buffer** of ``window`` slots so ``long_500k`` decode
stays O(window) in memory.  Invalid slots hold the sentinel position 2^30,
which every causal/window test rejects.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quantization as qlib
from repro.core.besf import BitStopperConfig, besf_attention_decode_paged, \
    besf_attention_verify_paged
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.sharding.api import constrain

NEG_INF = -1e30
POS_SENTINEL = 2 ** 30

# When a cache write grows a pool-wide running max-abs, overshoot the new
# max by this factor.  An exact running max creeps for the whole serve
# (P(new max per token) ~ 1/n), and every growth event is expensive: a
# whole-pool plane requant on the fused path, and a lossless-but-wasted
# bailout tick for speculative decoding.  With headroom, per-head growth
# events are O(log_headroom(dynamic range)) over the entire serve, at the
# cost of <= 25% coarser INT quantization right after a growth (still
# ~11.7 effective bits of the 12).
AMAX_HEADROOM = 1.25


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int | None = None          # sliding-window size (None = global)
    causal: bool = True
    impl: str = "xla"
    bitstopper: BitStopperConfig = BitStopperConfig()
    chunk_q: int = 512
    chunk_k: int = 512
    # Paged serving decode: walk physical KV pages with the fused Pallas
    # kernel (kernels/paged_decode.py) instead of the pure-JAX gather
    # fallback.  Only consulted when the cache carries a bit-plane pool.
    fused_decode: bool = False
    # Speculative serving: this forward is a draft-block VERIFY — multi-
    # query BitStopper attention goes through the paged verify path (each
    # query bit-identical to the Sq=1 decode at its position) instead of
    # the block-prefill reference.
    spec_verify: bool = False


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": L.init_linear(kq, cfg.d_model, (cfg.n_heads, cfg.head_dim),
                            cfg.qkv_bias, dtype),
        "wk": L.init_linear(kk, cfg.d_model, (cfg.n_kv_heads, cfg.head_dim),
                            cfg.qkv_bias, dtype),
        "wv": L.init_linear(kv, cfg.d_model, (cfg.n_kv_heads, cfg.head_dim),
                            cfg.qkv_bias, dtype),
        "wo": L.init_linear(ko, cfg.n_heads * cfg.head_dim, cfg.d_model,
                            False, dtype),
    }


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (jnp "flash"), grouped GQA.
# ---------------------------------------------------------------------------


def _mask_block(q_pos, k_pos, causal: bool, window: int | None):
    """[Bq, Bk] bool validity from absolute positions."""
    m = (k_pos[None, :] < POS_SENTINEL) & (q_pos[:, None] >= 0)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _fwd_impl(q, k, v, q_pos, k_pos, causal, window, cq, ck):
    """Padded-shape forward.  q [B,Sq,Hkv,G,D] grouped; returns (out, lse).

    lse[b,h,g,i] = m_i + log l_i — the softmax normalizer saved for the
    manual backward (flash-attention style)."""
    B, Sq, Hkv, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    nq, nk = Sq // cq, Sk // ck
    sm_scale = 1.0 / D ** 0.5

    kb = k.reshape(B, nk, ck, Hkv, D)
    vb = v.reshape(B, nk, ck, Hkv, Dv)
    qp = q_pos.reshape(nq, cq)
    kp = k_pos.reshape(nk, ck)

    def q_chunk(qi_chunk, qpos):
        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            kc, vc, kpos = inp
            logits = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi_chunk.astype(jnp.float32),
                kc.astype(jnp.float32)) * sm_scale
            mask = _mask_block(qpos, kpos, causal, window)
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.where(m_run == NEG_INF, 0.0, jnp.exp(m_run - m_new))
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            upd = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
            acc_new = acc * corr[..., None] + upd
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, cq), jnp.float32),
            jnp.zeros((B, Hkv, G, cq, Dv), jnp.float32),
        )
        (m_run, l_run, acc), _ = jax.lax.scan(
            kv_step, init, (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kp))
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        lse = jnp.where(l_run > 0, m_run + jnp.log(jnp.maximum(l_run, 1e-30)),
                        0.0)
        return jnp.einsum("bhgqd->bqhgd", out), lse      # lse [B,Hkv,G,cq]

    qg = q.reshape(B, nq, cq, Hkv, G, D)
    out, lse = jax.lax.map(lambda inp: q_chunk(*inp),
                           (qg.swapaxes(0, 1), qp))
    out = out.swapaxes(0, 1).reshape(B, Sq, Hkv, G, Dv)
    lse = jnp.moveaxis(lse, 0, 3).reshape(B, Hkv, G, Sq)
    return out, lse


def _make_chunked_attn(causal, window, cq, ck):
    """custom_vjp chunked attention with a MANUAL flash-style backward.

    Autodiff through the forward scans would save per-(q,kv)-tile softmax
    residuals — measured ~13 GB per layer at train_4k scale.  The manual
    backward recomputes each tile from (q, k, v, lse): residual memory is
    O(S·D), all tiles transient.
    """

    @jax.custom_vjp
    def attn(q, k, v, q_pos, k_pos):
        return _fwd_impl(q, k, v, q_pos, k_pos, causal, window, cq, ck)[0]

    def fwd(q, k, v, q_pos, k_pos):
        out, lse = _fwd_impl(q, k, v, q_pos, k_pos, causal, window, cq, ck)
        return out, (q, k, v, q_pos, k_pos, out, lse)

    def bwd(res, dout):
        q, k, v, q_pos, k_pos, out, lse = res
        B, Sq, Hkv, G, D = q.shape
        Sk, Dv = k.shape[1], v.shape[-1]
        nq, nk = Sq // cq, Sk // ck
        sm_scale = 1.0 / D ** 0.5

        dout = dout.astype(jnp.float32)
        # Per-row correction term D_i = sum_d dout_i · out_i.
        delta = jnp.einsum("bqhgd,bqhgd->bhgq", dout,
                           out.astype(jnp.float32))       # [B,Hkv,G,Sq]

        qg = q.reshape(B, nq, cq, Hkv, G, D).astype(jnp.float32)
        dog = dout.reshape(B, nq, cq, Hkv, G, Dv)
        kb = k.reshape(B, nk, ck, Hkv, D).astype(jnp.float32)
        vb = v.reshape(B, nk, ck, Hkv, Dv).astype(jnp.float32)
        lse_c = lse.reshape(B, Hkv, G, nq, cq)
        del_c = delta.reshape(B, Hkv, G, nq, cq)
        qp = q_pos.reshape(nq, cq)
        kp = k_pos.reshape(nk, ck)

        # Outer scan over KV chunks: emits (dk, dv) per chunk, carries the
        # full dq accumulator (O(S·D) f32).
        def kv_step(dq_acc, inp):
            kc, vc, kpos = inp                            # [B,ck,Hkv,D], ...

            def q_step(carry, qinp):
                dk_c, dv_c = carry
                qi, doi, lsei, deli, qpos = qinp
                logits = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kc) * sm_scale
                mask = _mask_block(qpos, kpos, causal, window)
                p = jnp.where(mask[None, None, None],
                              jnp.exp(logits - lsei[..., None]), 0.0)
                dv_c = dv_c + jnp.einsum("bhgqk,bqhgd->bkhd", p, doi)
                dp = jnp.einsum("bqhgd,bkhd->bhgqk", doi, vc)
                ds = p * (dp - deli[..., None]) * sm_scale
                dq_i = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kc)
                dk_c = dk_c + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qi)
                return (dk_c, dv_c), dq_i

            init = (jnp.zeros((B, ck, Hkv, D), jnp.float32),
                    jnp.zeros((B, ck, Hkv, Dv), jnp.float32))
            (dk_c, dv_c), dq_parts = jax.lax.scan(
                q_step, init,
                (qg.swapaxes(0, 1), dog.swapaxes(0, 1),
                 lse_c.transpose(3, 0, 1, 2, 4), del_c.transpose(3, 0, 1, 2, 4),
                 qp))
            dq_acc = dq_acc + jnp.moveaxis(dq_parts, 0, 1).reshape(
                B, Sq, Hkv, G, D)
            return dq_acc, (dk_c, dv_c)

        dq0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
        dq, (dk_parts, dv_parts) = jax.lax.scan(
            kv_step, dq0, (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kp))
        dk = jnp.moveaxis(dk_parts, 0, 1).reshape(B, Sk, Hkv, D)
        dv = jnp.moveaxis(dv_parts, 0, 1).reshape(B, Sk, Hkv, Dv)

        import numpy as _np
        zp = _np.zeros(q_pos.shape, jax.dtypes.float0)
        zk = _np.zeros(k_pos.shape, jax.dtypes.float0)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                zp, zk)

    attn.defvjp(fwd, bwd)
    return attn


def chunked_attention(
    q: jax.Array,              # [B, Sq, Hq, D]
    k: jax.Array,              # [B, Sk, Hkv, D]
    v: jax.Array,              # [B, Sk, Hkv, D]
    q_positions: jax.Array,    # [Sq] absolute positions of the queries
    k_positions: jax.Array,    # [Sk]
    causal: bool,
    window: int | None,
    chunk_q: int,
    chunk_k: int,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    pad_q, pad_k = (-Sq) % cq, (-Sk) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad_k),
                              constant_values=POS_SENTINEL)
    qg = q.reshape(B, q.shape[1], Hkv, G, D)
    attn = _make_chunked_attn(causal, window, cq, ck)
    out = attn(qg, k, v, q_positions, k_positions)       # [B,Sq',Hkv,G,Dv]
    out = out.reshape(B, q.shape[1], Hq, Dv)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# BitStopper score path: per-query-head dynamic sparsity.
# ---------------------------------------------------------------------------


def attention_block_shape(n: int, cap: int = 128) -> tuple[int, int]:
    """Public block-size helper for the block-granular BitStopper paths.

    Returns ``(block, pad)``: the tile size is ``min(cap, n)`` and the axis
    pads up to a multiple of it (padding must be fully masked, so dead tiles
    never fetch planes and zero pad rows don't move the per-tensor max-abs
    quant scale).  Padding — rather than shrinking the block to a divisor of
    ``n`` — keeps awkward (e.g. prime) lengths from degrading to 1-wide
    tiles."""
    b = min(cap, n)
    return b, (-n) % b


def _expand_gqa(q, k, v, G):
    """[B,S,H*,D] layout → head-major [B,Hq,S,D] with KV heads repeated
    (the BitStopper paths decide sparsity per query head)."""
    kr = jnp.repeat(k, G, axis=2).swapaxes(1, 2)      # [B, Hq, T, D]
    vr = jnp.repeat(v, G, axis=2).swapaxes(1, 2)
    return q.swapaxes(1, 2), kr, vr


def _bitstopper_full(q, k, v, cfg: AttnConfig, mask2d):
    """q [B,S,Hq,D], k/v [B,T,Hkv,D], mask2d [S,T] or None → [B,S,Hq,D]."""
    qt, kr, vr = _expand_gqa(q, k, v, cfg.n_heads // cfg.n_kv_heads)
    Sq = qt.shape[2]

    if cfg.impl == "bitstopper_xla" or mask2d is not None:
        from repro.core.block_adaptation import block_bitstopper_attention
        Sk = kr.shape[2]
        if mask2d is None and cfg.causal:
            mask2d = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        bq, pad_q = attention_block_shape(Sq)
        bk, pad_k = attention_block_shape(Sk)
        if pad_q or pad_k:
            if mask2d is None:
                mask2d = jnp.ones((Sq, Sk), bool)
            mask2d = jnp.pad(mask2d, ((0, pad_q), (0, pad_k)))
            qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
            kr = jnp.pad(kr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            vr = jnp.pad(vr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        flat = lambda a: a.reshape((-1,) + a.shape[2:])
        res = jax.vmap(
            lambda a, b, c: block_bitstopper_attention(
                a, b, c, cfg=cfg.bitstopper, block_q=bq, block_k=bk,
                mask=mask2d)
        )(flat(qt), flat(kr), flat(vr))
        out = res.out.reshape(qt.shape[:2] + res.out.shape[1:])
    else:
        out = kops.attention(qt, kr, vr, impl=cfg.impl, causal=cfg.causal,
                             cfg=cfg.bitstopper)
    return out.swapaxes(1, 2)[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Shape of a paged (block-pool) KV cache.

    ``pool_blocks`` physical blocks of ``page_size`` token slots each are
    shared by every request; a request addresses them through a
    ``[batch, max_blocks_per_req]`` *block table* mapping logical block
    index (position // page_size) to physical block id.  Physical block 0
    is the **null block**: never written, it backs unused table entries so
    gathers stay in bounds."""
    pool_blocks: int
    page_size: int
    max_blocks_per_req: int

    def __post_init__(self):
        if self.pool_blocks < 2:
            raise ValueError("pool_blocks must be >= 2 (block 0 is the "
                             f"reserved null block), got {self.pool_blocks}")
        if self.page_size < 1 or self.max_blocks_per_req < 1:
            raise ValueError("page_size and max_blocks_per_req must be >= 1")


def init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.float32,
               ring: bool = False, per_slot: bool = False,
               paged: PagedLayout | None = None):
    """With ``ring=True`` (sliding-window layers) only ``window`` slots are
    allocated and writes wrap — O(window) memory for long_500k decode.
    Ring-ness needs no flag at use time: writes always go to
    ``length mod n_slots``, which is the identity while length < n_slots.

    With ``per_slot=True`` (continuous-batching serving) every batch row is
    an independent *slot*: it carries its own write cursor (``length`` is
    [batch]) and its own slot->position map (``pos`` is [batch, n_slots]),
    so requests of different lengths share one decode batch without
    re-padding.  ``cache_is_per_slot`` distinguishes the two layouts.

    With ``paged=PagedLayout(...)`` the K/V storage loses its batch axis
    entirely: one ``[pool_blocks, page_size, Hkv, D]`` pool is shared by
    every slot, addressed through a per-slot block ``table`` (refcounted
    blocks can appear in several tables — copy-on-write prefix sharing).
    Sliding-window layers fall back to position masking (no ring): the
    logical index of a token is its absolute position.

    BitStopper layers additionally carry ``k_amax``/``v_amax`` — the
    monotone running max-abs per KV head defining the pool-wide quant
    scales both paged decode paths share — and, when ``cfg.fused_decode``,
    an **incremental bit-plane pool**: ``kq`` holds every page's K rows
    pre-quantized (INT-``bits``) and bit-packed 8 tokens/byte along the
    page axis — ``uint8[pool_blocks, bits, page_size//8, Hkv, D]`` —
    written at cache write time so the fused kernel never re-derives
    planes from the f32 pool (see ``_update_plane_pool`` for the
    rescale-on-demand rule)."""
    if paged is not None:
        nb, bs = paged.pool_blocks, paged.page_size
        cache = {
            "k": jnp.zeros((nb, bs, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((nb, bs, cfg.n_kv_heads, cfg.head_dim), dtype),
            "pos": jnp.full((nb, bs), POS_SENTINEL, jnp.int32),
            "table": jnp.zeros((batch, paged.max_blocks_per_req), jnp.int32),
            "length": jnp.zeros((batch,), jnp.int32),
        }
        if cfg.impl in ("bitstopper", "bitstopper_xla") and bs % 8 == 0:
            # Pool-wide running quant scales: needed by BOTH paged decode
            # paths (the kernel and the pure-JAX fallback oracle).
            cache["k_amax"] = jnp.zeros((cfg.n_kv_heads,), jnp.float32)
            cache["v_amax"] = jnp.zeros((cfg.n_kv_heads,), jnp.float32)
            if cfg.fused_decode:
                # The packed plane pool is read only by the fused kernel;
                # the fallback re-derives planes from the f32 pool, so
                # don't pay write-time packing/requants it won't use.
                bits = cfg.bitstopper.bits
                cache["kq"] = jnp.zeros(
                    (nb, bits, bs // 8, cfg.n_kv_heads, cfg.head_dim),
                    jnp.uint8)
        return cache
    n_slots = min(max_len, cfg.window) if (ring and cfg.window) else max_len
    if per_slot:
        pos = jnp.full((batch, n_slots), POS_SENTINEL, jnp.int32)
        length = jnp.zeros((batch,), jnp.int32)
    else:
        pos = jnp.full((n_slots,), POS_SENTINEL, jnp.int32)
        length = jnp.zeros((), jnp.int32)
    return {
        "k": jnp.zeros((batch, n_slots, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, n_slots, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": pos,
        "length": length,
    }


def cache_is_paged(cache) -> bool:
    return "table" in cache


def cache_is_per_slot(cache) -> bool:
    return cache_is_paged(cache) or cache["pos"].ndim == 2


def _update_cache(cache, k, v, positions):
    """Write the new token(s) into the cache.

    With active sharding rules and the cache's sequence axis sharded over
    "model", a plain dynamic-update-slice is decomposed by GSPMD into a
    masked SELECT over the whole local cache (full read+write of GiBs per
    layer per decoded token — measured as THE dominant decode traffic).
    The shard_map path does what serving systems do on real hardware: each
    shard tests whether the global slot lands in its range and performs an
    in-place LOCAL update of just that slot.
    """
    from repro.sharding.api import current_rules

    S = k.shape[1]
    n_slots = cache["k"].shape[1]
    kc = k.astype(cache["k"].dtype)
    vc = v.astype(cache["v"].dtype)
    pc = positions.astype(jnp.int32)

    if cache_is_per_slot(cache):
        # Per-slot layout: every batch row has its own cursor.  Writes are a
        # batched scatter at (length[b] + i) mod n_slots — rows at different
        # fill levels advance independently (continuous batching).  Tokens
        # whose position is the pad sentinel (bucketed prefill padding,
        # always trailing) are routed out of bounds and dropped, so pads
        # never consume ring slots or advance the cursor.
        B = kc.shape[0]
        pc2 = jnp.broadcast_to(pc, (B, S))
        real = pc2 != POS_SENTINEL
        idx = jax.lax.rem(
            cache["length"][:, None] + jnp.arange(S, dtype=jnp.int32)[None],
            n_slots)                                          # [B, S]
        idx = jnp.where(real, idx, n_slots)                   # OOB => dropped
        rows = jnp.arange(B)[:, None]
        ck = cache["k"].at[rows, idx].set(kc, mode="drop")
        cv = cache["v"].at[rows, idx].set(vc, mode="drop")
        cpos = cache["pos"].at[rows, idx].set(pc2, mode="drop")
        new = dict(cache, k=ck, v=cv, pos=cpos,
                   length=cache["length"] + real.sum(axis=1, dtype=jnp.int32))
        return ck, cv, cpos, new

    widx = jax.lax.rem(cache["length"], n_slots)
    rules = current_rules()
    use_shmap = (S == 1 and rules is not None
                 and "model" in rules.mesh.shape
                 and n_slots % rules.mesh.shape["model"] == 0)
    if not use_shmap:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, widx, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, widx, 1)
        cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pc, widx, 0)
    else:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh = rules.mesh
        bspec = rules.pspec(("batch",), (cache["k"].shape[0],))[0]
        cache_spec = P(bspec, "model", None, None)
        new_spec = P(bspec, None, None, None)

        def body(ck_l, cv_l, pos_l, kn, vn, pn, wi):
            T_loc = ck_l.shape[1]
            local = wi[0] - jax.lax.axis_index("model") * T_loc
            in_rng = (local >= 0) & (local < T_loc)
            idx = jnp.clip(local, 0, T_loc - 1)
            cur_k = jax.lax.dynamic_slice_in_dim(ck_l, idx, 1, 1)
            cur_v = jax.lax.dynamic_slice_in_dim(cv_l, idx, 1, 1)
            cur_p = jax.lax.dynamic_slice_in_dim(pos_l, idx, 1, 0)
            ck_l = jax.lax.dynamic_update_slice_in_dim(
                ck_l, jnp.where(in_rng, kn, cur_k), idx, 1)
            cv_l = jax.lax.dynamic_update_slice_in_dim(
                cv_l, jnp.where(in_rng, vn, cur_v), idx, 1)
            pos_l = jax.lax.dynamic_update_slice_in_dim(
                pos_l, jnp.where(in_rng, pn, cur_p), idx, 0)
            return ck_l, cv_l, pos_l

        ck, cv, cpos = shard_map(
            body, mesh=mesh,
            in_specs=(cache_spec, cache_spec, P("model"),
                      new_spec, new_spec, P(None), P(None)),
            out_specs=(cache_spec, cache_spec, P("model")),
            check_rep=False,
        )(cache["k"], cache["v"], cache["pos"], kc, vc, pc,
          widx[None])
    new = dict(cache, k=ck, v=cv, pos=cpos, length=cache["length"] + S)
    return ck, cv, cpos, new


def _update_plane_pool(cache, kc, vc, real, phys, p_safe, ok, k_pool_new):
    """Maintain the pool-wide quant scales — and, when the fused kernel is
    in play (``kq`` present), the incremental bit-plane pool — at cache
    write time.

    Scale policy (**rescale-on-demand**): ``k_amax``/``v_amax`` are the
    monotone running max-abs per KV head over every token ever written.
    While the max is stable, only the newly written tokens are quantized
    and their bits scattered into the packed pool (one byte column per
    token — O(written) traffic).  When a new token *grows* the max, every
    stored plane encodes integers under a stale scale, so the whole pool
    is requantized from the f32 K pool under the new scale — a rare,
    amortized event (max-abs growth is logarithmic in tokens served).

    Packing invariant: token at page offset ``t`` owns bit ``t % 8`` of
    byte ``t // 8`` (LSB-first, matching ``qlib.pack_planes_seq``).  Pages
    fill strictly front to back (allocator + append-only cursor), so a
    write to bit position ``b`` may clobber bits above ``b`` (never yet
    written, unreadable through the fill-level mask) but must preserve
    bits below ``b`` (earlier tokens) — hence the low-mask merge.
    """
    k_amax, v_amax = cache["k_amax"], cache["v_amax"]
    realm = real[..., None, None]
    kabs = jnp.abs(kc.astype(jnp.float32)) * realm
    vabs = jnp.abs(vc.astype(jnp.float32)) * realm
    k_hi = jnp.max(kabs, axis=(0, 1, 3))
    v_hi = jnp.max(vabs, axis=(0, 1, 3))
    # Growth overshoots by AMAX_HEADROOM so the running max settles after
    # a handful of events instead of creeping per token (each growth is a
    # whole-pool requant and/or a speculative bailout — see the constant).
    k_amax_new = jnp.where(k_hi > k_amax, k_hi * AMAX_HEADROOM, k_amax)
    v_amax_new = jnp.where(v_hi > v_amax, v_hi * AMAX_HEADROOM, v_amax)
    if "kq" not in cache:      # fallback decode: scales only, no packing
        return dict(k_amax=k_amax_new, v_amax=v_amax_new)
    kq = cache["kq"]
    nb, bits, bs8, H, D = kq.shape
    bs = bs8 * 8
    grew = jnp.any(k_amax_new > k_amax)
    k_scale = qlib.scale_from_amax(k_amax_new, bits)          # [H]

    def requant(kq):
        return qlib.pack_pool_planes(k_pool_new, k_amax_new, bits)

    def incremental(kq):
        S = real.shape[1]
        k_int = qlib.quantize_with_scale(
            kc, k_scale[None, None, :, None], bits)           # [B,S,H,D]
        u = jnp.where(k_int < 0, k_int + (1 << bits), k_int).astype(jnp.uint32)
        shifts = jnp.arange(bits - 1, -1, -1,
                            dtype=jnp.uint32).reshape(1, bits, 1, 1)

        def write_one(s, kq):
            us = u[:, s]                                      # [B, H, D]
            tokbits = ((us[:, None] >> shifts) & 1).astype(jnp.int32)
            off = p_safe[:, s] % bs
            byte, bitpos = off // 8, off % 8                  # [B]
            row = jnp.where(ok[:, s], phys[:, s], nb)         # OOB => dropped
            old = kq.at[row, :, byte].get(
                mode="fill", fill_value=0).astype(jnp.int32)  # [B,bits,H,D]
            lowmask = ((1 << bitpos) - 1)[:, None, None, None]
            newbyte = ((old & lowmask)
                       | (tokbits << bitpos[:, None, None, None]))
            return kq.at[row, :, byte].set(newbyte.astype(jnp.uint8),
                                           mode="drop")

        return jax.lax.fori_loop(0, S, write_one, kq)

    kq_new = jax.lax.cond(grew, requant, incremental, kq)
    return dict(kq=kq_new, k_amax=k_amax_new, v_amax=v_amax_new)


def _update_paged_cache(cache, k, v, positions):
    """Write new token(s) into the paged block-pool cache; returns ONLY the
    new cache — no logical view is materialized (callers that still need a
    dense gather ask :func:`gather_paged_view` explicitly).

    The K/V pool has no batch axis — every batch row (serving slot)
    scatters through its row of the block table.  A token at absolute
    position p lives in logical block p // bs at offset p % bs; the table
    maps logical -> physical block id.  Writes never target physical block
    0 (the null block backing unused table entries), and pad-sentinel
    tokens are routed out of bounds and dropped — exactly like the
    contiguous per-slot path."""
    nb, bs = cache["pos"].shape
    S = k.shape[1]
    kc = k.astype(cache["k"].dtype)
    vc = v.astype(cache["v"].dtype)
    pc = positions.astype(jnp.int32)
    B = kc.shape[0]
    table = cache["table"]                                    # [B, MB]
    MB = table.shape[1]
    pc2 = jnp.broadcast_to(pc, (B, S))
    real = pc2 != POS_SENTINEL
    p_safe = jnp.where(real, pc2, 0)
    logical = p_safe // bs
    phys = jnp.take_along_axis(table, jnp.clip(logical, 0, MB - 1),
                               axis=1)                        # [B, S]
    ok = real & (logical < MB) & (phys > 0)
    flat_idx = jnp.where(ok, phys * bs + p_safe % bs, nb * bs)
    kf = cache["k"].reshape((nb * bs,) + cache["k"].shape[2:])
    vf = cache["v"].reshape((nb * bs,) + cache["v"].shape[2:])
    pf = cache["pos"].reshape(nb * bs)
    fi = flat_idx.reshape(-1)
    kf = kf.at[fi].set(kc.reshape((-1,) + kc.shape[2:]), mode="drop")
    vf = vf.at[fi].set(vc.reshape((-1,) + vc.shape[2:]), mode="drop")
    pf = pf.at[fi].set(pc2.reshape(-1), mode="drop")
    new_len = cache["length"] + real.sum(axis=1, dtype=jnp.int32)
    new = dict(cache, k=kf.reshape(cache["k"].shape),
               v=vf.reshape(cache["v"].shape),
               pos=pf.reshape(nb, bs), length=new_len)
    if "k_amax" in cache:
        new.update(_update_plane_pool(cache, kc, vc, real, phys, p_safe, ok,
                                      new["k"]))
    return new


def gather_paged_view(cache, active=None):
    """Gather each row's dense logical view ``[B, MB*bs]`` from the pool.

    Only the first length[b] view slots were ever written by (or shared
    into) row b, so slots past the fill level are forced invalid and
    zeroed: a recycled physical block's stale K/V and positions are
    unobservable, and zeroed tails keep the BitStopper per-tensor max-abs
    quant scale identical to the contiguous layout.

    ``active`` ([B] bool) gates the gather to rows that actually attend
    this step: an inactive row's table is swapped for the null block, so
    its gather touches a single hot page instead of pulling
    ``max_blocks_per_req`` cold pages per layer.  (The fused decode path
    skips this gather entirely — it walks physical pages in the kernel.)
    """
    nb, bs = cache["pos"].shape
    table = cache["table"]
    if active is not None:
        table = jnp.where(active[:, None], table, 0)
    B, MB = table.shape
    Tv = MB * bs
    kf = cache["k"].reshape((nb * bs,) + cache["k"].shape[2:])
    vf = cache["v"].reshape((nb * bs,) + cache["v"].shape[2:])
    pf = cache["pos"].reshape(nb * bs)
    view = (table[..., None] * bs
            + jnp.arange(bs, dtype=jnp.int32)).reshape(B, Tv)
    k_view = kf[view]                                         # [B, Tv, H, D]
    v_view = vf[view]
    pos_view = pf[view]
    valid = jnp.arange(Tv, dtype=jnp.int32)[None] < cache["length"][:, None]
    pos_view = jnp.where(valid, pos_view, POS_SENTINEL)
    k_view = jnp.where(valid[..., None, None], k_view, 0)
    v_view = jnp.where(valid[..., None, None], v_view, 0)
    return k_view, v_view, pos_view


def _paged_shard_rules(cfg: AttnConfig):
    """Active mesh rules iff the paged pools are KV-head-sharded under them.

    Shardable iff a >1 "model" axis divides ``n_kv_heads`` — the same
    divisibility gate PAGED_CACHE_RULES applies to the pool placement, so
    this and the cache layout agree by construction.  When it fails (MQA's
    single KV head on a multi-way axis) the pools are replicated and the
    plain single-device call is already correct."""
    from repro.sharding.api import current_rules
    rules = current_rules()
    if rules is None:
        return None
    tp = rules.mesh.shape.get("model", 0)
    if tp <= 1 or cfg.n_kv_heads % tp != 0:
        return None
    return rules


def _shard_paged_attention(fn, rules, q, kpool, vpool, table, lengths,
                           q_pos, k_amax, v_amax):
    """Run a paged BESF entry point tensor-parallel over KV heads.

    Per-(slot, KV head) independence is what makes this exact: every BESF
    quantity — the LATS thresholds, bit-plane partial scores, the softmax
    normalizer, the V accumulation — reduces only within one (slot, KV
    head) pair, so splitting ``Hkv`` over "model" (grouped Q heads ride
    along: Q heads are KV-major, ``h -> h // G``) changes NO float
    reduction order.  Each shard runs the unmodified kernel/oracle at
    local geometry ``Hkv/tp`` against its slice of the bit-plane/V pools
    and amax scales (block table and fill levels replicated), and the
    trailing all-gather back to replicated heads is pure data movement —
    so the downstream ``wo`` matmul sums in single-device order and the
    output stays bit-identical to the unsharded run.  Slots shard over
    "data" the same way (batch rows are independent)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = rules.mesh
    verify = q.ndim == 4                                  # [B,Sq,Hq,D]
    bspec = rules.pspec(("batch",), (q.shape[0],))[0]
    qspec = (P(bspec, None, "model", None) if verify
             else P(bspec, "model", None))
    kspec = (P(None, None, None, "model", None) if kpool.ndim == 5
             else P(None, None, "model", None))           # kq vs f32 pool
    lspec = P(bspec, None) if verify else P(bspec)
    out = shard_map(
        lambda *a: fn(*a).out, mesh=mesh,
        in_specs=(qspec, kspec, P(None, None, "model", None),
                  P(bspec, None), lspec, lspec, P("model"), P("model")),
        out_specs=qspec, check_rep=False,
    )(q, kpool, vpool, table, lengths, q_pos, k_amax, v_amax)
    gspec = (P(bspec, None, None, None) if verify
             else P(bspec, None, None))
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, gspec))


def _paged_cached_attention(q, cache, positions, cfg: AttnConfig):
    """Attention against the (already updated) paged cache.

    The Sq == 1 BitStopper decode goes straight at the pool handles
    (block table + fill levels + bit-plane pool): the fused Pallas kernel
    when ``cfg.fused_decode``, else the pure-JAX paged oracle — the
    retained gather fallback with identical page-sequential semantics.
    Everything else (dense impl, prefill chunks, planeless pools) gathers
    the logical view, gated to active rows."""
    B, S = q.shape[:2]
    active = (positions != POS_SENTINEL).any(axis=1)
    if (cfg.spec_verify and cfg.impl in ("bitstopper", "bitstopper_xla")
            and "k_amax" in cache):
        # Speculative verify: score the whole draft block in one paged
        # multi-query BESF pass.  Every real query runs with its own fill
        # level (its position + 1 — the batched cache write has already
        # scattered the draft tokens, so query i sees exactly the KV set
        # the Sq=1 decode at that position would see: causal intra-draft
        # masking for free).  Padding queries (slot proposed fewer drafts,
        # or a row still prefilling) get fill level 0 and touch no pages.
        real = positions != POS_SENTINEL                      # [B, S]
        q_pos = jnp.where(real, positions, 0)
        lengths = jnp.where(real, q_pos + 1, 0)
        if cfg.fused_decode:
            from repro.kernels.paged_verify import paged_bitstopper_verify
            call = functools.partial(
                paged_bitstopper_verify,
                cfg=cfg.bitstopper, window=cfg.window, stats=False)
            pool = cache["kq"]
        else:
            call = functools.partial(
                besf_attention_verify_paged,
                cfg=cfg.bitstopper, window=cfg.window)
            pool = cache["k"]
        args = (q, pool, cache["v"], cache["table"], lengths, q_pos,
                cache["k_amax"], cache["v_amax"])
        rules = _paged_shard_rules(cfg)
        if rules is not None:
            out = _shard_paged_attention(call, rules, *args)
        else:
            out = call(*args).out
        return out.astype(q.dtype)                            # [B,S,Hq,Dv]
    if (cfg.impl in ("bitstopper", "bitstopper_xla") and S == 1
            and "k_amax" in cache):
        qt = q[:, 0]                                          # [B, Hq, D]
        q_pos = positions[:, 0]
        # Gate to active rows: a slot still prefilling decodes at the pad
        # sentinel (its output is discarded by the engine) — zeroing its
        # fill level makes every page unreachable, so the kernel issues
        # ZERO DMAs for it instead of walking its blocks per layer.
        lengths = jnp.where(active, cache["length"], 0)
        if cfg.fused_decode:
            from repro.kernels.paged_decode import paged_bitstopper_decode
            call = functools.partial(
                paged_bitstopper_decode,
                cfg=cfg.bitstopper, window=cfg.window, stats=False)
            pool = cache["kq"]
        else:
            call = functools.partial(
                besf_attention_decode_paged,
                cfg=cfg.bitstopper, window=cfg.window)
            pool = cache["k"]
        args = (qt, pool, cache["v"], cache["table"], lengths, q_pos,
                cache["k_amax"], cache["v_amax"])
        rules = _paged_shard_rules(cfg)
        if rules is not None:
            out = _shard_paged_attention(call, rules, *args)
        else:
            out = call(*args).out
        return out[:, None].astype(q.dtype)                   # [B,1,Hq,Dv]
    k_view, v_view, pos_view = gather_paged_view(cache, active)
    return _cached_attention(q, k_view, v_view, positions, pos_view, cfg)


def _cached_attention(q, k_all, v_all, q_positions, k_positions,
                      cfg: AttnConfig):
    """Attention against the (padded/ring) cache, mask from slot positions.

    ``q_positions`` / ``k_positions`` may be 1-D (legacy shared-cursor
    cache: mask shared across the batch) or 2-D [B, ...] (per-slot cache:
    every batch row masks against its own fill level)."""
    B, Sq = q.shape[0], q.shape[1]
    per_slot = q_positions.ndim == 2
    if per_slot:
        mask = jax.vmap(
            lambda qp, kp: _mask_block(qp, kp, causal=True, window=cfg.window)
        )(q_positions, k_positions)                          # [B, Sq, T]
    else:
        mask = _mask_block(q_positions, k_positions, causal=True,
                           window=cfg.window)                # [Sq, T]
    bmask = mask if per_slot else jnp.broadcast_to(
        mask[None], (B,) + mask.shape)

    if cfg.impl in ("bitstopper_xla", "bitstopper"):
        if Sq == 1:
            # Decode fast path: single-query BESF with the per-round
            # threshold-scan setup amortized across planes (one fused int
            # plane contraction per head instead of one per bit round).
            from repro.core.besf import besf_attention_decode
            qt, kr, vr = _expand_gqa(q, k_all, v_all,
                                     cfg.n_heads // cfg.n_kv_heads)
            res = besf_attention_decode(
                qt, kr, vr, cfg=cfg.bitstopper, mask=bmask[:, None])
            return res.out.swapaxes(1, 2).astype(q.dtype)
        if not per_slot:
            return _bitstopper_full(q, k_all, v_all, cfg, mask)
        if B == 1:
            return _bitstopper_full(q, k_all, v_all, cfg, mask[0])
        # Per-slot multi-request prefill: per-token reference with
        # per-example masks (rare; the engine prefills one slot at a time).
        from repro.core.besf import besf_attention
        qt, kr, vr = _expand_gqa(q, k_all, v_all,
                                 cfg.n_heads // cfg.n_kv_heads)
        res = besf_attention(qt, kr, vr, cfg=cfg.bitstopper,
                             mask=bmask[:, None])
        return res.out.swapaxes(1, 2).astype(q.dtype)

    G = cfg.n_heads // cfg.n_kv_heads
    B, T, Hkv, D = k_all.shape
    qg = q.reshape(q.shape[0], q.shape[1], Hkv, G, D)
    # Mixed-dtype einsums with f32 accumulation: never materialize an f32
    # copy of the (multi-GiB) KV cache — reads stay bf16 (measured ~3x
    # decode HBM-traffic reduction vs .astype(f32) upcasting).
    logits = jnp.einsum("bqhgd,bthd->bhgqt", qg, k_all,
                        preferred_element_type=jnp.float32) / D ** 0.5
    logits = jnp.where(bmask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(bmask[:, None, None], p, 0.0)
    out = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(v_all.dtype), v_all,
                     preferred_element_type=jnp.float32)
    return out.reshape(q.shape).astype(q.dtype)


# ---------------------------------------------------------------------------
# Public layer
# ---------------------------------------------------------------------------


def attention(
    p,
    x: jax.Array,                    # [B, S, d_model]
    positions: jax.Array,            # [S]
    cfg: AttnConfig,
    cache: dict[str, Any] | None = None,
):
    """Returns (out [B,S,d_model], new_cache).

    ``positions`` is [S] (shared across the batch) or, with a per-slot
    cache, [B, S] — each serving slot decodes at its own absolute position.
    """
    B, S, _ = x.shape
    if cache is not None and cache_is_per_slot(cache) and positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (B, S))
    q = L.linear(p["wq"], x)                         # [B, S, Hq, D]
    k = L.linear(p["wk"], x)                         # [B, S, Hkv, D]
    v = L.linear(p["wv"], x)
    rope_pos = positions if positions.ndim == 2 else positions[None, :]
    q = L.rope(q, rope_pos, cfg.rope_theta)
    k = L.rope(k, rope_pos, cfg.rope_theta)
    if positions.ndim == 2:
        # Zero pad rows (bucketed-prefill sentinel positions): their k/v are
        # dropped by the cache scatter, and zero q rows keep the BitStopper
        # per-tensor max-abs quant scale independent of how much bucket
        # padding a request happened to get.
        real = (positions != POS_SENTINEL)[..., None, None]
        q, k, v = q * real, k * real, v * real
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)

    if cache is None:
        if positions.ndim == 2:
            # The cache-free (training/prefill) path is batch-uniform; 2-D
            # positions only arise from the per-slot serving cache.
            positions = positions[0]
        if cfg.impl in ("bitstopper_xla", "bitstopper"):
            mask2d = None
            if cfg.window is not None:
                mask2d = _mask_block(positions, positions, cfg.causal,
                                     cfg.window)
            out = _bitstopper_full(q, k, v, cfg, mask2d)
        elif cfg.impl == "flash" and cfg.window is None:
            qt, kr, vr = _expand_gqa(q, k, v, cfg.n_heads // cfg.n_kv_heads)
            out = kops.attention(qt, kr, vr, impl="flash",
                                 causal=cfg.causal).swapaxes(1, 2)
        else:
            out = chunked_attention(
                q, k, v, positions, positions,
                causal=cfg.causal, window=cfg.window,
                chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k,
            )
        new_cache = None
    elif cache_is_paged(cache):
        new_cache = _update_paged_cache(cache, k, v, positions)
        out = _paged_cached_attention(q, new_cache, positions, cfg)
    else:
        k_all, v_all, k_pos, new_cache = _update_cache(cache, k, v, positions)
        out = _cached_attention(q, k_all, v_all, positions, k_pos, cfg)

    # Pin the head layout entering the wo contraction via the "heads_out"
    # logical axis.  Training rules map it to "model" (Megatron: partial
    # products + psum against the heads_flat-sharded wo).  Serving rules
    # map it to None: the all-gather back to replicated heads is pure data
    # movement, so the flattened-head matmul sums in single-device order —
    # the serving bit-identity invariant (docs/serving.md).
    out = constrain(out, "batch", "seq", "heads_out", None)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    y = L.linear(p["wo"], out)
    y = constrain(y, "batch", "seq", "embed")
    return y, new_cache


# ---------------------------------------------------------------------------
# Paged block-row transport: the device<->host seam of the memory hierarchy.
#
# These helpers move whole physical blocks between the paged device pools
# and host arrays — the engine's Prefix.payload handoff (PR 9), swap-to-
# host preemption and the persistent prefix store are all built on them.
# They live here (not in serving/) because they encode the paged cache
# layout: which per-layer arrays exist, how stacked (scanned) layers carry
# a leading reps axis, and how the packed plane pool relates to the f32
# pool and the amax scales.
# ---------------------------------------------------------------------------


def iter_paged_layers(tree):
    """Yield every paged-layer cache dict in the pytree, in deterministic
    (sorted-dict-key / list-index) order.  All transport helpers below use
    this same traversal, so extracted layer lists and splice targets pair
    up positionally."""
    if isinstance(tree, dict):
        if cache_is_paged(tree):
            yield tree
        else:
            for key in sorted(tree):
                yield from iter_paged_layers(tree[key])
    elif isinstance(tree, (list, tuple)):
        for sub in tree:
            yield from iter_paged_layers(sub)


def map_paged_layers(tree, fn, _counter=None):
    """Rebuild the pytree with ``fn(layer_dict, layer_index)`` applied to
    every paged-layer cache dict (same order as :func:`iter_paged_layers`)."""
    if _counter is None:
        _counter = [0]
    if isinstance(tree, dict):
        if cache_is_paged(tree):
            i = _counter[0]
            _counter[0] += 1
            return fn(tree, i)
        return {k: map_paged_layers(tree[k], fn, _counter)
                for k in sorted(tree)}
    if isinstance(tree, (list, tuple)):
        mapped = [map_paged_layers(sub, fn, _counter) for sub in tree]
        return type(tree)(mapped) if isinstance(tree, tuple) else mapped
    return tree


def _rows_take(c, field, idx):
    a = c[field]
    return a[:, idx] if c["table"].ndim == 3 else a[idx]


def extract_block_rows(caches, bids, planes: bool = False):
    """Device→host copy of whole physical blocks ``bids`` from every paged
    layer.  Returns one dict per paged layer holding numpy ``k``/``v``
    rows and the ``pos`` plane (stacked layers keep their leading reps
    axis), plus the packed ``kq`` plane rows when ``planes=True`` and the
    layer maintains them.  Registered blocks are append-only and full
    blocks are never rewritten, so extracted rows stay valid until the
    block is freed and poisoned/reused."""
    import numpy as np
    idx = jnp.asarray(list(bids), jnp.int32)
    layers = []
    for c in iter_paged_layers(caches):
        rows = {"k": np.asarray(_rows_take(c, "k", idx)),
                "v": np.asarray(_rows_take(c, "v", idx)),
                "pos": np.asarray(_rows_take(c, "pos", idx))}
        if planes and "kq" in c:
            rows["kq"] = np.asarray(_rows_take(c, "kq", idx))
        layers.append(rows)
    return layers


def splice_block_rows(caches, bids, layers, sel=None):
    """Scatter rows from :func:`extract_block_rows` into physical blocks
    ``bids`` of every paged layer.  ``sel`` picks which record rows feed
    which bid (``bids[i] <- rows[sel[i]]``; default: all rows in order).
    ``kq`` rows are spliced only when both the record and the cache carry
    them — a caller whose scales moved since extraction must skip/repack
    instead (:func:`repack_block_planes`)."""
    idx = jnp.asarray(list(bids), jnp.int32)

    def put(c, i):
        rows = layers[i]
        stacked = c["table"].ndim == 3
        new = dict(c)
        for field in ("k", "v", "pos", "kq"):
            if field not in rows or field not in c:
                continue
            val = jnp.asarray(rows[field]).astype(c[field].dtype)
            if sel is not None:
                s = jnp.asarray(list(sel), jnp.int32)
                val = val[:, s] if stacked else val[s]
            new[field] = (c[field].at[:, idx].set(val) if stacked
                          else c[field].at[idx].set(val))
        return new

    return map_paged_layers(caches, put)


def requant_plane_pools(caches):
    """Rebuild every packed K bit-plane pool from its f32 pool under the
    current amax scales.  ``pack_pool_planes`` is a pure function of
    (f32 pool, amax), so the rebuilt planes are bit-identical to an
    incrementally maintained pool whose last requant happened at the
    current scales — the fix-up step after any operation that moves
    ``k_amax`` out from under stored planes (detached-prefix amax merge,
    store injection that grew the scale)."""
    def rq(c, _i):
        if "kq" not in c:
            return c
        stacked = c["table"].ndim == 3
        bits = c["kq"].shape[2] if stacked else c["kq"].shape[1]
        kf = c["k"].astype(jnp.float32)
        if stacked:
            kq = jax.vmap(
                lambda kp, am: qlib.pack_pool_planes(kp, am, bits)
            )(kf, c["k_amax"])
        else:
            kq = qlib.pack_pool_planes(kf, c["k_amax"], bits)
        return dict(c, kq=kq.astype(c["kq"].dtype))

    return map_paged_layers(caches, rq)


def repack_block_planes(caches, bids):
    """Rebuild the packed planes of just blocks ``bids`` from their (just
    spliced) f32 rows under the CURRENT scales — the no-growth injection
    path.  Bit-identical to the incremental write rule quantizing the
    same tokens under the same unchanged scale, at O(len(bids)) cost
    instead of a whole-pool requant."""
    idx = jnp.asarray(list(bids), jnp.int32)

    def rp(c, _i):
        if "kq" not in c:
            return c
        stacked = c["table"].ndim == 3
        bits = c["kq"].shape[2] if stacked else c["kq"].shape[1]
        if stacked:
            packed = jax.vmap(
                lambda kp, am: qlib.pack_pool_planes(kp, am, bits)
            )(c["k"][:, idx].astype(jnp.float32), c["k_amax"])
            return dict(c, kq=c["kq"].at[:, idx].set(
                packed.astype(c["kq"].dtype)))
        packed = qlib.pack_pool_planes(c["k"][idx].astype(jnp.float32),
                                       c["k_amax"], bits)
        return dict(c, kq=c["kq"].at[idx].set(packed.astype(c["kq"].dtype)))

    return map_paged_layers(caches, rp)


def apply_inject_amax_rule(caches, layers, groups):
    """Replay the cache-write scale rule for store-injected rows, one
    application per chunk group — exactly the trajectory chunked prefill
    of the same tokens would have produced.

    ``layers`` pairs with the paged layers (an :func:`extract_block_rows`
    result); ``groups`` is a list of chunk groups, each a list of
    ``(row, lo, hi)`` — a record row index plus the token-offset window
    within that block belonging to the group (chunk boundaries need not
    align with page boundaries).  Host-side numpy on purpose: ``abs`` and
    ``max`` are exact, and the float32 ``AMAX_HEADROOM`` multiply rounds
    identically to the device rule in ``_update_plane_pool``, so the
    resulting leaves are bit-identical to the recompute reference's.

    Returns ``(new_caches, k_grew)`` — ``k_grew`` True iff any K scale
    moved (the caller must then :func:`requant_plane_pools`, mirroring
    the reference's growth-triggered whole-pool requant; otherwise
    :func:`repack_block_planes` of the injected blocks suffices)."""
    import numpy as np
    headroom = np.float32(AMAX_HEADROOM)
    k_grew = [False]

    def window_hi(rows, stacked, row, lo, hi):
        if stacked:                       # [reps, nrows, bs, H, D]
            w = np.abs(rows[:, row, lo:hi])
            return w.max(axis=(1, 3), initial=np.float32(0.0))
        w = np.abs(rows[row, lo:hi])      # [hi-lo, H, D]
        return w.max(axis=(0, 2), initial=np.float32(0.0))

    def upd(c, i):
        if "k_amax" not in c:
            return c
        rows = layers[i]
        stacked = c["table"].ndim == 3
        k_rows = np.asarray(rows["k"], np.float32)
        v_rows = np.asarray(rows["v"], np.float32)
        k_amax = np.asarray(c["k_amax"], np.float32).copy()
        v_amax = np.asarray(c["v_amax"], np.float32).copy()
        k0, v0 = k_amax.copy(), v_amax.copy()
        for group in groups:
            k_hi = np.zeros(k_amax.shape, np.float32)
            v_hi = np.zeros(v_amax.shape, np.float32)
            for row, lo, hi in group:
                k_hi = np.maximum(k_hi, window_hi(k_rows, stacked, row,
                                                  lo, hi))
                v_hi = np.maximum(v_hi, window_hi(v_rows, stacked, row,
                                                  lo, hi))
            k_new = np.where(k_hi > k_amax, k_hi * headroom, k_amax)
            v_new = np.where(v_hi > v_amax, v_hi * headroom, v_amax)
            if (k_new > k_amax).any():
                k_grew[0] = True
            k_amax = k_new.astype(np.float32)
            v_amax = v_new.astype(np.float32)
        if (k_amax == k0).all() and (v_amax == v0).all():
            return c
        return dict(c, k_amax=jnp.asarray(k_amax),
                    v_amax=jnp.asarray(v_amax))

    return map_paged_layers(caches, upd), k_grew[0]
