"""Composable model definitions (pure-functional JAX, pytree params)."""
