"""Modality frontends — STUBS per the assignment.

``[audio]`` / ``[vlm]`` archs specify the transformer *backbone* only; the
modality encoder (EnCodec / CLIP-ViT) is out of scope.  ``input_specs()``
supplies precomputed frame/patch embeddings; these helpers splice them into
the token stream so the backbone sees an ordinary [B, S, d_model] sequence.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import layers as L


def audio_frontend(params, codes, cfg):
    """MusicGen-style: EnCodec codes ARE discrete tokens (vocab 2048); the
    'frontend' is just the embedding table — returned as embeddings so the
    backbone path is uniform with the VLM case."""
    return L.embed(params["embed"], codes).astype(cfg.activation_dtype)


def vision_frontend(params, tokens, patch_embeds, cfg):
    """LLaVA-NeXT-style: precomputed anyres patch embeddings [B, P, D] are
    prepended to the embedded text tokens [B, S_text, D]."""
    text = L.embed(params["embed"], tokens)
    return jnp.concatenate(
        [patch_embeds.astype(text.dtype), text], axis=1
    ).astype(cfg.activation_dtype)
