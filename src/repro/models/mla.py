"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and KV are low-rank compressed; the KV cache stores only the latent
``c_kv`` [S, kv_rank] plus the decoupled RoPE key [S, rope_dim].

Two execution paths:
* **prefill/train** — expand the latents into per-head K/V and run standard
  chunked attention (compute-bound regime; expansion is one matmul).
* **decode (absorbed)** — fold ``W_uk`` into the query so scores form
  directly against the latent cache:  ``s = (q_nope W_uk) · c_kv + q_pe·k_pe``.
  This is where BitStopper applies for this arch: the latent cache is the
  K operand, so bit-plane early termination prunes *latent rows* — identical
  token granularity, d = kv_rank + rope_dim.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.besf import BitStopperConfig
from repro.models import layers as L
from repro.sharding.api import constrain

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_rank: int = 1536
    kv_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    impl: str = "xla"               # decode path: "xla" | "bitstopper_xla"
    bitstopper: BitStopperConfig = BitStopperConfig()
    chunk_q: int = 512
    chunk_k: int = 512


def init_mla(key, cfg: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": L.init_linear(ks[0], cfg.d_model, cfg.q_rank, False, dtype),
        "q_norm": L.init_rmsnorm(cfg.q_rank, dtype),
        "wq_b": L.init_linear(ks[1], cfg.q_rank, (cfg.n_heads, qk_dim), False, dtype),
        "wkv_a": L.init_linear(ks[2], cfg.d_model,
                               cfg.kv_rank + cfg.qk_rope_dim, False, dtype),
        "kv_norm": L.init_rmsnorm(cfg.kv_rank, dtype),
        "wkv_b": L.init_linear(ks[3], cfg.kv_rank,
                               (cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim),
                               False, dtype),
        "wo": L.init_linear(ks[4], cfg.n_heads * cfg.v_head_dim, cfg.d_model,
                            False, dtype),
    }


def _project_q(p, x, cfg: MLAConfig, positions):
    q_lat = L.rms_norm(p["q_norm"], L.linear(p["wq_a"], x))
    q = L.linear(p["wq_b"], q_lat)                       # [B,S,H,nope+rope]
    q_nope = q[..., : cfg.qk_nope_dim]
    q_pe = L.rope(q[..., cfg.qk_nope_dim:], positions[None, :], cfg.rope_theta)
    return q_nope, q_pe


def _project_kv_latent(p, x, cfg: MLAConfig, positions):
    kv = L.linear(p["wkv_a"], x)                         # [B,S,kv_rank+rope]
    c_kv = L.rms_norm(p["kv_norm"], kv[..., : cfg.kv_rank])
    k_pe = L.rope(kv[..., None, cfg.kv_rank:], positions[None, :],
                  cfg.rope_theta)[..., 0, :]             # [B,S,rope]
    return c_kv, k_pe


def mla_attention(
    p,
    x: jax.Array,                     # [B, S, d_model]
    positions: jax.Array,             # [S]
    cfg: MLAConfig,
    cache: dict[str, Any] | None = None,
):
    """Returns (out, new_cache).  Cache = latent c_kv + k_pe (MLA's point)."""
    B, S, _ = x.shape
    q_nope, q_pe = _project_q(p, x, cfg, positions)
    c_kv, k_pe = _project_kv_latent(p, x, cfg, positions)
    q_nope = constrain(q_nope, "batch", None, "heads", None)

    if cache is None:
        # Prefill/train: expand latents to per-head K/V, chunked attention.
        kv = L.linear(p["wkv_b"], c_kv)                  # [B,S,H,nope+v]
        k_nope = kv[..., : cfg.qk_nope_dim]
        v = kv[..., cfg.qk_nope_dim:]
        k_pe_h = jnp.broadcast_to(k_pe[:, :, None, :],
                                  k_pe.shape[:2] + (cfg.n_heads, cfg.qk_rope_dim))
        q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
        k_full = jnp.concatenate([k_nope, k_pe_h], axis=-1)
        from repro.models.attention import chunked_attention
        out = chunked_attention(
            q_full, k_full, v, positions, positions,
            causal=True, window=None,
            chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k,
        )                                                 # [B,S,H,v_dim]
        out = out.reshape(B, S, cfg.n_heads * cfg.v_head_dim)
        y = L.linear(p["wo"], out)
        return constrain(y, "batch", None, "embed"), None

    # Decode: absorbed scoring against the latent cache.
    idx = cache["length"]
    c_all = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), idx, 1)
    pe_all = jax.lax.dynamic_update_slice_in_dim(
        cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), idx, 1)
    new_cache = {"c_kv": c_all, "k_pe": pe_all, "length": idx + S}

    w_kv_b = p["wkv_b"]["w"]                              # [kv_rank, H, nope+v]
    w_uk = w_kv_b[..., : cfg.qk_nope_dim]                 # [kv_rank, H, nope]
    w_uv = w_kv_b[..., cfg.qk_nope_dim:]                  # [kv_rank, H, v]

    # Absorb W_uk into q: q_abs [B,S,H,kv_rank].
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    T = c_all.shape[1]
    k_positions = jnp.arange(T)
    q_positions = positions
    mask = k_positions[None, :] <= q_positions[:, None]   # [S, T]

    if cfg.impl == "bitstopper_xla":
        # BitStopper on the latent cache: K rows are [c_kv ; k_pe] of width
        # kv_rank + rope_dim; queries are [q_abs ; q_pe].
        from repro.core.block_adaptation import block_bitstopper_attention
        q_cat = jnp.concatenate([q_abs, jnp.broadcast_to(
            q_pe.astype(jnp.float32), q_pe.shape)], axis=-1)
        k_cat = jnp.concatenate([c_all, pe_all], axis=-1) # [B,T,rank+rope]
        qt = q_cat.swapaxes(1, 2)                         # [B,H,S,rank+rope]
        d_lat = k_cat.shape[-1]
        sm = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
        # block_bitstopper applies 1/sqrt(d_lat); rescale via q so the
        # effective softmax scale matches 1/sqrt(qk_dim).
        qt = qt * (sm * d_lat ** 0.5)
        bq = min(128, qt.shape[2])
        bk = min(128, T)

        def per_head(qh, kb, vb):      # qh [S, dlat], kb [T, dlat]
            return block_bitstopper_attention(
                qh, kb, vb, cfg=cfg.bitstopper, block_q=bq, block_k=bk,
                mask=mask).scores

        def per_batch(qb, kb):         # qb [H, S, dlat], kb [T, dlat]
            dummy_v = jnp.ones((T, 1), jnp.float32)
            return jax.vmap(lambda a: per_head(a, kb, dummy_v))(qb)

        logits = jax.vmap(per_batch)(qt, k_cat.astype(jnp.float32))
        probs = jax.nn.softmax(jnp.where(logits <= NEG_INF / 2, NEG_INF, logits),
                               axis=-1)
        probs = jnp.where(logits <= NEG_INF / 2, 0.0, probs)
    else:
        sm = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
        # mixed-dtype einsums: no f32 copy of the latent cache
        s_lat = jnp.einsum("bshr,btr->bhst", q_abs.astype(c_all.dtype),
                           c_all, preferred_element_type=jnp.float32)
        s_pe = jnp.einsum("bshr,btr->bhst", q_pe.astype(pe_all.dtype),
                          pe_all, preferred_element_type=jnp.float32)
        logits = (s_lat + s_pe) * sm
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)

    # Weighted latent sum then expand through W_uv (absorbed V path).
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs.astype(c_all.dtype), c_all,
                         preferred_element_type=jnp.float32)
    out = jnp.einsum("bshr,rhv->bshv", ctx_lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, S, cfg.n_heads * cfg.v_head_dim).astype(x.dtype)
    y = L.linear(p["wo"], out)
    return constrain(y, "batch", None, "embed"), new_cache


def init_mla_cache(cfg: MLAConfig, batch: int, max_len: int, dtype=jnp.float32):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "length": jnp.zeros((), jnp.int32),
    }
