"""Mixture-of-Experts FFN: shared + routed experts, top-k, capacity-based.

Dispatch is **sort + capacity buffers** (the GShard/MaxText pattern, index
arithmetic instead of one-hot tensors): token replicas are bucketed into a
``[E_local, capacity, D]`` buffer by (expert, position-in-expert) scatter,
processed with one *batched* matmul per FFN weight (static shapes, MXU-
friendly), and gathered back.  FLOPs are ``capacity_factor ×`` the active
expert FLOPs — never E× dense compute — so the roofline "useful-FLOPs"
ratio stays honest.  (lax.ragged_dot was measured to lower dense-with-
group-dim on this backend: 100 GB temp / 15× FLOPs for ONE qwen-moe layer
backward — see EXPERIMENTS.md §Perf hillclimb log.)

Distribution (under ``shard_map`` over the full mesh; tokens are sharded
over the data axes and replicated over "model", which is how TP activations
already arrive):

* **EP** when ``n_routed % model_axis == 0`` (deepseek: 256/16 = 16 experts
  per shard).  Every shard routes its local tokens, scatters only rows
  bound for its own experts into its capacity buffer, and per-token outputs
  are ``psum``-combined over "model".  Expert weights are *stored* with the
  hidden dim FSDP-sharded over "data" (rules.py: ``expert_ffn → data``) and
  gathered just-in-time by the shard_map in_spec — ZeRO-3 for DeepSeek's
  1.3 TB of expert weights.
* **expert-TP** otherwise (qwen2-moe: 60 experts, 1408/16 = 88): all
  experts on every shard with the per-expert hidden dim split over "model"
  (stored that way, no gather), psum after ``wo``.

Tokens beyond an expert's capacity are dropped (standard; the router aux
loss keeps loads balanced, and capacity_factor=1.25 makes drops rare).
The router computes in f32, softmax-after-top-k renormalization behind a
flag, Switch-style load-balance aux loss.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_routed: int
    top_k: int
    d_expert: int                  # per-expert ffn hidden dim
    n_shared: int = 0              # shared experts (always active)
    d_shared: int = 0              # shared-expert hidden (total)
    act: str = "swiglu"
    norm_topk: bool = True         # renormalize top-k probs to sum 1
    router_scale: float = 1.0
    aux_loss_coef: float = 0.001
    capacity_factor: float = 1.25  # per-expert token capacity multiplier
    resident: bool = False         # decode: experts sharded over the FULL
                                   # mesh (1/dev), tokens gathered — no
                                   # per-layer weight gathers


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    E, D, H = cfg.n_routed, cfg.d_model, cfg.d_expert
    p = {
        "router": L.init_linear(ks[0], D, E, False, jnp.float32),
        "wi_gate": L.truncated_normal_init(ks[1], (E, D, H), 1.0, dtype),
        "wi_up": L.truncated_normal_init(ks[2], (E, D, H), 1.0, dtype),
        "wo": L.truncated_normal_init(ks[3], (E, H, D), 1.0, dtype),
    }
    if cfg.n_shared:
        p["shared"] = L.init_mlp(ks[4], D, cfg.d_shared, cfg.act, False, dtype)
    return p


def _route(p, x2d, cfg: MoEConfig):
    """x2d [T, D] → (weights [T,k], expert_ids [T,k], aux_loss)."""
    logits = L.linear(p["router"], x2d.astype(jnp.float32)) * cfg.router_scale
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)              # [T, k]
    if cfg.norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    T = x2d.shape[0]
    density = jnp.zeros((cfg.n_routed,)).at[top_i.reshape(-1)].add(1.0) / (
        T * cfg.top_k)
    mean_prob = probs.mean(axis=0)
    aux = cfg.n_routed * jnp.sum(density * mean_prob) * cfg.aux_loss_coef
    return top_p, top_i, aux


def _capacity(T: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(factor * T * top_k / max(n_experts, 1))
    return max(8, -(-c // 8) * 8)          # round up to a multiple of 8


def _local_moe(p_w, x2d, top_p, top_i, cfg: MoEConfig,
               expert_offset, n_local: int, capacity: int):
    """One shard's routed-expert compute (also the single-device path).

    Scatter rows into [n_local, capacity, D] by (expert, slot), batched
    matmuls, gather back.  Rows for non-local experts (or beyond capacity)
    contribute nothing.
    """
    T, D = x2d.shape
    k = cfg.top_k
    R = T * k
    flat_eid = top_i.reshape(R)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    flat_w = top_p.reshape(R)

    local_eid = flat_eid - expert_offset
    is_local = (local_eid >= 0) & (local_eid < n_local)
    key = jnp.where(is_local, local_eid, n_local)
    # Slot within the expert bucket = running count of prior rows with the
    # same expert id (computed via sorted positions, no one-hot tensors).
    order = jnp.argsort(key)
    sorted_key = key[order]
    gs = jnp.bincount(key, length=n_local + 1)[:n_local]
    starts = jnp.concatenate([jnp.zeros((1,), gs.dtype), jnp.cumsum(gs)])[:-1]
    slot = jnp.arange(R) - starts[jnp.clip(sorted_key, 0, n_local - 1)]
    valid = (sorted_key < n_local) & (slot < capacity)
    e_idx = jnp.where(valid, sorted_key, 0)
    s_idx = jnp.where(valid, slot, 0)
    tok_sorted = flat_tok[order]

    rows = x2d[tok_sorted] * valid[:, None].astype(x2d.dtype)
    buf = jnp.zeros((n_local, capacity, D), x2d.dtype)
    buf = buf.at[e_idx, s_idx].add(rows, mode="drop")

    a = jnp.einsum("ecd,edh->ech", buf, p_w["wi_gate"],
                   preferred_element_type=jnp.float32)
    b = jnp.einsum("ecd,edh->ech", buf, p_w["wi_up"],
                   preferred_element_type=jnp.float32)
    actf = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
    h = (actf(a) * b).astype(x2d.dtype)
    y_buf = jnp.einsum("ech,ehd->ecd", h, p_w["wo"],
                       preferred_element_type=jnp.float32)

    y_rows = y_buf[e_idx, s_idx] * valid[:, None]
    y_rows = y_rows * flat_w[order][:, None]
    out = jnp.zeros((T, D), y_rows.dtype).at[tok_sorted].add(
        y_rows, mode="drop")
    return out


def moe_ffn(p, x, cfg: MoEConfig, mesh=None, ep_axis: str = "model"):
    """Full MoE block.  x [B, S, D] → (out, aux_loss)."""
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)
    top_p, top_i, aux = _route(p, x2d, cfg)

    tp = mesh.shape[ep_axis] if (mesh is not None and ep_axis in mesh.shape) else 1

    if tp == 1:
        cap = _capacity(B * S, cfg.top_k, cfg.n_routed, cfg.capacity_factor)
        out2d = _local_moe(p, x2d, top_p, top_i, cfg, 0, cfg.n_routed, cap)
    else:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        dp = tuple(a for a in mesh.axis_names if a != ep_axis)

        ep_pair = ("data", ep_axis)
        resident_ok = (cfg.resident and "data" in mesh.shape
                       and cfg.n_routed % (mesh.shape["data"] * tp) == 0)
        if resident_ok:
            # ---- resident EP (decode): experts sharded over data×model —
            # weights stay put; the (tiny) decode token batch is gathered.
            n_grp = mesh.shape["data"] * tp
            n_local = cfg.n_routed // n_grp
            tok_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

            def res_body(wig, wiu, wog, xs, tpp, tii):
                xg = jax.lax.all_gather(xs, tok_axes, axis=0, tiled=True)
                tpg = jax.lax.all_gather(tpp, tok_axes, axis=0, tiled=True)
                tig = jax.lax.all_gather(tii, tok_axes, axis=0, tiled=True)
                gidx = (jax.lax.axis_index("data") * tp
                        + jax.lax.axis_index(ep_axis))
                cap = _capacity(xg.shape[0], cfg.top_k, cfg.n_routed,
                                cfg.capacity_factor)
                y = _local_moe({"wi_gate": wig, "wi_up": wiu, "wo": wog},
                               xg, tpg, tig, cfg, gidx * n_local, n_local,
                               cap)
                y = jax.lax.psum(y, ("data", ep_axis))
                # take back this shard's token slice
                T_loc = xs.shape[0]
                start = jax.lax.axis_index("data") * T_loc
                if "pod" in mesh.shape:
                    start = start + (jax.lax.axis_index("pod")
                                     * mesh.shape["data"] * T_loc)
                return jax.lax.dynamic_slice_in_dim(y, start, T_loc, 0)

            out2d = shard_map(
                res_body, mesh=mesh,
                in_specs=(P(ep_pair), P(ep_pair), P(ep_pair),
                          P(dp), P(dp), P(dp)),
                out_specs=P(dp),
                check_rep=False,
            )(p["wi_gate"], p["wi_up"], p["wo"], x2d, top_p, top_i)
        elif cfg.n_routed % tp == 0:
            # ---- EP: experts sharded over "model".
            n_local = cfg.n_routed // tp

            def ep_body(wig, wiu, wog, xs, tpp, tii):
                idx = jax.lax.axis_index(ep_axis)
                cap = _capacity(xs.shape[0], cfg.top_k, cfg.n_routed,
                                cfg.capacity_factor)
                y = _local_moe({"wi_gate": wig, "wi_up": wiu, "wo": wog},
                               xs, tpp, tii, cfg, idx * n_local, n_local, cap)
                return jax.lax.psum(y, ep_axis)

            out2d = shard_map(
                ep_body, mesh=mesh,
                in_specs=(P(ep_axis), P(ep_axis), P(ep_axis),
                          P(dp), P(dp), P(dp)),
                out_specs=P(dp),
                check_rep=False,
            )(p["wi_gate"], p["wi_up"], p["wo"], x2d, top_p, top_i)
        else:
            # ---- expert-TP: every shard, all experts, 1/tp of hidden dim.
            def tpx_body(wig, wiu, wog, xs, tpp, tii):
                cap = _capacity(xs.shape[0], cfg.top_k, cfg.n_routed,
                                cfg.capacity_factor)
                y = _local_moe({"wi_gate": wig, "wi_up": wiu, "wo": wog},
                               xs, tpp, tii, cfg, 0, cfg.n_routed, cap)
                return jax.lax.psum(y, ep_axis)

            out2d = shard_map(
                tpx_body, mesh=mesh,
                in_specs=(P(None, None, ep_axis), P(None, None, ep_axis),
                          P(None, ep_axis, None), P(dp), P(dp), P(dp)),
                out_specs=P(dp),
                check_rep=False,
            )(p["wi_gate"], p["wi_up"], p["wo"], x2d, top_p, top_i)

    out = out2d.reshape(B, S, D).astype(x.dtype)
    if "shared" in p:
        out = out + L.mlp(p["shared"], x, cfg.act)
    return out, aux
