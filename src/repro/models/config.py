"""ModelConfig — one declarative config covering every assigned family.

A model is a stack of *segments*; each segment is a repeated **pattern
unit** of blocks (so hybrids like RecurrentGemma's (rglru, rglru, local)
and DeepSeek's (3 dense then 58 MoE layers) scan cleanly over homogeneous
stacks).  Block mixers: attn | local_attn | mla | ssm | rglru.
FFN kinds: dense | moe | none.

The serving-facing runtime switches (``attn_impl``, ``fused_decode``,
``spec_verify``) select the BitStopper score path and its paged decode /
speculative-verify kernels; scheduler-level policy (pool sizing, prefix
sharing, oversubscription/preemption) lives in ``ServeConfig``
(``repro.serving.engine``), not here — see ``docs/architecture.md`` and
``docs/serving.md`` for the split.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.besf import BitStopperConfig


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str                    # attn | local_attn | mla | ssm | rglru
    ffn: str = "dense"            # dense | moe | none


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    vocab: int
    segments: tuple[tuple[tuple[BlockSpec, ...], int], ...]
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int | None = None
    act: str = "swiglu"
    norm: str = "rms"             # rms | ln
    tie_embeddings: bool = True
    # MLA (deepseek)
    q_rank: int = 0
    kv_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_routed: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_expert: int = 0
    d_shared: int = 0
    moe_capacity_factor: float = 1.25
    moe_resident: bool = False     # decode: fully-sharded resident experts
    attn_chunk: int = 512          # chunked-attention tile size (xla path)
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # RG-LRU
    lru_width: int = 0
    # extras
    mtp: bool = False             # multi-token prediction head (deepseek)
    frontend: str | None = None   # None | audio | vision
    # runtime
    attn_impl: str = "xla"
    bitstopper: BitStopperConfig = BitStopperConfig()
    fused_decode: bool = False    # paged serving: Pallas paged-decode kernel
    spec_verify: bool = False     # speculative serving: route multi-query
                                  # forwards through the paged BESF verify
                                  # (draft-block scoring), not block prefill
    dtype: str = "float32"        # activation dtype
    param_dtype: str = "float32"
    remat: str = "none"           # none | full | dots
    scan_layers: bool = True
    sub_quadratic: bool = False   # True iff long_500k decode is runnable

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def n_layers(self) -> int:
        return sum(len(unit) * reps for unit, reps in self.segments)

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def parameter_dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    # ------ derived per-module configs ------

    def attn_config(self, local: bool = False):
        from repro.models.attention import AttnConfig
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            qkv_bias=self.qkv_bias, rope_theta=self.rope_theta,
            window=self.window if local else None,
            impl=self.attn_impl, bitstopper=self.bitstopper,
            chunk_q=self.attn_chunk, chunk_k=self.attn_chunk,
            fused_decode=self.fused_decode, spec_verify=self.spec_verify,
        )

    def mla_config(self):
        from repro.models.mla import MLAConfig
        return MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            q_rank=self.q_rank, kv_rank=self.kv_rank,
            qk_nope_dim=self.qk_nope_dim, qk_rope_dim=self.qk_rope_dim,
            v_head_dim=self.v_head_dim, rope_theta=self.rope_theta,
            impl=self.attn_impl, bitstopper=self.bitstopper,
        )

    def moe_config(self):
        from repro.models.moe import MoEConfig
        return MoEConfig(
            d_model=self.d_model, n_routed=self.n_routed, top_k=self.top_k,
            d_expert=self.d_expert, n_shared=self.n_shared,
            d_shared=self.d_shared, act=self.act,
            capacity_factor=self.moe_capacity_factor,
            resident=self.moe_resident,
        )

    def ssm_config(self):
        from repro.models.ssm import SSMConfig
        return SSMConfig(
            d_model=self.d_model, d_state=self.ssm_state,
            d_conv=self.ssm_conv, expand=self.ssm_expand,
            head_dim=self.ssm_head_dim,
        )

    def rglru_config(self):
        from repro.models.rglru import RGLRUConfig
        return RGLRUConfig(
            d_model=self.d_model, width=self.lru_width or self.d_model,
            n_heads=self.n_heads,
        )


def uniform_segments(n_layers: int, mixer: str = "attn",
                     ffn: str = "dense") -> tuple:
    return (((BlockSpec(mixer, ffn),), n_layers),)
