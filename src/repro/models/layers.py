"""Shared building blocks: norms, RoPE, MLPs, embeddings, linear layers.

Params are plain nested dicts of jax.Arrays.  Sharding is attached later by
path-pattern rules (repro/sharding/rules.py), so layers stay mesh-agnostic.
All matmuls run in the array dtype with f32 accumulation via
``preferred_element_type``; norms/softmax always compute in f32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    stddev = scale / max(1.0, math.sqrt(shape[0] if shape else 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * stddev).astype(dtype)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out, bias: bool = False, dtype=jnp.float32):
    """d_out may be an int or a tuple (fused multi-output heads)."""
    out_shape = (d_out,) if isinstance(d_out, int) else tuple(d_out)
    w = truncated_normal_init(key, (d_in, *out_shape), 1.0, dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros(out_shape, dtype)
    return p


def linear(p, x):
    ndim_out = p["w"].ndim - 1
    y = jax.lax.dot_general(
        x, p["w"], (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if "b" in p:
        y = y + p["b"]
    return y.astype(x.dtype) if ndim_out else y.astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": truncated_normal_init(key, (vocab, d), math.sqrt(vocab), dtype)}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed(p, x):
    """Tied readout: logits = x @ table^T (f32)."""
    return jax.lax.dot_general(
        x, p["table"], (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm(p, x):
    return layer_norm(p, x) if "bias" in p else rms_norm(p, x)


def init_norm(d: int, kind: str = "rms", dtype=jnp.float32):
    return init_layernorm(d, dtype) if kind == "ln" else init_rmsnorm(d, dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding.  x [..., S, H, Dh] (Dh even), positions [..., S]."""
    dh = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, dh, 2, dtype=jnp.float32) / dh)   # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs          # [..., S, Dh/2]
    # broadcast over the heads axis
    angles = angles[..., None, :]                                      # [..., S, 1, Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, kind: str = "swiglu", bias: bool = False,
             dtype=jnp.float32, d_out: int | None = None):
    k1, k2, k3 = jax.random.split(key, 3)
    d_out = d_out or d
    if kind in ("swiglu", "geglu"):
        return {
            "wi_gate": init_linear(k1, d, d_ff, bias, dtype),
            "wi_up": init_linear(k2, d, d_ff, bias, dtype),
            "wo": init_linear(k3, d_ff, d_out, bias, dtype),
        }
    return {  # plain gelu MLP
        "wi": init_linear(k1, d, d_ff, bias, dtype),
        "wo": init_linear(k2, d_ff, d_out, bias, dtype),
    }


def mlp(p, x, kind: str = "swiglu"):
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(linear(p["wi_gate"], x)) * linear(p["wi_up"], x)
        return linear(p["wo"], h)
    return linear(p["wo"], jax.nn.gelu(linear(p["wi"], x)))
