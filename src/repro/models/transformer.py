"""The transformer stack: embedding → scanned block segments → head.

* **scan-over-layers** per segment (stacked params) keeps HLO size O(1) in
  depth — essential for compiling the 61-layer 671B dry-run — and lets the
  XLA latency-hiding scheduler pipeline per-layer collectives.
* **remat** policies: "none" | "dots" (save matmul outputs) | "full".
* Decode threads a per-layer cache pytree through the same scan.
* Optional **MTP** head (DeepSeek-style multi-token prediction): one extra
  block over [h_t ; embed(next_token)] predicting token t+2.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import attention, init_attention, init_cache
from repro.models.config import BlockSpec, ModelConfig
from repro.models.mla import init_mla, init_mla_cache, mla_attention
from repro.models.moe import init_moe, moe_ffn
from repro.models.rglru import init_rglru, init_rglru_cache, rglru_forward
from repro.models.ssm import init_ssm, init_ssm_cache, ssm_forward
from repro.sharding.api import constrain, current_rules


# ---------------------------------------------------------------------------
# Block init / forward
# ---------------------------------------------------------------------------


def init_block(key, spec: BlockSpec, cfg: ModelConfig):
    km, kf = jax.random.split(key)
    dt = cfg.parameter_dtype
    p: dict[str, Any] = {"norm1": L.init_norm(cfg.d_model, cfg.norm, dt)}
    if spec.mixer in ("attn", "local_attn"):
        p["attn"] = init_attention(km, cfg.attn_config(spec.mixer == "local_attn"), dt)
    elif spec.mixer == "mla":
        p["mla"] = init_mla(km, cfg.mla_config(), dt)
    elif spec.mixer == "ssm":
        p["ssm"] = init_ssm(km, cfg.ssm_config(), dt)
    elif spec.mixer == "rglru":
        p["rglru"] = init_rglru(km, cfg.rglru_config(), dt)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm2"] = L.init_norm(cfg.d_model, cfg.norm, dt)
        if spec.ffn == "moe":
            p["moe"] = init_moe(kf, cfg.moe_config(), dt)
        else:
            p["ffn"] = L.init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.act, False, dt)
    return p


def block_forward(p, x, positions, spec: BlockSpec, cfg: ModelConfig,
                  cache=None):
    """Returns (x, new_cache, aux_loss)."""
    h = L.norm(p["norm1"], x)
    if spec.mixer in ("attn", "local_attn"):
        out, new_cache = attention(
            p["attn"], h, positions, cfg.attn_config(spec.mixer == "local_attn"),
            cache)
    elif spec.mixer == "mla":
        out, new_cache = mla_attention(p["mla"], h, positions, cfg.mla_config(),
                                       cache)
    elif spec.mixer == "ssm":
        out, new_cache = ssm_forward(p["ssm"], h, cfg.ssm_config(), cache)
    elif spec.mixer == "rglru":
        out, new_cache = rglru_forward(p["rglru"], h, cfg.rglru_config(), cache)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h2 = L.norm(p["norm2"], x)
        if spec.ffn == "moe":
            rules = current_rules()
            mesh = rules.mesh if rules is not None else None
            y, aux = moe_ffn(p["moe"], h2, cfg.moe_config(), mesh=mesh)
        else:
            y = L.mlp(p["ffn"], h2, cfg.act)
        x = x + y
    # Scan-carry contract: blocks always emit the activation dtype, no
    # matter how param/activation dtypes promoted inside the mixers.
    x = constrain(x.astype(cfg.activation_dtype), "batch", "seq", "embed")
    return x, new_cache, aux


def init_block_cache(spec: BlockSpec, cfg: ModelConfig, batch: int,
                     max_len: int, dtype=jnp.float32, per_slot: bool = False,
                     paged=None):
    if spec.mixer == "attn":
        return init_cache(cfg.attn_config(False), batch, max_len, dtype,
                          per_slot=per_slot, paged=paged)
    if spec.mixer == "local_attn":
        return init_cache(cfg.attn_config(True), batch, max_len, dtype,
                          ring=True, per_slot=per_slot, paged=paged)
    if per_slot or paged is not None:
        raise NotImplementedError(
            f"per-slot/paged serving caches support attn/local_attn mixers "
            f"only, got {spec.mixer!r}")
    if spec.mixer == "mla":
        return init_mla_cache(cfg.mla_config(), batch, max_len, dtype)
    if spec.mixer == "ssm":
        return init_ssm_cache(cfg.ssm_config(), batch, dtype)
    if spec.mixer == "rglru":
        return init_rglru_cache(cfg.rglru_config(), batch, dtype)
    raise ValueError(spec.mixer)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig):
    keys = jax.random.split(key, 3 + len(cfg.segments))
    dt = cfg.parameter_dtype
    params: dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg.vocab, cfg.d_model, dt),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_linear(keys[1], cfg.d_model, cfg.vocab,
                                          False, dt)
    for si, (unit, reps) in enumerate(cfg.segments):
        seg_keys = jax.random.split(keys[2 + si], reps)

        def init_unit(k):
            uks = jax.random.split(k, len(unit))
            return {f"b{i}": init_block(uks[i], unit[i], cfg)
                    for i in range(len(unit))}

        if cfg.scan_layers and reps > 1:
            params[f"seg{si}"] = jax.vmap(init_unit)(seg_keys)
        else:
            params[f"seg{si}"] = [init_unit(k) for k in seg_keys]
    if cfg.mtp:
        km1, km2 = jax.random.split(keys[-1])
        params["mtp_block"] = init_block(km1, cfg.segments[-1][0][-1], cfg)
        params["mtp_proj"] = L.init_linear(km2, 2 * cfg.d_model, cfg.d_model,
                                           False, dt)
        params["mtp_norm"] = L.init_norm(cfg.d_model, cfg.norm, dt)
    return params


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def run_segments(params, x, positions, cfg: ModelConfig, caches=None):
    """caches: None or {segN: stacked cache pytree (or list)}."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict[str, Any] = {}
    for si, (unit, reps) in enumerate(cfg.segments):
        seg_p = params[f"seg{si}"]
        seg_c = caches.get(f"seg{si}") if caches is not None else None

        def unit_fwd(x, p_unit, c_unit):
            aux = jnp.zeros((), jnp.float32)
            ncs = {}
            for i, spec in enumerate(unit):
                c = c_unit[f"b{i}"] if c_unit is not None else None
                x, nc, a = block_forward(p_unit[f"b{i}"], x, positions, spec,
                                         cfg, c)
                aux = aux + a
                if nc is not None:
                    ncs[f"b{i}"] = nc
            return x, (ncs or None), aux

        if cfg.scan_layers and reps > 1:
            body = _remat_wrap(
                lambda x, pc: (lambda r: (r[0], (r[1], r[2])))(
                    unit_fwd(x, pc[0], pc[1])),
                cfg.remat,
            )
            x, (ncs, auxs) = jax.lax.scan(
                body, x, (seg_p, seg_c) if seg_c is not None else (seg_p, None))
            aux_total = aux_total + jnp.sum(auxs)
            if ncs is not None:
                new_caches[f"seg{si}"] = ncs
        else:
            seg_new = []
            for li in range(reps):
                c_unit = seg_c[li] if seg_c is not None else None
                x, ncs, a = unit_fwd(x, seg_p[li], c_unit)
                aux_total = aux_total + a
                seg_new.append(ncs)
            if any(c is not None for c in seg_new):
                new_caches[f"seg{si}"] = seg_new
    return x, (new_caches or None), aux_total


def forward(params, tokens, cfg: ModelConfig, caches=None,
            positions=None, embeds=None):
    """tokens [B, S] int32 (or ``embeds`` [B, S, D] for stubbed frontends).

    Returns (logits [B,S,vocab] f32, new_caches, aux_loss).
    """
    if embeds is None:
        x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    else:
        x = embeds.astype(cfg.activation_dtype)
    x = constrain(x, "batch", "seq", "embed")
    S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    x, new_caches, aux = run_segments(params, x, positions, cfg, caches)
    h = L.norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], h)
    else:
        logits = L.linear(params["unembed"], h).astype(jnp.float32)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, new_caches, aux


def mtp_logits(params, tokens, h, cfg: ModelConfig, positions):
    """DeepSeek-style MTP: predict token t+2 from [h_t ; emb(token_{t+1})]."""
    emb_next = L.embed(params["embed"], jnp.roll(tokens, -1, axis=1))
    cat = jnp.concatenate([L.norm(params["mtp_norm"], h),
                           emb_next.astype(h.dtype)], axis=-1)
    x = L.linear(params["mtp_proj"], cat)
    spec = cfg.segments[-1][0][-1]
    x, _, _ = block_forward(params["mtp_block"], x, positions, spec, cfg)
    return L.unembed(params["embed"], L.norm(params["final_norm"], x))


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.float32, per_slot: bool = False, paged=None):
    """Stacked (scan-compatible) cache pytree for decode.

    ``per_slot=True`` builds the continuous-batching layout: each batch row
    is an independent serving slot with its own write cursor and
    slot-position map (see :func:`repro.models.attention.init_cache`).

    ``paged=PagedLayout(...)`` builds the block-pool layout instead: one
    batch-free K/V pool per layer, addressed through per-slot block tables
    ([batch, max_blocks_per_req] int32) — the serving engine owns block
    allocation and rewrites the ``table``/``length`` leaves between
    forwards.  BitStopper layers additionally carry the incremental
    bit-plane pool (``kq`` + ``k_amax``/``v_amax`` leaves) that the fused
    paged decode kernel consumes; those leaves are maintained by the cache
    write path and pass through the engine's table attachment untouched."""
    caches: dict[str, Any] = {}
    for si, (unit, reps) in enumerate(cfg.segments):
        def unit_cache(_):
            return {f"b{i}": init_block_cache(unit[i], cfg, batch, max_len,
                                              dtype, per_slot=per_slot,
                                              paged=paged)
                    for i in range(len(unit))}
        if cfg.scan_layers and reps > 1:
            caches[f"seg{si}"] = jax.vmap(unit_cache)(jnp.arange(reps))
        else:
            caches[f"seg{si}"] = [unit_cache(None) for _ in range(reps)]
    return caches
