"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block = gate branch (linear→GeLU) ⊙ recurrent branch (linear→conv1d→RG-LRU),
then output linear.  The RG-LRU recurrence

    r_t = sigmoid(W_a x_t)        (recurrence gate, block-diag per head)
    i_t = sigmoid(W_x x_t)        (input gate)
    a_t = exp(-c * softplus(Λ) * r_t),   c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

is a diagonal linear recurrence → computed with ``lax.associative_scan``
(log-depth) for train/prefill and a single fused step for decode (the
``long_500k`` path: O(1) state per token).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.api import constrain

_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    width: int                    # recurrent width (lru_width)
    n_heads: int
    d_conv: int = 4


def init_rglru(key, cfg: RGLRUConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    W, H = cfg.width, cfg.n_heads
    hd = W // H
    return {
        "in_x": L.init_linear(ks[0], cfg.d_model, W, False, dtype),
        "in_gate": L.init_linear(ks[1], cfg.d_model, W, False, dtype),
        "conv_w": L.truncated_normal_init(ks[2], (cfg.d_conv, W), 1.0, dtype),
        "conv_b": jnp.zeros((W,), dtype),
        # block-diagonal head-wise gates
        "rg": {"w": L.truncated_normal_init(ks[3], (H, hd, hd), 1.0, dtype)},
        "ig": {"w": L.truncated_normal_init(ks[4], (H, hd, hd), 1.0, dtype)},
        # Λ init so a^(1/c) ~ U[0.9, 0.999] (paper appendix)
        "a_param": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, W)) )).astype(dtype),
        "out": L.init_linear(ks[5], W, cfg.d_model, False, dtype),
    }


def _headwise(w, x, n_heads):
    """Block-diagonal matmul.  x [...,W] → [...,W] with w [H, hd, hd]."""
    shape = x.shape
    xh = x.reshape(shape[:-1] + (n_heads, shape[-1] // n_heads))
    y = jnp.einsum("...hi,hij->...hj", xh.astype(jnp.float32),
                   w.astype(jnp.float32))
    return y.reshape(shape)


def _causal_conv(x, w, b):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i: i + x.shape[1]] * w[i] for i in range(K)) + b


def _gates(p, xr, cfg: RGLRUConfig):
    r = jax.nn.sigmoid(_headwise(p["rg"]["w"], xr, cfg.n_heads))
    i = jax.nn.sigmoid(_headwise(p["ig"]["w"], xr, cfg.n_heads))
    log_a = -_C * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xr.astype(jnp.float32))
    return a, gated_in


def rglru_forward(p, x, cfg: RGLRUConfig, cache: dict[str, Any] | None = None):
    """x [B,S,D] → (out, new_cache)."""
    B, S, D = x.shape
    gate = jax.nn.gelu(L.linear(p["in_gate"], x).astype(jnp.float32))
    xr = L.linear(p["in_x"], x)

    if cache is None:
        xr = _causal_conv(xr, p["conv_w"], p["conv_b"])
        a, gin = _gates(p, xr, cfg)
        # h_t = a_t h_{t-1} + gin_t  — associative scan over S.
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        _, h = jax.lax.associative_scan(combine, (a, gin), axis=1)
        new_cache = None
    else:
        conv_state = jnp.concatenate(
            [cache["conv"][:, S:], xr.astype(cache["conv"].dtype)], axis=1)
        K = cfg.d_conv
        window = conv_state[:, -K:]
        xr = (jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])[:, None]
        a, gin = _gates(p, xr, cfg)
        h = a * cache["h"][:, None] + gin
        new_cache = {"conv": conv_state, "h": h[:, 0]}

    y = (h * gate).astype(x.dtype)
    out = L.linear(p["out"], y)
    return constrain(out, "batch", None, "embed"), new_cache


def init_rglru_cache(cfg: RGLRUConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv, cfg.width), dtype),
        "h": jnp.zeros((batch, cfg.width), jnp.float32),
    }
