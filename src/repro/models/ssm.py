"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Chunked SSD for train/prefill (sequence split into chunks; quadratic
attention-like compute within a chunk, linear recurrence across chunks) and
an O(1)-per-token stateful step for decode — this is what makes the
``long_500k`` shape runnable for this family.

Layout follows mamba2 reference: in_proj → [z, x, B, C, dt]; causal depthwise
conv over (x,B,C); SSD; gated RMSNorm; out_proj.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.api import constrain


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def init_ssm(key, cfg: SSMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state + cfg.n_heads
    return {
        "in_proj": L.init_linear(ks[0], cfg.d_model, d_in_proj, False, dtype),
        "conv_w": L.truncated_normal_init(ks[1], (cfg.d_conv, cfg.conv_dim), 1.0, dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads)).astype(dtype),
        "D": jnp.ones((cfg.n_heads,), dtype),
        "dt_bias": jnp.zeros((cfg.n_heads,), dtype),
        "norm": L.init_rmsnorm(cfg.d_inner, dtype),
        "out_proj": L.init_linear(ks[2], cfg.d_inner, cfg.d_model, False, dtype),
    }


def _split_proj(zxbcdt, cfg: SSMConfig):
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d.  xBC [B,S,C], w [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xBC.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum_decay(log_a):
    """log_a [..., Q] → L [..., Q, Q]: exp(cumsum_i - cumsum_j) lower-tri.

    The upper triangle has *positive* exponents (would overflow to inf and
    poison gradients through the mask), so it is masked to -inf BEFORE exp.
    """
    Q = log_a.shape[-1]
    cum = jnp.cumsum(log_a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.exp(jnp.where(tri, diff, -jnp.inf))


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD over a full sequence — one ``lax.scan`` step per chunk.

    x [b,S,h,p], dt [b,S,h] (post-softplus), A [h] (negative),
    B,C [b,S,g,n].  Returns y [b,S,h,p] and final state [b,h,n,p].

    Scanning chunk-by-chunk keeps peak memory at ONE chunk's decay matrix
    ([b,h,Q,Q] ≈ 100 MB at b=16,h=24,Q=256) instead of all n_chunks at once
    (which was 10s of GB per layer at train_4k scale).
    """
    b, S, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    log_a = dt * A[None, None, :]                            # [b,S,h]

    # [nc, b, Q, ...] scan inputs, kept in the activation dtype (the f32
    # upcasts happen inside the checkpointed step — halves scan residuals).
    xc = x.reshape(b, nc, Q, h, p).swapaxes(0, 1)
    dtc = dt.reshape(b, nc, Q, h).swapaxes(0, 1)
    lac = log_a.reshape(b, nc, Q, h).swapaxes(0, 1)
    Bc = B.reshape(b, nc, Q, g, n).swapaxes(0, 1)
    Cc = C.reshape(b, nc, Q, g, n).swapaxes(0, 1)

    @jax.checkpoint  # recompute the O(Q^2) decay/score matrices in backward
    def chunk_step(state, inp):
        xq, dtq, la, Bq, Cq = inp       # [b,Q,h,p], [b,Q,h]×2, [b,Q,g,n]×2
        xd = xq.astype(jnp.float32) * dtq[..., None]
        la = la.astype(jnp.float32)
        Bq = Bq.astype(jnp.float32)
        Cq = Cq.astype(jnp.float32)
        cum = jnp.cumsum(la, axis=1)                          # [b,Q,h]
        # Intra-chunk (attention-like): scores_ij = (C_i . B_j) * L_ij.
        Lm = _segsum_decay(la.transpose(0, 2, 1))             # [b,h,Q,Q]
        CB = jnp.einsum("bqgn,bkgn->bgqk", Cq, Bq)            # [b,g,Q,Q]
        CB = jnp.repeat(CB, hg, axis=1)                       # [b,h,Q,Q]
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", CB * Lm, xd)
        # Chunk summary: S_c = sum_j exp(cum_Q - cum_j) B_j xdt_j^T.
        decay_tail = jnp.exp(cum[:, -1:, :] - cum)            # [b,Q,h]
        Bh = jnp.repeat(Bq, hg, axis=2)                       # [b,Q,h,n]
        S_c = jnp.einsum("bqhn,bqhp,bqh->bhnp", Bh, xd, decay_tail)
        # Inter-chunk: y_t += (C_t . state_prev) * exp(cum_t).
        Ch = jnp.repeat(Cq, hg, axis=2)
        y_inter = jnp.einsum("bqhn,bhnp,bqh->bqhp", Ch, state, jnp.exp(cum))
        new_state = state * jnp.exp(cum[:, -1])[..., None, None] + S_c
        return new_state, (y_intra + y_inter).astype(xq.dtype)

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final_state, ys = jax.lax.scan(chunk_step, init, (xc, dtc, lac, Bc, Cc))
    y = ys.astype(x.dtype).swapaxes(0, 1).reshape(b, S, h, p)
    return y, final_state


def ssm_forward(p, x, cfg: SSMConfig, cache: dict[str, Any] | None = None):
    """Full mixer.  x [B,S,D] → (out, new_cache)."""
    Bb, S, D = x.shape
    zxbcdt = L.linear(p["in_proj"], x)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cache is None:
        xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
        new_cache = None
    else:
        # Decode: roll the conv window, single-step conv + SSM update.
        conv_state = cache["conv"]                             # [B,K,C]
        conv_state = jnp.concatenate(
            [conv_state[:, S:], xBC.astype(conv_state.dtype)], axis=1)
        K = cfg.d_conv
        w, bconv = p["conv_w"], p["conv_b"]
        # For S==1 the last K entries of the rolled buffer are the window.
        window = conv_state[:, -K:]
        out = jnp.einsum("bkc,kc->bc", window, w)
        xBC = jax.nn.silu(out + bconv)[:, None, :]

    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    xs = xBC[..., :di].reshape(Bb, -1, cfg.n_heads, cfg.head_dim)
    Bmat = xBC[..., di: di + gn].reshape(Bb, -1, cfg.n_groups, cfg.d_state)
    Cmat = xBC[..., di + gn:].reshape(Bb, -1, cfg.n_groups, cfg.d_state)

    if cache is None:
        y, final_state = ssd_chunked(xs, dt, A, Bmat, Cmat, cfg.chunk)
    else:
        # Single-token recurrent update (O(1) per token): the long_500k path.
        state = cache["ssm"]                                   # [B,h,n,p]
        hg = cfg.n_heads // cfg.n_groups
        a = jnp.exp(dt[:, 0] * A[None, :])                     # [B,h]
        Bh = jnp.repeat(Bmat[:, 0], hg, axis=1)                # [B,h,n]
        Ch = jnp.repeat(Cmat[:, 0], hg, axis=1)
        xdt = xs[:, 0].astype(jnp.float32) * dt[:, 0][..., None]
        state = state * a[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bh.astype(jnp.float32), xdt)
        y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
        y = y[:, None].astype(x.dtype)                         # [B,1,h,p]
        final_state = state
        new_cache = {"conv": conv_state, "ssm": state}

    y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bb, -1, cfg.d_inner)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    out = L.linear(p["out_proj"], y)
    out = constrain(out, "batch", None, "embed")
    if cache is None:
        return out, None
    return out, new_cache


def init_ssm_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv, cfg.conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
                         jnp.float32),
    }
