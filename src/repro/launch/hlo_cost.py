"""Trip-count-aware cost analysis over compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts every ``while`` body
ONCE — under scan-over-layers, grad-accumulation scans, chunked-attention
and chunked-loss scans that undercounts FLOPs/bytes by 1-2 orders of
magnitude (verified empirically: FLOPs flat in layer count under scan,
2× under unroll).  This module walks the HLO call graph instead:

* ``while``          → body cost × trip count (trip count recovered from
                       the loop-condition computation's s32 constant)
* ``fusion``         → operand+output bytes (the fused kernel's true HBM
                       traffic) + inner dot FLOPs
* ``dot``            → 2 × |out| × contracting-dim product
* collectives        → per-opcode bytes, **multiplied through enclosing
                       loops** (the paper-relevant fix: per-layer
                       all-reduces inside a scan are L× the naive parse)
* ``call``/``conditional`` → recurse (max over branches for conditional)

Costs are per-device (the SPMD module is per-device).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*|pred|token)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.*)$")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONSTANT = re.compile(r"^s32\[\]\s+constant\((\d+)\)")
_OPERANDS = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")


def _shape_info(text: str):
    """All array shapes in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_shapes: list            # [(dtype, dims), ...]
    operands: list              # instruction names
    rhs: str                    # full right-hand side text


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})


def parse_module(text: str):
    """→ (computations: name → [Instr], entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        # Split "<type> <opcode>(<operands>), attrs".  The type is either
        # "dtype[dims]{layout}" (no spaces) or a parenthesized tuple.
        if rhs.startswith("("):
            depth = 0
            tend = -1
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        tend = i
                        break
            if tend < 0:
                continue
            type_str, rest = rhs[: tend + 1], rhs[tend + 1:]
        else:
            sp = rhs.find(" ")
            if sp < 0:
                continue
            type_str, rest = rhs[:sp], rhs[sp:]
        paren = rest.find("(")
        if paren < 0:
            continue
        opcode = rest[:paren].strip()
        # operand list: names inside the first balanced paren group of rest
        depth = 0
        end = paren
        for i in range(paren, len(rest)):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERANDS.findall(rest[paren:end + 1])
        out_shapes = _shape_info(type_str)
        comps[cur].append(Instr(name, opcode, out_shapes, operands, rhs))
    return comps, entry


def _trip_count(cond_instrs) -> int:
    """Largest s32 constant in the loop condition ≈ trip count."""
    best = 1
    for ins in cond_instrs:
        m = _CONSTANT.search(ins.rhs)
        if m:
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> Cost:
    comps, entry = parse_module(text)
    symtab = {name: {i.name: i for i in instrs}
              for name, instrs in comps.items()}
    memo: dict[str, Cost] = {}

    def dot_flops(ins: Instr, table) -> float:
        out_elems = 1
        for _, shape in ins.out_shapes:
            for d in shape:
                out_elems *= d
        m = _LHS_CDIMS.search(ins.rhs)
        cdim = 1
        if m and ins.operands:
            lhs = table.get(ins.operands[0])
            if lhs is not None and lhs.out_shapes:
                _, lshape = lhs.out_shapes[0]
                for di in (int(x) for x in m.group(1).split(",") if x):
                    if di < len(lshape):
                        cdim *= lshape[di]
        return 2.0 * out_elems * cdim

    def io_bytes(ins: Instr, table) -> float:
        b = _nbytes(ins.out_shapes)
        for op in ins.operands:
            src = table.get(op)
            if src is not None:
                b += _nbytes(src.out_shapes)
        return b

    def fusion_io_bytes(ins: Instr, table, called: str) -> float:
        """Operand/output bytes for a fusion with slice-aware accounting:

        * operands consumed only through dynamic-slice/gather are charged
          the SLICE size (scan-over-layers weight indexing: charging the
          full [L, ...] stack per iteration overcounts L×);
        * an operand that is the *updatee* of a dynamic-update-slice is
          charged the UPDATE size, and if the fusion's root is that DUS the
          output is too (KV-cache writes alias in place on hardware —
          charging the full 32k-token cache per decoded token overcounts
          ~1000×)."""
        sub = comps.get(called, [])
        sub_tab = symtab.get(called, {})
        param_names = {}
        for si in sub:
            if si.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", si.rhs)
                if m:
                    param_names[si.name] = int(m.group(1))
        sliced: dict[int, float] = {}
        out_bytes = _nbytes(ins.out_shapes)
        root = sub[-1] if sub else None
        for si in sub:
            for oi, op in enumerate(si.operands):
                if op not in param_names:
                    continue
                idx = param_names[op]
                if si.opcode in ("dynamic-slice", "gather"):
                    sz = _nbytes(si.out_shapes)
                elif si.opcode == "dynamic-update-slice" and oi == 0:
                    # updatee: traffic = the written update region
                    upd = sub_tab.get(si.operands[1]) if len(si.operands) > 1 \
                        else None
                    sz = _nbytes(upd.out_shapes) if upd else 0.0
                    if si is root:
                        out_bytes = min(out_bytes, sz)
                else:
                    sliced[idx] = None
                    continue
                if sliced.get(idx, 0.0) is not None:
                    sliced[idx] = sliced.get(idx, 0.0) + sz
        b = out_bytes
        for i, op in enumerate(ins.operands):
            src = table.get(op)
            if src is None:
                continue
            full = _nbytes(src.out_shapes)
            s = sliced.get(i, None)
            b += full if s is None else min(s, full)
        return b

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # break recursion cycles defensively
        total = Cost()
        table = symtab.get(name, {})
        for ins in comps.get(name, []):
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_OPS:
                c = Cost(0.0, 0.0, {base: float(_nbytes(ins.out_shapes))})
                total += c
            elif op == "dot" or op == "convolution":
                total += Cost(dot_flops(ins, table), io_bytes(ins, table))
            elif op == "fusion":
                m = _CALLS.search(ins.rhs)
                if m:
                    sub = comp_cost(m.group(1))
                    total += Cost(sub.flops,
                                  fusion_io_bytes(ins, table, m.group(1)),
                                  dict(sub.coll))
                else:
                    total += Cost(0.0, io_bytes(ins, table))
            elif op == "while":
                m = _COND_BODY.search(ins.rhs)
                if m:
                    cond, body = m.group(1), m.group(2)
                    trips = _trip_count(comps.get(cond, []))
                    total += comp_cost(body).scaled(trips)
            elif op == "conditional":
                m = _BRANCHES.search(ins.rhs)
                if m:
                    branches = _OPERANDS.findall(m.group(1))
                    costs = [comp_cost(b) for b in branches]
                    if costs:
                        total += max(costs, key=lambda c: c.flops + c.bytes)
            elif op in ("call", "custom-call", "reduce", "sort", "scatter",
                        "map"):
                m = _TO_APPLY.search(ins.rhs) or _CALLS.search(ins.rhs)
                if m:
                    total += comp_cost(m.group(1))
                total += Cost(0.0, io_bytes(ins, table))
            elif op == "dynamic-update-slice":
                # in-place update: traffic = the written region (read+write)
                upd = (table.get(ins.operands[1])
                       if len(ins.operands) > 1 else None)
                sz = _nbytes(upd.out_shapes) if upd else 0.0
                total += Cost(0.0, 2.0 * sz)
            elif op in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "after-all", "partition-id",
                        "replica-id"):
                continue
            else:
                # unfused top-level op: count its output traffic
                total += Cost(0.0, float(_nbytes(ins.out_shapes)))
        memo[name] = total
        return total

    if entry is None:
        return Cost()
    return comp_cost(entry)


def collective_bytes_dict(cost: Cost) -> dict[str, float]:
    out = {f"{op}_bytes": cost.coll.get(op, 0.0) for op in COLLECTIVE_OPS}
    return out
