"""Async serving front door: streamed tokens over a line-JSON socket.

``python -m repro.launch.serve_async --arch stablelm-1.6b --demo 4``

Runs an :class:`~repro.serving.frontdoor.AsyncFrontDoor` over a paged
engine (or a prefill/decode ``DisaggController`` with ``--disagg``) and
serves it two ways:

* ``--demo N`` — no sockets: submit an N-request mixed-length trace
  through the door and print each request's tokens as they stream.  The
  quickest way to see admission fairness, per-token streaming, and the
  SLA mapper working end to end.
* default — an asyncio TCP server speaking newline-delimited JSON.
  Each request line ``{"prompt": [ints], "max_new_tokens": N,
  "slo": "standard", "deadline_s": 0.5}`` is answered with one
  ``{"rid": r}`` ack, a ``{"rid": r, "token": t}`` line per generated
  token as the engine commits it, and a final ``{"rid": r, "done":
  true, "reason": ...}``.  ``examples/stream_client.py`` is the
  matching client.

Wall-clock deadlines (``deadline_s``) are mapped onto the engine's
tick-indexed QoS by the :class:`~repro.serving.frontdoor.SlaMapper`,
fed with tick timings from an injected ``SystemClock`` — the serving
tree itself stays wall-clock-free (lint rule ``repo-tick-wallclock``).

Graceful shutdown: SIGINT/SIGTERM stop admissions and, with
``--snapshot-dir``, persist the engine through the checkpoint store
(``shutdown("snapshot")``); re-launching with the same directory
restores and every interrupted stream replays losslessly from token
zero.  Without a snapshot dir the door drains: everything already
accepted is served to completion first.
"""

from __future__ import annotations

import argparse
import asyncio
import json
import signal

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.runtime import SystemClock
from repro.serving import PagedEngine, ServeConfig
from repro.serving.frontdoor import AsyncFrontDoor, DisaggController, \
    SlaMapper


def build_door(args):
    cfg = reduced_config(args.arch).replace(
        attn_impl=args.impl,
        bitstopper=BitStopperConfig(alpha=args.alpha),
    )
    params = T.init_model(jax.random.PRNGKey(0), cfg)

    def scfg(slots):
        return ServeConfig(
            max_len=args.max_prompt + args.new_tokens + 8,
            max_slots=slots, prefill_bucket=8,
            temperature=args.temperature,
            fused_decode={"auto": None, "on": True, "off": False}[
                args.fused_decode])

    if args.disagg:
        if args.snapshot_dir is not None:
            raise SystemExit("--snapshot-dir needs a colocated engine "
                             "(--disagg drains instead)")
        backend = DisaggController(
            PagedEngine(cfg, params, scfg(max(1, args.slots // 2))),
            PagedEngine(cfg, params, scfg(args.slots)))
    else:
        backend = PagedEngine(cfg, params, scfg(args.slots))
    clock = SystemClock()
    door = AsyncFrontDoor(backend, clock=clock,
                          sla=SlaMapper(granularity=clock.granularity),
                          snapshot_dir=args.snapshot_dir, seed=args.seed)
    return cfg, door


async def serve_socket(args, door):
    async def handle(reader, writer):
        async def pump(rid):
            async for tok in door.stream(rid):
                writer.write(json.dumps(
                    {"rid": rid, "token": tok}).encode() + b"\n")
                await writer.drain()
            req = door.result(rid)
            reason = (req.shed_reason if req.shed_reason is not None
                      else "deadline" if req.deadline_hit else "done")
            writer.write(json.dumps(
                {"rid": rid, "done": True, "reason": reason,
                 "tokens": list(req.generated)}).encode() + b"\n")
            await writer.drain()

        pumps = []
        try:
            async for line in reader:
                msg = json.loads(line)
                try:
                    rid = door.submit(
                        np.asarray(msg["prompt"], np.int32),
                        max_new_tokens=int(msg.get("max_new_tokens", 32)),
                        slo=msg.get("slo", "standard"),
                        deadline_s=msg.get("deadline_s"))
                except (RuntimeError, ValueError) as e:
                    writer.write(json.dumps(
                        {"error": str(e)}).encode() + b"\n")
                    await writer.drain()
                    continue
                writer.write(json.dumps({"rid": rid}).encode() + b"\n")
                await writer.drain()
                pumps.append(asyncio.create_task(pump(rid)))
        finally:
            if pumps:
                await asyncio.gather(*pumps, return_exceptions=True)
            writer.close()

    server = await asyncio.start_server(handle, args.host, args.port)
    runner = asyncio.create_task(door.run())
    loop = asyncio.get_running_loop()
    mode = "snapshot" if args.snapshot_dir is not None else "drain"
    for sig in (signal.SIGINT, signal.SIGTERM):
        loop.add_signal_handler(sig, door.shutdown, mode)
    addr = server.sockets[0].getsockname()
    print(f"serving on {addr[0]}:{addr[1]} "
          f"(shutdown mode on signal: {mode})", flush=True)
    await runner                      # exits on drain/snapshot shutdown
    server.close()
    await server.wait_closed()
    print(f"stopped after {door.ticks_run} ticks; "
          f"admitted {len(door.admission_log)} request(s)"
          + (f"; {len(door.interrupted)} stream(s) snapshotted for resume"
             if door.interrupted else ""))


async def run_demo(args, door, cfg):
    restored = door.start()
    rng = np.random.default_rng(args.seed)
    rids = []
    if restored:
        print(f"restored snapshot; resuming "
              f"{len(door.backend.requests)} in-flight request(s)")
        rids = sorted(door.backend.requests)
    else:
        slos = ("strict", "standard", "besteffort")
        for i in range(args.demo):
            prompt = rng.integers(
                0, cfg.vocab,
                int(rng.integers(args.min_prompt, args.max_prompt + 1)),
                dtype=np.int32)
            rids.append(door.submit(prompt, args.new_tokens,
                                    slo=slos[i % len(slos)],
                                    deadline_s=args.deadline_s))
    runner = asyncio.create_task(door.run())

    async def show(rid):
        toks = []
        async for tok in door.stream(rid):
            toks.append(tok)
        req = door.result(rid)
        status = (req.shed_reason or
                  ("deadline" if req.deadline_hit else "done"))
        print(f"  rid {rid} [{req.slo:>10}] {status}: {toks}")

    streams = asyncio.gather(*(show(r) for r in rids))
    door.shutdown("drain")
    await streams
    await runner
    print(f"admission order: {door.admission_log} "
          f"({door.ticks_run} engine ticks)")
    if door.sla.tick_estimate:
        print(f"measured tick: {door.sla.tick_estimate * 1e3:.1f} ms")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--impl", default="bitstopper_xla",
                    choices=["xla", "bitstopper_xla"])
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fused-decode", default="auto",
                    choices=["auto", "on", "off"])
    ap.add_argument("--disagg", action="store_true",
                    help="two-instance mode: a prefill engine hands "
                         "detached prefixes to the decode engine through "
                         "the transfer queue (docs/serving.md)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="persist engine state on signalled shutdown; "
                         "relaunching restores and interrupted streams "
                         "replay losslessly")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="demo mode: per-request wall-clock deadline, "
                         "mapped to engine ticks by the SLA mapper")
    ap.add_argument("--demo", type=int, default=0, metavar="N",
                    help="self-driving mode: stream an N-request trace "
                         "to stdout instead of opening a socket")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8763)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, door = build_door(args)
    if args.demo:
        asyncio.run(run_demo(args, door, cfg))
    else:
        door.start()
        asyncio.run(serve_socket(args, door))


if __name__ == "__main__":
    main()
