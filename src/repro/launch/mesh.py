"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests keep their single CPU device;
only launch/dryrun.py (which forces 512 host devices before any jax import)
ever builds the full meshes.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; "pod" is the outer
    data axis (hierarchical gradient reduction: intra-pod on "data" over
    ICI, inter-pod on "pod" over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for subprocess sharding tests (8 forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
