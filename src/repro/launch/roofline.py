"""Three-term roofline from the dry-run's compiled artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device (ring-adjusted) / link_bw

All inputs come from the SPMD per-device module (cost_analysis + HLO
collective parsing — see launch/dryrun.py), so no division by chip count
is applied here.  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (constants from the assignment).

Also reports MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) against the
compiled HLO FLOPs — the "useful-compute" ratio that catches remat and
dispatch waste — plus the dominant term and what would move it.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

# ring all-reduce moves ~2 x bytes; others ~1 x
_COLL_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def param_counts(arch: str) -> dict:
    """Total and active (per-token matmul-visible) parameter counts."""
    from repro.configs import get_config
    from repro.models import transformer as T
    import jax

    cfg = get_config(arch)
    params = jax.eval_shape(lambda k: T.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    total = 0
    routed = 0
    for kp, leaf in flat:
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in kp)
        n = int(np.prod(leaf.shape))
        total += n
        if "/moe/wi" in path or "/moe/wo" in path:
            routed += n
    active = total - routed
    if cfg.n_routed:
        active += routed * cfg.top_k // cfg.n_routed
    # embedding table does no per-token matmul except the (tied) LM head —
    # keep it in (the head matmul is real compute).
    return {"total": total, "active": active}


def model_flops(arch: str, shape_kind: str, seq_len: int, global_batch: int,
                devices: int) -> float:
    """6·N_active·D per device (training); 2·N_active·D for fwd-only."""
    pc = param_counts(arch)
    tokens = seq_len * global_batch if shape_kind != "decode" else global_batch
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * pc["active"] * tokens / devices


def roofline_terms(cell: dict) -> dict:
    """cell: one launch/dryrun.py result row.

    Uses the trip-count-aware tc_* numbers (hlo_cost.py); the naive
    cost_analysis values are kept in the JSON for reference only."""
    flops = cell.get("tc_flops", cell["hlo_flops"])
    bytes_ = cell.get("tc_bytes", cell["hlo_bytes"])
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    coll = 0.0
    for op, f in _COLL_FACTOR.items():
        coll += f * cell.get(f"tc_{op}_bytes", cell.get(f"{op}_bytes", 0.0))
    t_coll = coll / LINK_BW

    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful model compute vs the time the dominant
    # term pins the step at.
    from repro.configs import SHAPES
    shape = SHAPES[cell["shape"]]
    mf = model_flops(cell["arch"], shape.kind, shape.seq_len,
                     shape.global_batch, cell["devices"])
    t_ideal = mf / PEAK_FLOPS
    frac = t_ideal / bound if bound > 0 else 0.0
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": frac,
    }


def analyze(results_path: str, out_path: str | None = None):
    with open(results_path) as f:
        cells = json.load(f)
    rows = []
    for cell in cells:
        if not cell.get("ok"):
            rows.append(dict(cell))
            continue
        rows.append({**cell, **roofline_terms(cell)})
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def print_table(rows):
    hdr = (f"{'arch':<20} {'shape':<12} {'comp(s)':>9} {'mem(s)':>9} "
           f"{'coll(s)':>9} {'dom':>6} {'useful':>7} {'roofl%':>7} "
           f"{'GiB/dev':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if not r.get("ok"):
            print(f"{r['arch']:<20} {r['shape']:<12} FAILED: "
                  f"{r.get('error', '?')[:60]}")
            continue
        print(f"{r['arch']:<20} {r['shape']:<12} "
              f"{r['t_compute_s']:>9.2e} {r['t_memory_s']:>9.2e} "
              f"{r['t_collective_s']:>9.2e} {r['dominant'][:6]:>6} "
              f"{r['useful_flops_ratio']:>7.2f} "
              f"{100 * r['roofline_fraction']:>6.1f}% "
              f"{r['peak_bytes'] / 2**30:>8.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="dryrun JSON")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = analyze(args.results, args.out)
    print_table(rows)


if __name__ == "__main__":
    main()
