import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the production mesh (512 placeholder host
devices), constructs ShapeDtypeStruct stand-ins for every input (weights,
optimizer state, KV caches, token batches — nothing is allocated), jits the
step with the sharding rules, and runs ``.lower().compile()``.  Success
proves the distribution config is coherent: every sharding divides, every
collective is supported, and the per-device memory fits.

Outputs per cell (JSON): memory_analysis numbers, cost_analysis FLOPs/bytes
(NB: per-DEVICE under SPMD), and per-opcode collective bytes parsed from
the compiled HLO — the inputs to launch/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.shapes import applicable_shapes
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.sharding.api import use_rules
from repro.sharding.rules import cache_pspecs, make_rules
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
# no device allocation)
# ---------------------------------------------------------------------------


def _specify(tree, pspec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)),
        tree, pspec_tree)


N_PATCHES = 576      # stubbed anyres vision frontend: precomputed embeddings


def _batch_specs(cfg: ModelConfig, shape, rules):
    """Token batch (plus patch embeddings for VLM archs) as specs."""
    B = shape.global_batch
    if cfg.frontend == "vision":
        S_text = shape.seq_len - N_PATCHES
        return {
            "tokens": jax.ShapeDtypeStruct(
                (B, S_text), jnp.int32,
                sharding=rules.sharding(("batch", None), (B, S_text))),
            "patches": jax.ShapeDtypeStruct(
                (B, N_PATCHES, cfg.d_model), jnp.bfloat16,
                sharding=rules.sharding(("batch", None, None),
                                        (B, N_PATCHES, cfg.d_model))),
        }
    return jax.ShapeDtypeStruct(
        (B, shape.seq_len), jnp.int32,
        sharding=rules.sharding(("batch", None), (B, shape.seq_len)))


def train_cell(cfg: ModelConfig, shape, mesh, rules, microbatches=1,
               remat="full", moment_dtype="float32"):
    # Baseline train dry-runs: bf16 activations + full per-layer remat
    # (hillclimbs relax these per cell — see EXPERIMENTS.md §Perf).
    from repro.train.optimizer import AdamWConfig
    cfg = cfg.replace(remat=remat, dtype="bfloat16")
    tcfg = TrainConfig(microbatches=microbatches,
                       optimizer=AdamWConfig(moment_dtype=moment_dtype))
    state_like = jax.eval_shape(
        lambda k: init_train_state(k, cfg, tcfg), jax.random.PRNGKey(0))
    state_specs = _specify(state_like, rules.tree_pspecs(state_like), mesh)
    batch = _batch_specs(cfg, shape, rules)
    step = make_train_step(cfg, tcfg)
    return step, (state_specs, batch)


def prefill_cell(cfg: ModelConfig, shape, mesh, rules):
    cfg = cfg.replace(dtype="bfloat16")
    params_like = jax.eval_shape(
        lambda k: T.init_model(k, cfg), jax.random.PRNGKey(0))
    param_specs = _specify(params_like, rules.tree_pspecs(params_like), mesh)
    batch = _batch_specs(cfg, shape, rules)

    def prefill_step(params, batch):
        """Prefill returns ONLY the last-position logits (a full [B,S,V]
        materialization would be absurd for 129k vocabs)."""
        from repro.models import layers as Lyr
        from repro.sharding.api import constrain
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        x = Lyr.embed(params["embed"], tokens).astype(cfg.activation_dtype)
        if isinstance(batch, dict):
            x = jnp.concatenate(
                [batch["patches"].astype(x.dtype), x], axis=1)
        x = constrain(x, "batch", "seq", "embed")
        h, _, _ = T.run_segments(params, x, jnp.arange(x.shape[1]), cfg)
        hl = Lyr.norm(params["final_norm"], h[:, -1])
        if cfg.tie_embeddings:
            return Lyr.unembed(params["embed"], hl)
        return Lyr.linear(params["unembed"], hl)

    return prefill_step, (param_specs, batch)


def decode_cell(cfg: ModelConfig, shape, mesh, rules):
    """One new token against a KV cache of seq_len (length = seq_len - 1)."""
    cfg = cfg.replace(dtype="bfloat16")
    params_like = jax.eval_shape(
        lambda k: T.init_model(k, cfg), jax.random.PRNGKey(0))
    param_specs = _specify(params_like, rules.tree_pspecs(params_like), mesh)
    cache_dtype = (jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    caches_like = jax.eval_shape(
        lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len,
                              cache_dtype))
    cache_specs = _specify(caches_like, cache_pspecs(rules, caches_like), mesh)
    token = jax.ShapeDtypeStruct(
        (shape.global_batch, 1), jnp.int32,
        sharding=rules.sharding(("batch", None), (shape.global_batch, 1)))
    pos = shape.seq_len - 1

    def serve_step(params, token, caches):
        logits, new_caches, _ = T.forward(
            params, token, cfg, caches=caches,
            positions=jnp.full((1,), pos, jnp.int32))
        return logits[:, -1], new_caches

    return serve_step, (param_specs, token, cache_specs)


def input_specs(arch, shape_name: str, mesh, rules, **kw):
    """Public entry: (step_fn, specs tuple) for one cell.
    ``arch`` may be a name or an already-overridden ModelConfig."""
    cfg = arch if isinstance(arch, ModelConfig) else get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh, rules, **kw)
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, mesh, rules)
    return decode_cell(cfg, shape, mesh, rules)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z]+[0-9]+|pred)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def _first_shape_bytes(text: str) -> int:
    """Bytes of the first shape literal in an HLO line (tuple → sum all)."""
    total = 0
    for m in _SHAPE_RE.finditer(text.split(" ", 1)[0] + " " +
                                text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
        break
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output bytes per collective opcode from per-device HLO.

    Ring-transfer approximations applied by the roofline (not here):
    all-reduce moves ~2× its bytes; others ~1×.
    """
    out: dict[str, float] = {op: 0.0 for op in _COLL_OPS}
    counts: dict[str, int] = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            # match " all-reduce(" or " all-gather(" as the opcode position
            if f" {op}(" in line or f"{op}-start(" in line:
                m = _DEF_RE.match(line)
                if not m:
                    continue
                rhs = m.group(2)
                out[op] += _first_shape_bytes(rhs)
                counts[op] += 1
                break
    res = {f"{op}_bytes": v for op, v in out.items()}
    res.update({f"{op}_count": float(counts[op]) for op in _COLL_OPS})
    return res


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


# Per-arch default microbatch counts for train_4k (65536 tokens/device on
# the single-pod mesh): sized so remat checkpoints (~d_model×2B/token/layer)
# fit the 16 GB v5e budget.  Overridable with --microbatches.
TRAIN_MICROBATCHES = {
    "stablelm-12b": 4, "qwen2.5-14b": 4, "granite-20b": 8,
    "llava-next-34b": 8, "deepseek-v3-671b": 8, "musicgen-medium": 2,
    "qwen2-moe-a2.7b": 2, "recurrentgemma-2b": 2,
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int | None = None,
             extra_rules_kw: dict | None = None,
             cfg_overrides: dict | None = None,
             remat: str = "full",
             moment_dtype: str = "float32"):
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, n_routed=cfg.n_routed,
                       **(extra_rules_kw or {}))
    if microbatches is None:
        microbatches = TRAIN_MICROBATCHES.get(arch, 1)
    kw = {}
    if SHAPES[shape_name].kind == "train":
        kw = {"microbatches": microbatches, "remat": remat,
              "moment_dtype": moment_dtype}
    t0 = time.monotonic()
    with use_rules(rules):
        step, specs = input_specs(cfg, shape_name, mesh, rules, **kw)
        lowered = jax.jit(step).lower(*specs)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()
        coll = parse_collective_bytes(hlo_text)
        # Trip-count-aware accounting (XLA's cost_analysis counts while
        # bodies once — wrong by ~layers× under scan; see hlo_cost.py).
        from repro.launch.hlo_cost import analyze_hlo, collective_bytes_dict
        tc = analyze_hlo(hlo_text)
        coll_tc = {f"tc_{k}": v
                   for k, v in collective_bytes_dict(tc).items()}

    n_dev = mesh.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # per-device numbers (SPMD module)
        "arg_bytes": mem.argument_size_in_bytes,
        "out_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_bytes": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                       + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        "hlo_flops": cost.get("flops", 0.0),
        "hlo_bytes": cost.get("bytes accessed", 0.0),
        # trip-count-aware (authoritative for the roofline)
        "tc_flops": tc.flops,
        "tc_bytes": tc.bytes,
        **coll_tc,
        **coll,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_archs():
            if arch == "paper-opt1.3b":
                continue
            cfg = get_config(arch)
            for s in applicable_shapes(cfg):
                cells.append((arch, s.name))
    else:
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
            try:
                r = run_cell(arch, shape, mp, args.microbatches)
                print(f"[dryrun] OK   {tag}: peak {r['peak_bytes']/2**30:.2f} "
                      f"GiB/dev, {r['hlo_flops']:.3e} FLOP/dev, "
                      f"compile {r['compile_s']:.0f}s")
            except Exception as e:
                r = {"arch": arch, "shape": shape,
                     "mesh": "2x16x16" if mp else "16x16", "ok": False,
                     "error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()[-2000:]}
                print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {e}")
            results.append(r)
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} cells compiled")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
