"""Serving launcher: paged continuous-batching generation with BitStopper
sparse attention over a mixed-length request trace.

``python -m repro.launch.serve --arch stablelm-1.6b --impl bitstopper_xla``

Engines:

* ``--engine paged`` (default) — block-pool KV cache with copy-on-write
  prefix sharing and chunked prefill.  Admission is bounded by pool
  capacity (``--pool-blocks``) rather than a per-slot ``max_len``; block
  granularity is ``--page-size`` tokens and prompts prefill
  ``--prefill-chunk`` tokens per scheduler tick, interleaved with decode.
  With ``--oversubscribe`` admission reserves prompt-sized block budgets
  instead of worst-case ``max_new_tokens`` and mid-decode exhaustion
  preempts a victim (``--preempt-policy``), which later resumes
  losslessly — see ``docs/serving.md`` for the full request lifecycle.
* ``--engine continuous`` — the contiguous per-slot cache (each slot
  reserves ``max_len`` rows); the paged engine is bit-identical to it on
  the dense path, at a fraction of the resident KV memory.
* ``--engine static`` — legacy length-bucketed batcher (the baseline
  ``benchmarks/serve_throughput.py`` measures against).

Robustness knobs (paged engine; docs/robustness.md): ``--deadline`` /
``--shed-watermark`` bound per-request latency and queue growth;
``--snapshot-dir`` + ``--snapshot-every`` persist crash snapshots; a
``--fault-plan`` drives the whole trace through the deterministic chaos
harness (scripted crashes, kernel faults, drafter faults, …) with
kill-and-restore recovery — served tokens are bit-identical to an
undisturbed run.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.serving import (
    ContinuousBatchingEngine,
    FaultPlan,
    PagedEngine,
    Request,
    ServeConfig,
    StaticBucketEngine,
    serve_with_chaos,
)


def make_trace(rng, vocab, n_requests, min_len, max_len, new_tokens,
               shared_prefix=0):
    """Mixed-length request trace; with ``shared_prefix`` > 0 every request
    starts with the same system prompt (the prefix-sharing workload)."""
    prefix = rng.integers(0, vocab, shared_prefix, dtype=np.int32)
    reqs = []
    for _ in range(n_requests):
        tail = rng.integers(0, vocab,
                            int(rng.integers(min_len, max_len + 1)),
                            dtype=np.int32)
        reqs.append(Request(prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=new_tokens))
    return reqs


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--impl", default="bitstopper_xla",
                    choices=["xla", "bitstopper_xla"])
    ap.add_argument("--engine", default="paged",
                    choices=["paged", "continuous", "static"])
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common system prompt of this many "
                         "tokens to every request (prefix-sharing demo)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="paged engine: physical KV blocks in the pool "
                         "(default: full capacity for all slots)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="paged engine: prompt tokens prefetched per "
                         "scheduler tick (multiple of the prefill bucket)")
    ap.add_argument("--oversubscribe", action="store_true",
                    help="paged engine: admit against prompt-sized "
                         "reservations instead of worst-case "
                         "max-new-tokens; mid-decode pool exhaustion "
                         "preempts a victim (freed + requeued; resume is "
                         "lossless, tokens never change)")
    ap.add_argument("--preempt-policy", default="fewest_tokens",
                    choices=["fewest_tokens", "lifo"],
                    help="victim choice under --oversubscribe: least "
                         "generated output (cheapest recompute) or newest "
                         "admission")
    ap.add_argument("--fused-decode", default="auto",
                    choices=["auto", "on", "off"],
                    help="paged BitStopper decode through the fused Pallas "
                         "kernel (on), the pure-JAX gather fallback (off), "
                         "or kernel iff on TPU (auto)")
    ap.add_argument("--speculative", default="off",
                    choices=["off", "ngram", "draft"],
                    help="paged engine: speculative decoding with the "
                         "n-gram prompt-lookup self-drafter (ngram) or a "
                         "draft transformer (draft; self-drafts with the "
                         "target model).  Lossless: served tokens never "
                         "change, only how many verify forwards they take")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens proposed per speculative tick")
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="paged engine: serve over a (data, model) device "
                         "mesh — slots shard over the dp axis, KV heads "
                         "(paged pools + per-head BESF attention) over tp. "
                         "Output is bit-identical to single-device "
                         "(docs/serving.md).  Needs dp*tp visible devices "
                         "(CPU: XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--deadline", type=int, default=None, metavar="TICKS",
                    help="paged engine: default per-request deadline in "
                         "scheduler ticks from submission; expiry "
                         "truncates started requests (emitted tokens stay "
                         "a prefix of the undisturbed stream) and sheds "
                         "never-started ones")
    ap.add_argument("--shed-watermark", type=float, default=None,
                    metavar="FRAC",
                    help="paged engine: shed queued besteffort requests "
                         "while pool saturation exceeds this fraction "
                         "(requires --oversubscribe)")
    ap.add_argument("--besteffort-tail", type=int, default=0, metavar="N",
                    help="mark the last N trace requests slo=besteffort "
                         "(sheddable; preferred preemption victims)")
    ap.add_argument("--swap-host-bytes", type=int, default=0, metavar="B",
                    help="paged engine: host-RAM budget for swap-to-host "
                         "preemption — victims' exclusive blocks copy to "
                         "host and resume by splice instead of chunked-"
                         "prefill recompute (requires --oversubscribe; "
                         "0 = recompute only)")
    ap.add_argument("--prefix-store-dir", default=None,
                    help="paged engine: persistent prefix store — cold "
                         "registered prefix blocks spill here (atomic "
                         "stage-then-promote) and a restarted engine warms "
                         "its prefix cache from it")
    ap.add_argument("--prefix-host-bytes", type=int, default=0, metavar="B",
                    help="paged engine: host-RAM tier between the device "
                         "prefix LRU and the disk store (evictions cascade "
                         "downward; 0 = spill straight to disk)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="persist crash snapshots (engine host state; "
                         "atomic stage-then-promote) under this directory")
    ap.add_argument("--snapshot-every", type=int, default=0, metavar="N",
                    help="snapshot cadence in engine ticks (with "
                         "--snapshot-dir; 0 = only the initial snapshot)")
    ap.add_argument("--fault-plan", default=None, metavar="PLAN",
                    help="drive the trace through the chaos harness under "
                         "this fault plan: inline JSON [[kind, tick], ...] "
                         "or @file.json.  Crashes need --snapshot-dir to "
                         "restore from")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mesh = None
    if args.mesh is not None:
        if args.engine != "paged":
            ap.error("--mesh requires --engine paged")
        try:
            dp, tp = (int(x) for x in args.mesh.split(","))
        except ValueError:
            ap.error(f"--mesh expects 'dp,tp' (got {args.mesh!r})")
        n_dev = len(jax.devices())
        if dp * tp > n_dev:
            ap.error(f"--mesh {dp},{tp} needs {dp * tp} devices, "
                     f"{n_dev} visible")
        mesh = jax.make_mesh((dp, tp), ("data", "model"))

    cfg = reduced_config(args.arch).replace(
        attn_impl=args.impl,
        bitstopper=BitStopperConfig(alpha=args.alpha),
    )
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(
        max_len=args.shared_prefix + args.max_prompt + args.new_tokens + 8,
        max_slots=args.slots, temperature=args.temperature,
        page_size=args.page_size, pool_blocks=args.pool_blocks,
        prefill_chunk=args.prefill_chunk,
        fused_decode={"auto": None, "on": True, "off": False}[
            args.fused_decode],
        speculative=args.speculative, draft_k=args.draft_k,
        oversubscribe=args.oversubscribe,
        preempt_policy=args.preempt_policy, mesh=mesh,
        deadline_ticks=args.deadline, shed_watermark=args.shed_watermark,
        snapshot_every=args.snapshot_every,
        swap_host_bytes=args.swap_host_bytes,
        prefix_store_dir=args.prefix_store_dir,
        prefix_host_bytes=args.prefix_host_bytes)
    if args.speculative != "off" and args.engine != "paged":
        ap.error("--speculative requires --engine paged "
                 "(block-table rollback)")
    if args.oversubscribe and args.engine != "paged":
        ap.error("--oversubscribe requires --engine paged "
                 "(block-pool preemption)")
    if args.engine != "paged" and (
            args.swap_host_bytes or args.prefix_host_bytes
            or args.prefix_store_dir is not None):
        ap.error("--swap-host-bytes/--prefix-store-dir/--prefix-host-bytes "
                 "require --engine paged (docs/serving.md)")
    chaos = args.fault_plan is not None or args.snapshot_dir is not None
    if args.engine != "paged" and (
            chaos or args.deadline is not None
            or args.shed_watermark is not None or args.snapshot_every):
        ap.error("--fault-plan/--snapshot-dir/--snapshot-every/--deadline/"
                 "--shed-watermark require --engine paged "
                 "(docs/robustness.md)")
    plan = None
    if args.fault_plan is not None:
        text = args.fault_plan
        if text.startswith("@"):
            with open(text[1:]) as f:
                text = f.read()
        plan = FaultPlan.from_json(text)

    def make_engine():
        return {"paged": PagedEngine,
                "continuous": ContinuousBatchingEngine,
                "static": StaticBucketEngine}[args.engine](cfg, params, scfg)

    rng = np.random.default_rng(args.seed)
    reqs = make_trace(rng, cfg.vocab, args.requests,
                      args.min_prompt, args.max_prompt, args.new_tokens,
                      shared_prefix=args.shared_prefix)
    if args.besteffort_tail:
        for r in reqs[len(reqs) - args.besteffort_tail:]:
            r.slo = "besteffort"

    if chaos:
        t0 = time.monotonic()
        reqs, rep = serve_with_chaos(
            make_engine, reqs, seed=args.seed, plan=plan,
            snapshot_dir=args.snapshot_dir)
        dt = time.monotonic() - t0
        n_tok = sum(len(r.generated) for r in reqs)
        c = rep["engine_counters"]
        print(f"[serve] {len(reqs)} requests / {n_tok} new tokens in "
              f"{dt:.2f}s ({n_tok / dt:.1f} tok/s, engine={args.engine}, "
              f"impl={args.impl}, chaos)")
        print(f"[serve] chaos: {rep['crashes']} crashes / "
              f"{rep['restores']} restores, "
              f"{rep['snapshots_taken']} snapshots "
              f"({rep['snapshots_interrupted']} interrupted, "
              f"{rep['staging_reclaimed']} staging orphans reclaimed), "
              f"fired={rep['fired_by_kind']}, unfired={rep['unfired']}")
        print(f"[serve] chaos: {c.get('degradations', 0)} kernel "
              f"degradations, {c.get('drafter_failures', 0)} drafter "
              f"failures, {c.get('forced_preemptions', 0)} forced "
              f"preemptions, {c.get('requests_shed', 0)} shed "
              f"(watermark {c.get('shed_watermark', 0)} / deadline "
              f"{c.get('shed_deadline', 0)}), "
              f"{c.get('deadline_truncated', 0)} deadline-truncated")
        print(f"[serve] counters: {c}")
        return

    engine = make_engine()
    t0 = time.monotonic()
    engine.generate(reqs, seed=args.seed)
    dt = time.monotonic() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    print(f"[serve] {len(reqs)} requests / {n_tok} new tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, engine={args.engine}, impl={args.impl})")
    if isinstance(engine, (PagedEngine, ContinuousBatchingEngine)):
        print(f"[serve] counters: {engine.counters}")
        if isinstance(engine, PagedEngine) and args.speculative != "off":
            c = engine.counters
            acc = (c["spec_accepted"] / c["spec_proposed"]
                   if c["spec_proposed"] else 0.0)
            print(f"[serve] speculative({args.speculative}, k={args.draft_k}):"
                  f" {c['spec_ticks']} verify ticks, "
                  f"{c['spec_accepted']}/{c['spec_proposed']} drafts "
                  f"accepted ({acc:.0%}), {c['spec_bailouts']} "
                  f"scale-growth bailouts, "
                  f"{c['decode_tokens']}/{c['decode_steps']} tokens/tick")
        if isinstance(engine, PagedEngine) and args.oversubscribe:
            c = engine.counters
            print(f"[serve] oversubscribed({args.preempt_policy}): "
                  f"{c['preemptions']} preemptions, "
                  f"{c['preempt_freed_blocks']} blocks reclaimed, "
                  f"{c['preempt_dropped_tokens']} cached tokens dropped "
                  f"(resume re-maps registered blocks, recomputes the "
                  f"unshared tail)")
        if isinstance(engine, PagedEngine) and (
                args.swap_host_bytes or args.prefix_host_bytes
                or args.prefix_store_dir is not None):
            if args.prefix_store_dir is not None:
                # Graceful shutdown: persist still-registered prefix
                # blocks so the next launch warms from the store.
                flushed = engine.flush_prefixes()
                print(f"[serve] prefix store: flushed {flushed} "
                      f"record(s) to {args.prefix_store_dir}")
            c = engine.counters
            print(f"[serve] hierarchy: {c['swap_outs']} swap-outs / "
                  f"{c['swap_ins']} swap-ins ({c['swap_in_tokens']} tokens "
                  f"spliced, {c['swap_fallbacks']} recompute fallbacks), "
                  f"{c['prefix_spills']} prefix spills, "
                  f"{c['prefix_store_hits']} store hits "
                  f"({c['prefix_store_tokens']} tokens warmed); "
                  f"tiers={engine.memory_report()}")
        if isinstance(engine, PagedEngine):
            print(f"[serve] kv pool: page_size={engine.layout.page_size} "
                  f"blocks={engine.layout.pool_blocks} "
                  f"peak_live={engine.pool.peak_live_blocks} "
                  f"resident={engine.kv_bytes_resident() / 1024:.1f} KiB "
                  f"(contiguous would reserve "
                  f"{engine.kv_bytes_contiguous_equiv() / 1024:.1f} KiB)")
        rep = engine.sparsity_report([r.prompt for r in reqs])
        if rep:
            agg = {k: round(v, 4) for k, v in rep.items()
                   if k != "per_request"}
            print(f"[serve] measured sparsity (aggregate): {agg}")
            for r in rep["per_request"]:
                print(f"[serve]   len={r['prompt_len']:4d} "
                      f"planes={r['plane_fraction']:.2f} "
                      f"survivors={r['survivor_fraction']:.2f}")


if __name__ == "__main__":
    main()
