"""Serving launcher: continuous-batching generation with BitStopper sparse
attention over a mixed-length request trace.

``python -m repro.launch.serve --arch stablelm-1.6b --impl bitstopper_xla``

``--engine static`` selects the legacy length-bucketed batcher (the
baseline ``benchmarks/serve_throughput.py`` measures against).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.serving import (
    ContinuousBatchingEngine,
    Request,
    ServeConfig,
    StaticBucketEngine,
)


def make_trace(rng, vocab, n_requests, min_len, max_len, new_tokens):
    """Mixed-length request trace (what a real frontend would enqueue)."""
    return [
        Request(prompt=rng.integers(0, vocab,
                                    int(rng.integers(min_len, max_len + 1)),
                                    dtype=np.int32),
                max_new_tokens=new_tokens)
        for _ in range(n_requests)
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--impl", default="bitstopper_xla",
                    choices=["xla", "bitstopper_xla"])
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "static"])
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch).replace(
        attn_impl=args.impl,
        bitstopper=BitStopperConfig(alpha=args.alpha),
    )
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_len=args.max_prompt + args.new_tokens + 8,
                       max_slots=args.slots, temperature=args.temperature)
    if args.engine == "continuous":
        engine = ContinuousBatchingEngine(cfg, params, scfg)
    else:
        engine = StaticBucketEngine(cfg, params, scfg)

    rng = np.random.default_rng(args.seed)
    reqs = make_trace(rng, cfg.vocab, args.requests,
                      args.min_prompt, args.max_prompt, args.new_tokens)
    t0 = time.monotonic()
    engine.generate(reqs, seed=args.seed)
    dt = time.monotonic() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    print(f"[serve] {len(reqs)} requests / {n_tok} new tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, engine={args.engine}, impl={args.impl})")
    if isinstance(engine, ContinuousBatchingEngine):
        print(f"[serve] counters: {engine.counters}")
        rep = engine.sparsity_report([r.prompt for r in reqs])
        if rep:
            agg = {k: round(v, 4) for k, v in rep.items()
                   if k != "per_request"}
            print(f"[serve] measured sparsity (aggregate): {agg}")
            for r in rep["per_request"]:
                print(f"[serve]   len={r['prompt_len']:4d} "
                      f"planes={r['plane_fraction']:.2f} "
                      f"survivors={r['survivor_fraction']:.2f}")


if __name__ == "__main__":
    main()
