"""Serving launcher: batched generation with BitStopper sparse attention.

``python -m repro.launch.serve --arch stablelm-1.6b --impl bitstopper_xla``
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.serving import ServeConfig, ServingEngine
from repro.serving.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--impl", default="bitstopper_xla",
                    choices=["xla", "bitstopper_xla"])
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(args.arch).replace(
        attn_impl=args.impl,
        bitstopper=BitStopperConfig(alpha=args.alpha),
    )
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, ServeConfig(
        max_len=args.prompt_len + args.new_tokens + 8))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.batch)]
    t0 = time.monotonic()
    engine.generate(reqs)
    dt = time.monotonic() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    print(f"[serve] {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, impl={args.impl})")
    rep = engine.sparsity_report(np.stack([r.prompt for r in reqs]))
    if rep:
        print(f"[serve] measured sparsity: {rep}")


if __name__ == "__main__":
    main()
