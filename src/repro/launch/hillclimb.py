import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbs: three cells, hypothesis → change → re-lower → record.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  A. stablelm-12b × train_4k     — worst roofline fraction among trains
  B. deepseek-v3-671b × decode_32k — most collective-bound
  C. musicgen-medium × decode_32k  — most representative of the paper's
                                     technique (MHA decode, KV-read-bound)

Each iteration re-runs the REAL dry-run (lower+compile+tc-analysis) and
appends a row to results/hillclimb.json.  Analytic (non-compiled) deltas —
e.g. BitStopper plane-skipping applied to measured K/V traffic — are
explicitly labeled "analytic".
"""

import json

from repro.launch.dryrun import run_cell
from repro.launch.roofline import roofline_terms

OUT = "results/hillclimb.json"


def record(rows, cell, label, hypothesis, result, note=""):
    row = {"cell": cell, "iter": label, "hypothesis": hypothesis, **result}
    if note:
        row["note"] = note
    rows.append(row)
    r = roofline_terms(result) if result.get("ok") else {}
    print(f"[hc] {cell} :: {label}: "
          + (f"comp {r.get('t_compute_s', 0):.2e} mem {r.get('t_memory_s', 0):.2e} "
               f"coll {r.get('t_collective_s', 0):.2e} "
               f"roofl {100 * r.get('roofline_fraction', 0):.1f}%"
             if result.get("ok") else f"FAILED {result.get('error')}"))
    os.makedirs("results", exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(rows, f, indent=1, default=str)


def safe(fn, **kw):
    try:
        return fn(**kw)
    except Exception as e:  # record failures as data, keep climbing
        import traceback
        return {"ok": False, "error": str(e),
                "traceback": traceback.format_exc()[-1500:]}


def cell_a(rows):
    cell = "stablelm-12b x train_4k"
    base = safe(run_cell, arch="stablelm-12b", shape_name="train_4k",
                multi_pod=False)
    record(rows, cell, "baseline", "paper-faithful substrate: f32 params, "
           "full remat, chunk 512", base)

    it1 = safe(run_cell, arch="stablelm-12b", shape_name="train_4k",
               multi_pod=False,
               cfg_overrides={"param_dtype": "bfloat16"},
               moment_dtype="bfloat16")
    record(rows, cell, "it1-bf16-params-moments",
           "weights+optimizer are ~40% of HBM traffic at f32; bf16 halves "
           "them -> predict memory term -20-25%", it1)

    it2 = safe(run_cell, arch="stablelm-12b", shape_name="train_4k",
               multi_pod=False,
               cfg_overrides={"param_dtype": "bfloat16"},
               moment_dtype="bfloat16", remat="dots")
    record(rows, cell, "it2-remat-dots",
           "full remat recomputes the whole layer (+~33% FLOPs); saving dot "
           "outputs trades bytes for FLOPs -> predict compute -25%, "
           "memory +10-15%", it2)

    it3 = safe(run_cell, arch="stablelm-12b", shape_name="train_4k",
               multi_pod=False,
               cfg_overrides={"param_dtype": "bfloat16", "attn_chunk": 1024},
               moment_dtype="bfloat16")
    record(rows, cell, "it3-chunk-1024",
           "attention tile traffic ~ nq*Sk*d per layer; doubling the chunk "
           "halves the number of K/V passes -> predict memory term -10%",
           it3)

    it4 = safe(run_cell, arch="stablelm-12b", shape_name="train_4k",
               multi_pod=False,
               cfg_overrides={"param_dtype": "bfloat16", "attn_chunk": 1024},
               moment_dtype="bfloat16", microbatches=8)
    record(rows, cell, "it4-microbatch-8",
           "8 microbatches halve live activations (15->8 GiB predicted) at "
           "the cost of 2x weight re-gathers -> memory term up slightly, "
           "peak memory down", it4)


def cell_b(rows):
    cell = "deepseek-v3-671b x decode_32k"
    base = safe(run_cell, arch="deepseek-v3-671b", shape_name="decode_32k",
                multi_pod=False)
    record(rows, cell, "baseline",
           "train-layout experts (EP over model, H FSDP over data): decode "
           "re-gathers 1.3 GiB of expert weights per layer", base)

    it1 = safe(run_cell, arch="deepseek-v3-671b", shape_name="decode_32k",
               multi_pod=False,
               cfg_overrides={"moe_resident": True},
               extra_rules_kw={"moe_resident": True})
    record(rows, cell, "it1-resident-experts",
           "256 experts / 256 chips = 1 resident expert per device; gather "
           "the 128-token decode batch (tiny) instead of the weights -> "
           "predict collective term -95% (3.3s -> ~0.15s)", it1)

    it2 = safe(run_cell, arch="deepseek-v3-671b", shape_name="decode_32k",
               multi_pod=False,
               cfg_overrides={"moe_resident": True, "param_dtype": "bfloat16"},
               extra_rules_kw={"moe_resident": True})
    record(rows, cell, "it2-bf16-weights",
           "remaining memory term is dominated by reading resident weights "
           "once per step; bf16 halves it", it2)


def cell_c(rows):
    cell = "musicgen-medium x decode_32k"
    base = safe(run_cell, arch="musicgen-medium", shape_name="decode_32k",
                multi_pod=False)
    record(rows, cell, "baseline",
           "dense decode: every step reads the whole 32k x 24-head KV "
           "cache (paper's 'Baseline' accelerator).  NB: measured bytes "
           "include a ~3.5x CPU-backend inflation (bf16-dot legalization "
           "carries the cache in f32 AND bf16 through the layer scan + "
           "layout copies) that does not exist on TPU", base)

    it1 = safe(run_cell, arch="musicgen-medium", shape_name="decode_32k",
               multi_pod=False)
    record(rows, cell, "it1-inplace-cache-update",
           "GSPMD decomposes a sharded-axis cache DUS into a masked SELECT "
           "over the whole local cache; the shard_map in-place local "
           "update (models/attention._update_cache) writes one slot",
           it1, note="change is live in _update_cache; on this CPU HLO the "
                     "saving is masked by the f32/bf16 double-carry")

    if base.get("ok"):
        import numpy as np
        from benchmarks.common import llm_like_qkv
        from repro.core.block_adaptation import block_bitstopper_attention
        from repro.core.besf import BitStopperConfig

        # TPU-native floor: per device per step, KV reads + weight reads.
        L, B, T, H, D = 48, 8, 2048, 24, 64     # T = 32768 / model 16
        kv_bytes = L * B * T * H * D * 2 * 2    # K+V, bf16
        w_bytes = 1.36e9 * 4 / 256              # f32 params, fully sharded
        tpu_floor = dict(base)
        tpu_floor["tc_bytes"] = kv_bytes + w_bytes + 2e9  # +logits/misc
        record(rows, cell, "it2-tpu-native-floor(analytic)",
               "strip CPU-only legalization traffic: TPU keeps ONE bf16 "
               "cache copy and dots read it in place -> bytes = KV "
               f"({kv_bytes/1e9:.1f} GB) + weights + logits", tpu_floor,
               note="analytic: removes CPU bf16-dot legalization artifacts")

        q, k, v = llm_like_qkv(3, 1024, d=64, Sq=8)
        res = block_bitstopper_attention(
            q, k, v, cfg=BitStopperConfig(alpha=0.6), block_q=8, block_k=64)
        plane_frac = float(np.asarray(res.stats.rounds_per_block).mean()) / 12
        alive_frac = float(np.asarray(res.stats.block_alive).mean())
        bs = dict(base)
        # fused sparse kernel: logits/softmax tiles live in VMEM (the 2 GB
        # of XLA-path intermediates disappears along with the skipped KV)
        bs["tc_bytes"] = (w_bytes + 0.1e9
                          + kv_bytes / 2 * (plane_frac * 12 / 16)  # K planes
                          + kv_bytes / 2 * alive_frac)             # live V
        record(rows, cell, "it3-bitstopper-kv(analytic)",
               f"the paper's technique on the floor: measured block "
               f"sparsity on LLM-like scores gives plane_frac="
               f"{plane_frac:.2f} (K planes actually fetched) and "
               f"alive_frac={alive_frac:.2f} (V blocks fetched); K x "
               f"plane_frac x 12/16, V x alive_frac", bs,
               note="analytic: data-dependent DMA skip modeled on measured "
                    "sparsity; realized by kernels/bitstopper_qk.py on TPU")


def main():
    rows = []
    cell_a(rows)
    cell_b(rows)
    cell_c(rows)
    print(f"[hc] wrote {OUT} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
