"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced configs end-to-end (the real thing);
on a TPU slice the same entry point builds the production mesh and rules.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced_config
from repro.data import DataConfig
from repro.sharding.rules import make_rules
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mesh", choices=["none", "production"], default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", choices=["none", "int8_ef"],
                    default="none")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
    )
    rules = None
    if args.mesh == "production":
        from repro.launch.mesh import make_production_mesh
        rules = make_rules(make_production_mesh(), n_routed=cfg.n_routed)
    run = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir)
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    trainer = Trainer(cfg, tcfg, run, rules=rules, data_cfg=data)
    trainer.train()
    print(f"[train] done: {args.steps} steps of {cfg.name}")


if __name__ == "__main__":
    main()
