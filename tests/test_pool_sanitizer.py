"""KV-pool sanitizer: seeded violations per rule class, poison-mode
stale-read detection, and serving equivalence under REPRO_SANITIZE=1.

Every negative test corrupts exactly one invariant and asserts the
sanitizer reports *that* rule — a detector that fires the wrong class
would send someone debugging the wrong subsystem.
"""

import jax
import numpy as np
import pytest

from repro.analysis.pool_sanitizer import (
    POISON_BYTE,
    POISON_KV,
    POISON_POS,
    PoolInvariantError,
    SanitizedKVBlockPool,
    SanitizedSwapPool,
    make_kv_pool,
    make_swap_pool,
    run_pool_selfcheck,
    sanitize_enabled,
)
from repro.serving.kv_pool import KVBlockPool, SwapPool


def _pool(**kw):
    return SanitizedKVBlockPool(8, 16, **kw)


# ---------------------------------------------------------------------------
# gating
# ---------------------------------------------------------------------------


def test_factory_plain_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    p = make_kv_pool(8, 16)
    assert type(p) is KVBlockPool


def test_factory_sanitized_when_enabled(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled()
    p = make_kv_pool(8, 16)
    assert isinstance(p, SanitizedKVBlockPool)


def test_swap_factory_gated_like_kv_pool(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert type(make_swap_pool(100)) is SwapPool
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert isinstance(make_swap_pool(100), SanitizedSwapPool)


# ---------------------------------------------------------------------------
# one seeded violation per rule class
# ---------------------------------------------------------------------------


def test_seeded_conservation_leak():
    """A block silently vanishing from the free list (the classic lost-
    update) trips conservation at the next audited op."""
    p = _pool()
    p._free.pop()
    with pytest.raises(PoolInvariantError) as e:
        p.reserve(0)
    assert e.value.rule == "pool-conservation"


def test_seeded_refcount_drift():
    p = _pool()
    bid = p.alloc()
    p._ref[bid] += 1                      # pool leaks a reference
    with pytest.raises(PoolInvariantError) as e:
        p.reserve(0)
    assert e.value.rule == "pool-refcount"


def test_seeded_double_free():
    p = _pool(prefix_sharing=False)
    bid = p.alloc()
    p.decref(bid)
    with pytest.raises(PoolInvariantError) as e:
        p.decref(bid)
    assert e.value.rule == "pool-use-after-free"


def test_seeded_incref_after_free():
    p = _pool(prefix_sharing=False)
    bid = p.alloc()
    p.decref(bid)
    with pytest.raises(PoolInvariantError) as e:
        p.incref(bid)                     # stale handle
    assert e.value.rule == "pool-use-after-free"


def test_seeded_reservation_drift():
    p = _pool()
    p._reserved += 1                      # phantom reservation
    with pytest.raises(PoolInvariantError) as e:
        p.reserve(0)
    assert e.value.rule == "pool-rollback-reservation"


def test_rollback_restores_reservation_units():
    """rollback(reserve=True) must re-create exactly len(bids) units —
    audited directly, and the ledger catches a pool that forgets."""
    p = _pool()
    p.reserve(2)
    a = p.alloc(reserved=True)
    b = p.alloc(reserved=True)
    p.rollback([a, b], reserve=True)
    assert p._reserved == 2
    p.cancel_reservation(2)


def test_seeded_rollback_of_registered_block():
    p = _pool()
    bid = p.alloc()
    p.register(("prefix", 0), bid)
    with pytest.raises(PoolInvariantError) as e:
        p.rollback([bid])
    assert e.value.rule == "pool-registered-protection"


def test_seeded_preempt_of_shared_block():
    p = _pool()
    bid = p.alloc()
    p.incref(bid)                         # shared by two sequences
    with pytest.raises(PoolInvariantError) as e:
        p.preempt([bid])
    assert e.value.rule == "pool-registered-protection"


def test_lookup_live_hit_and_resurrect_paths():
    """Both lookup paths keep the ledger in step: a live hit routes
    through the audited incref (and must not be double-replayed), a
    parked hit resurrects from the LRU cache."""
    p = _pool()
    bid = p.alloc()
    p.register(("sys",), bid)
    assert p.lookup(("sys",)) == bid      # live hit
    assert p.refcount(bid) == 2
    p.decref(bid)
    p.decref(bid)                         # parks
    assert p.lookup(("sys",)) == bid      # resurrect
    assert p.refcount(bid) == 1
    p.decref(bid)                         # parks again; still auditable
    p.reserve(0)


def test_error_carries_oplog():
    p = _pool(prefix_sharing=False)
    bid = p.alloc()
    p.decref(bid)
    with pytest.raises(PoolInvariantError, match="last ops"):
        p.decref(bid)


# ---------------------------------------------------------------------------
# host-tier (SwapPool) conservation
# ---------------------------------------------------------------------------


def test_sanitized_swap_clean_ops_pass():
    """Normal put/get/take/evict traffic never trips the shadow ledger."""
    spilled = []
    sp = SanitizedSwapPool(100,
                           evict_cb=lambda k, r, n: spilled.append(k))
    assert sp.put("a", {"v": 1}, 40)
    assert sp.put("b", {"v": 2}, 40)
    assert sp.get("a") == {"v": 1}
    assert sp.put("c", {"v": 3}, 40)      # evicts LRU-oldest ("b")
    assert spilled == ["b"]
    assert sp.take("a") == {"v": 1}
    assert sp.take("a") is None
    assert sp.bytes_used == 40


def test_seeded_tier_byte_leak():
    sp = SanitizedSwapPool(100)
    sp.put("a", {}, 40)
    sp.bytes_used -= 1                    # tier under-counts its bytes
    with pytest.raises(PoolInvariantError) as e:
        sp.get("a")
    assert e.value.rule == "pool-tier-conservation"


def test_seeded_tier_record_loss():
    """A record silently vanishing from the tier (the lost-swap bug that
    turns into a silent re-prefill) trips the ledger at the next read."""
    sp = SanitizedSwapPool(100)
    sp.put("a", {}, 40)
    n = sp._nbytes.pop("a")               # tier drops the record...
    sp._records.pop("a")
    sp.bytes_used -= n                    # ...with self-consistent bytes
    with pytest.raises(PoolInvariantError) as e:
        sp.get("a")                       # ledger still expects it
    assert e.value.rule == "pool-tier-conservation"


def test_refused_put_leaves_ledger_clean():
    sp = SanitizedSwapPool(50)
    assert not sp.put("big", {}, 60)
    assert sp.refused_count == 1
    assert sp.put("ok", {}, 40)           # tier still fully serviceable
    assert sp.take("ok") == {}


# ---------------------------------------------------------------------------
# poison mode
# ---------------------------------------------------------------------------


def test_poison_cb_fires_on_every_free_path():
    """decref-to-free, rollback, preempt and LRU eviction all report the
    dying block before it can be handed to a new owner."""
    poisoned = []
    p = _pool(poison_cb=poisoned.extend)
    a = p.alloc()
    p.decref(a)                           # unregistered -> free
    assert a in poisoned

    b = p.alloc()
    p.rollback([b], reserve=False)
    assert b in poisoned

    c = p.alloc()
    p.preempt([c])
    assert c in poisoned

    # LRU eviction: park every block behind a registered prefix, then
    # drain the free list so the next alloc must evict.
    p2_poisoned = []
    p2 = SanitizedKVBlockPool(4, 16, poison_cb=p2_poisoned.extend)
    parked = []
    for i in range(3):
        bid = p2.alloc()
        p2.register(("k", i), bid)
        p2.decref(bid)
        parked.append(bid)
    evictee = p2.alloc()                  # must evict the LRU parked block
    assert evictee == parked[0]
    assert p2_poisoned == [parked[0]]


def test_poison_never_touches_null_block():
    p = _pool(poison_cb=lambda bids: None)
    with pytest.raises(PoolInvariantError) as e:
        p._poison([0])
    assert e.value.rule == "pool-conservation"


def test_poisoned_read_is_loud():
    """The end-to-end property the rule class names: data written to a
    block, read back through a *stale* block-table entry after the block
    was freed, comes back as the poison sentinel — not the stale KV."""
    pages = np.zeros((8, 16), np.float32)

    def cb(bids):
        pages[np.asarray(bids)] = POISON_KV

    p = _pool(prefix_sharing=False, poison_cb=cb)
    bid = p.alloc()
    pages[bid] = 3.25                     # the sequence writes its KV
    stale_table = np.array([bid])         # someone keeps the old table
    p.decref(bid)                         # block freed -> pages poisoned
    gathered = pages[stale_table]
    assert np.all(gathered == POISON_KV), \
        "stale-table gather returned stale KV instead of poison"


# ---------------------------------------------------------------------------
# self-check + serving integration
# ---------------------------------------------------------------------------


def test_selfcheck_clean():
    findings, meta = run_pool_selfcheck()
    assert findings == []
    assert meta["scenarios"] == 8


@pytest.fixture(scope="module")
def model():
    from repro.configs import reduced_config
    from repro.models import transformer as T
    cfg = reduced_config("stablelm-1.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _paged_engine(cfg, params):
    from repro.serving import PagedEngine, ServeConfig
    return PagedEngine(cfg, params, ServeConfig(
        max_len=64, max_slots=2, prefill_bucket=8, page_size=8))


def _reqs(cfg, lens, max_new=4, seed=0):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, L, dtype=np.int32),
                    max_new_tokens=max_new)
            for L in lens]


def _paged_layers(c):
    if isinstance(c, dict):
        if "table" in c:
            yield c
        else:
            for v in c.values():
                yield from _paged_layers(v)
    elif isinstance(c, (list, tuple)):
        for v in c:
            yield from _paged_layers(v)


def test_sanitized_serving_token_equivalence(model, monkeypatch):
    """The wrapper + poison mode must not perturb served tokens: freed
    pages are dead by construction, so poisoning them is invisible to a
    correct engine."""
    cfg, params = model
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    plain = _reqs(cfg, (5, 9, 7))
    _paged_engine(cfg, params).generate(plain, seed=0)

    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sanitized = _reqs(cfg, (5, 9, 7))
    _paged_engine(cfg, params).generate(sanitized, seed=0)
    assert [r.generated for r in plain] == [r.generated for r in sanitized]


def test_sanitized_engine_poisons_freed_pool_pages(model, monkeypatch):
    """After requests complete their blocks return to the free list, and
    the engine's poison callback must have overwritten those pool pages
    with the sentinels — a stale block-table read would be loud."""
    cfg, params = model
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    eng = _paged_engine(cfg, params)
    assert isinstance(eng.pool, SanitizedKVBlockPool)
    reqs = _reqs(cfg, (5, 9))
    eng.generate(reqs, seed=0)
    assert all(len(r.generated) == 4 for r in reqs)

    free = sorted(set(eng.pool._free) - {0})
    assert free, "pool should have free blocks after all requests finish"
    layers = list(_paged_layers(eng.caches))
    assert layers, "paged engine must expose paged cache layers"
    found_poisoned = False
    for c in layers:
        stacked = c["table"].ndim == 3
        for bid in free:
            k = np.asarray(c["k"][:, bid] if stacked else c["k"][bid])
            pos = np.asarray(c["pos"][:, bid] if stacked else c["pos"][bid])
            if np.all(k == POISON_KV):
                assert np.all(pos == POISON_POS)
                if "kq" in c:
                    kq = np.asarray(c["kq"][:, bid] if stacked
                                    else c["kq"][bid])
                    assert np.all(kq == POISON_BYTE)
                found_poisoned = True
    assert found_poisoned, \
        "no freed pool page carries the poison sentinel — freed-page " \
        "poisoning is dark"


def test_sanitized_engine_wraps_host_tiers(model, monkeypatch):
    """With REPRO_SANITIZE=1 the engine's swap and host-prefix tiers are
    the audited SwapPool subclass, so every serve exercises the tier-
    conservation ledger alongside the device-pool audits."""
    cfg, params = model
    from repro.serving import PagedEngine, ServeConfig
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    eng = PagedEngine(cfg, params, ServeConfig(
        max_len=64, max_slots=3, prefill_bucket=8, page_size=8,
        pool_blocks=10, oversubscribe=True, swap_host_bytes=1 << 20,
        prefix_host_bytes=1 << 20))
    assert isinstance(eng.pool, SanitizedKVBlockPool)
    assert isinstance(eng._swap, SanitizedSwapPool)
    assert isinstance(eng._prefix_host, SanitizedSwapPool)
    reqs = _reqs(cfg, (12, 9, 11), max_new=16)
    eng.generate(reqs, seed=0)            # swap traffic under audit
    assert eng.counters["swap_ins"] >= 1
    assert eng._swap.bytes_used == 0
