"""Mesh-sharded paged serving: the standing bit-identity invariant.

``ServeConfig.mesh`` shards the paged KV pools over KV heads ("model"
axis) and serving slots over "data", wrapping the four paged attention
calls in ``shard_map`` (docs/serving.md, "Multi-device serving").  The
invariant these tests pin: **the served token streams are bit-identical
to single-device serving on every path** — greedy, seeded sampling,
shared-prefix CoW, speculative, oversubscribed/preempting, through both
the fused kernel and the gather fallback.

Like test_sharding_multidev.py, each test spawns a fresh interpreter
with 8 forced host devices (the main pytest process must keep its single
CPU device); several serving configs share one subprocess to amortize
interpreter + compile startup.
"""

import os
import subprocess
import sys
import textwrap

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=_SRC)
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=540)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


_PRELUDE = """
    import numpy as np
    import jax

    from repro.configs import reduced_config
    from repro.core.besf import BitStopperConfig
    from repro.models import transformer as T
    from repro.serving import PagedEngine, Request, ServeConfig
    from repro.launch.mesh import make_debug_mesh

    def serve(mesh, fused, n_kv=None, speculative='off', temperature=0.0,
              oversub=False, shared_prefix=0):
        cfg = reduced_config('stablelm-1.6b').replace(
            attn_impl='bitstopper_xla',
            bitstopper=BitStopperConfig(alpha=0.85))
        if n_kv is not None:
            cfg = cfg.replace(n_kv_heads=n_kv)
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        kw = dict(max_len=64, max_slots=2, prefill_bucket=4, page_size=8,
                  fused_decode=fused, mesh=mesh, temperature=temperature,
                  speculative=speculative)
        if oversub:
            kw.update(pool_blocks=10, oversubscribe=True)
        eng = PagedEngine(cfg, params, ServeConfig(**kw))
        rng = np.random.default_rng(3)
        prefix = rng.integers(0, cfg.vocab, shared_prefix, dtype=np.int32)
        reqs = [Request(prompt=np.concatenate(
                            [prefix, rng.integers(0, cfg.vocab, L,
                                                  dtype=np.int32)]),
                        max_new_tokens=6) for L in (5, 9, 7)]
        eng.generate(reqs, seed=0)
        return [list(r.generated) for r in reqs], eng

    def check(name, mesh, **kw):
        ref, _ = serve(None, **kw)
        got, eng = serve(mesh, **kw)
        assert got == ref, (name, ref, got)
        assert all(ref), (name, 'empty generation proves nothing', ref)
        print(name, 'OK')
        return eng
"""


def test_sharded_tokens_bit_identical_decode_paths():
    """Greedy through both decode paths + seeded sampling: sharded (2,2)
    == single-device, token for token.  Also proves the plane pool is
    physically sharded (local Hkv == Hkv / tp on every device)."""
    _run(_PRELUDE + """
        mesh = make_debug_mesh(2, 2)
        check('greedy-fallback', mesh, fused=False)
        eng = check('greedy-fused', mesh, fused=True)
        check('seeded', mesh, fused=False, temperature=0.8)

        kq = eng.caches['seg0']['b0']['kq']
        for shard in kq.addressable_shards:
            assert shard.data.shape[-2] == kq.shape[-2] // 2, (
                kq.shape, shard.data.shape)
        print('POOL SHARDED: OK', kq.shape, '->', shard.data.shape)
    """)


def test_sharded_tokens_bit_identical_serving_features():
    """Shared-prefix CoW, speculative draft-verify, and oversubscribed
    preemption/resume all stay bit-identical under the mesh — the
    host-side block-table machinery is device-count-blind (tables and
    fill levels replicated over 'model', sharded only over 'data')."""
    _run(_PRELUDE + """
        mesh = make_debug_mesh(2, 2)
        eng = check('shared-prefix', mesh, fused=False, shared_prefix=12)
        assert eng.counters['prefix_hit_tokens'] > 0, eng.counters
        check('speculative', mesh, fused=False, speculative='ngram')
        eng = check('oversubscribed', mesh, fused=False, oversub=True)
        print('FEATURES OK', eng.counters['preemptions'], 'preemptions')
    """)


def test_mqa_indivisible_heads_fall_back_replicated():
    """n_kv_heads == 1 with tp == 2: heads are indivisible, the pools
    replicate over 'model' and attention runs unsharded — still
    bit-identical, and the kq leaf must NOT be head-split."""
    _run(_PRELUDE + """
        mesh = make_debug_mesh(2, 2)
        eng = check('mqa-fallback', mesh, fused=False, n_kv=1)
        kq = eng.caches['seg0']['b0']['kq']
        for shard in kq.addressable_shards:
            assert shard.data.shape[-2] == kq.shape[-2], (
                kq.shape, shard.data.shape)
        print('MQA REPLICATED: OK')
    """)


def test_paged_cache_rules_cover_every_leaf():
    """Every leaf of the paged cache tree must be matched by an explicit
    PAGED_CACHE_RULES entry: pool leaves KV-head-sharded over 'model',
    per-slot leaves sharded over 'data' — a newly added leaf name that
    silently falls through to replicated fails here.  Runs on the single
    in-process CPU device (a 1x1 mesh exercises the same rule lookup)."""
    import jax
    from repro.configs import reduced_config
    from repro.models import transformer as T
    from repro.models.attention import PagedLayout
    from repro.sharding.rules import PAGED_CACHE_RULES, cache_pspecs, \
        make_serve_rules

    cfg = reduced_config("stablelm-1.6b").replace(
        attn_impl="bitstopper_xla", fused_decode=True)  # kq plane pool on
    layout = PagedLayout(pool_blocks=12, page_size=8, max_blocks_per_req=4)
    caches = T.init_caches(cfg, batch=2, max_len=32, paged=layout)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = cache_pspecs(make_serve_rules(mesh), caches)

    expect_axis = {"k": "model", "v": "model", "kq": "model",
                   "k_amax": "model", "v_amax": "model",
                   "table": "data", "length": "data", "pos": None}
    flat, _ = jax.tree_util.tree_flatten_with_path(caches)
    flat_specs = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(flat) == len(flat_specs)
    seen = set()
    for (path, leaf), spec in zip(flat, flat_specs):
        name = path[-1].key
        assert name in PAGED_CACHE_RULES, f"unruled paged leaf {name!r}"
        assert len(spec) <= leaf.ndim, (name, spec, leaf.shape)
        want = expect_axis[name]
        assert (want in tuple(spec)) if want else all(
            s is None for s in tuple(spec)), (name, spec)
        seen.add(name)
    assert seen >= {"k", "v", "kq", "k_amax", "v_amax", "table", "length",
                    "pos"}, seen
