"""Make `repro` (under src/) and the test-local shim importable regardless
of how pytest is invoked — ``PYTHONPATH=src python -m pytest`` and a bare
``python -m pytest`` both work."""

import os
import sys

_HERE = os.path.dirname(__file__)
for p in (os.path.join(_HERE, "..", "src"), _HERE):
    p = os.path.abspath(p)
    if p not in sys.path:
        sys.path.insert(0, p)
