"""Substrate tests: data determinism, checkpoint atomicity/roundtrip,
optimizer correctness, fault-tolerance policies, serving engine."""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step
from repro.data import DataConfig, SyntheticLMDataset
from repro.runtime import ClusterMonitor, ElasticMeshManager, StragglerPolicy
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_across_instances():
    cfg = DataConfig(vocab=256, seq_len=64, global_batch=4, seed=5)
    a = SyntheticLMDataset(cfg).batch_at(17)
    b = SyntheticLMDataset(cfg).batch_at(17)
    np.testing.assert_array_equal(a, b)


def test_data_shards_partition_global_batch():
    cfg = DataConfig(vocab=256, seq_len=32, global_batch=8, seed=5)
    full = SyntheticLMDataset(cfg).batch_at(3)
    shards = [SyntheticLMDataset(cfg, shard=i, num_shards=4).batch_at(3)
              for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), full)


def test_data_prefetch_matches_sync():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=2, seed=1)
    ds = SyntheticLMDataset(cfg)
    ds.start_prefetch(start_step=5)
    step, batch = ds.next_batch()
    ds.stop()
    assert step == 5
    np.testing.assert_array_equal(batch, ds.batch_at(5))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nest": {"b": jnp.arange(6, dtype=jnp.int32),
                     "c": [jnp.ones(3), jnp.zeros(2)]}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tree, str(tmp_path), 7, n_shards=3)
    restored, step = load_checkpoint(_tree(1), str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A crashed (partial) save must never shadow the last good one."""
    tree = _tree()
    save_checkpoint(tree, str(tmp_path), 1)
    # simulate a crash: stale .tmp directory from a dead writer
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1
    restored, step = load_checkpoint(_tree(1), str(tmp_path))
    assert step == 1


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save_async(_tree(s), s)
    mgr.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [20, 30]
    _, latest = mgr.restore(_tree(0))
    assert latest == 30


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = init_opt_state(p, cfg)
    p1, st1, _ = adamw_update(p, g, st, cfg)
    # step 1: m_hat = g, v_hat = g^2 -> delta = g/(|g|+eps) = sign(g)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray(p["w"]) - 0.1 * np.sign([0.5, 0.5]),
                               rtol=1e-5)


def test_grad_clip_triggers():
    from repro.train.optimizer import clip_by_global_norm
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["w"])), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# fault tolerance policies
# ---------------------------------------------------------------------------


def test_cluster_monitor_detects_failures():
    mon = ClusterMonitor(n_nodes=8, timeout=10.0)
    assert mon.healthy_count() == 8
    mon.inject_failure(3)
    assert mon.failed_nodes() == {3}
    mon.recover(3)
    assert mon.healthy_count() == 8


def test_elastic_mesh_preserves_tp_degree():
    mgr = ElasticMeshManager(model_parallel=4, devices_per_node=4)
    d = mgr.decide(healthy_nodes=7)          # 28 devices
    assert d.model == 4 and d.data == 7
    with pytest.raises(RuntimeError):
        ElasticMeshManager(model_parallel=64, devices_per_node=1).decide(8)


def test_straggler_policy():
    pol = StragglerPolicy(slack=2.0)
    for _ in range(10):
        pol.observe(1.0)
    assert not pol.is_straggler(1.5)
    assert pol.is_straggler(2.5)
    donor = StragglerPolicy.reassign_shard(3, [0, 1, 2, 4], step=7)
    assert donor in [0, 1, 2, 4]
    # deterministic: every host computes the same donor
    assert donor == StragglerPolicy.reassign_shard(3, [0, 1, 2, 4], step=7)


# ---------------------------------------------------------------------------
# trainer resume equivalence
# ---------------------------------------------------------------------------


def test_trainer_resume_bit_identical():
    """train(6) == train(3) + resume-train(3): same data, same final loss."""
    from repro.configs import reduced_config
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = reduced_config("stablelm-1.6b")
    tcfg = TrainConfig(total_steps=6, warmup_steps=2)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=0)

    d1 = tempfile.mkdtemp()
    t1 = Trainer(cfg, tcfg, TrainerConfig(steps=6, ckpt_every=100,
                                          ckpt_dir=d1, log_every=0),
                 data_cfg=data)
    s_straight = t1.train()

    d2 = tempfile.mkdtemp()
    t2 = Trainer(cfg, tcfg, TrainerConfig(steps=3, ckpt_every=3,
                                          ckpt_dir=d2, log_every=0),
                 data_cfg=data)
    t2.train()
    t3 = Trainer(cfg, tcfg, TrainerConfig(steps=6, ckpt_every=100,
                                          ckpt_dir=d2, log_every=0),
                 data_cfg=data)
    s_resumed = t3.train()

    a = jax.tree_util.tree_leaves(s_straight["params"])
    b = jax.tree_util.tree_leaves(s_resumed["params"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-6, rtol=1e-6)
    shutil.rmtree(d1)
    shutil.rmtree(d2)
