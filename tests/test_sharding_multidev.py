"""Multi-device sharding tests (subprocess-isolated: the main pytest
process must keep its single CPU device, so each test spawns a fresh
interpreter with XLA_FLAGS forcing 8 host devices)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=_SRC)
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=540)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_train_step_sharded_matches_single_device():
    """The sharded (2x4 mesh, FSDP+TP) train step must produce the same
    loss and parameters as the unsharded one."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import make_debug_mesh
        from repro.sharding.rules import make_rules
        from repro.sharding.api import use_rules
        from repro.train.train_step import TrainConfig, make_train_step, \\
            init_train_state

        cfg = reduced_config('stablelm-1.6b')
        tcfg = TrainConfig()
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                    cfg.vocab)
        state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        step = make_train_step(cfg, tcfg)

        ref_state, ref_metrics = jax.jit(step)(state, tokens)

        mesh = make_debug_mesh(2, 4)
        rules = make_rules(mesh, n_routed=cfg.n_routed)
        with use_rules(rules):
            state_sh = jax.device_put(
                state, rules.tree_shardings(state))
            tok_sh = jax.device_put(tokens, rules.sharding(('batch', None),
                                                           tokens.shape))
            new_state, metrics = jax.jit(step)(state_sh, tok_sh)

        np.testing.assert_allclose(float(metrics['loss']),
                                   float(ref_metrics['loss']),
                                   rtol=2e-4, atol=2e-4)
        for a, b in zip(jax.tree_util.tree_leaves(ref_state['params']),
                        jax.tree_util.tree_leaves(new_state['params'])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)
        print('SHARDED == SINGLE: OK')
    """)


def test_moe_ep_matches_single_device():
    """shard_map EP (experts over 'model') must equal the tp=1 path."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.models.moe import MoEConfig, init_moe, moe_ffn
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = MoEConfig(d_model=32, n_routed=8, top_k=2, d_expert=16,
                        capacity_factor=8.0)
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        ref, aux_ref = moe_ffn(p, x, cfg, mesh=None)

        mesh = make_debug_mesh(2, 4)       # EP degree 4 (8 % 4 == 0)
        out, aux = jax.jit(
            lambda p, x: moe_ffn(p, x, cfg, mesh=mesh))(p, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print('MOE EP == SINGLE: OK')
    """)


def test_moe_expert_tp_matches_single_device():
    """expert-TP path (n_routed not divisible by the axis)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.models.moe import MoEConfig, init_moe, moe_ffn

        cfg = MoEConfig(d_model=32, n_routed=6, top_k=2, d_expert=16,
                        capacity_factor=8.0)   # 6 % 4 != 0 -> expert-TP
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        ref, _ = moe_ffn(p, x, cfg, mesh=None)
        mesh = make_debug_mesh(2, 4)
        out, _ = jax.jit(lambda p, x: moe_ffn(p, x, cfg, mesh=mesh))(p, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print('MOE expert-TP == SINGLE: OK')
    """)


def test_int8_gradient_allreduce():
    """int8+error-feedback all-reduce approximates the f32 mean and the
    residual carries the quantization error."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.train.train_step import allreduce_int8_ef

        mesh = make_debug_mesh(2, 4)
        g = {'w': jax.random.normal(jax.random.PRNGKey(0), (16, 16))}
        e = {'w': jnp.zeros((16, 16))}
        out, err = jax.jit(
            lambda g, e: allreduce_int8_ef(g, e, mesh, ('data',)))(g, e)
        # replicated input: mean over data axis == input, up to int8 error
        np.testing.assert_allclose(np.asarray(out['w']),
                                   np.asarray(g['w']), atol=0.05)
        resid = np.asarray(err['w'])
        assert np.abs(resid).max() <= float(
            np.abs(np.asarray(g['w'])).max()) / 127 + 1e-6
        print('INT8 ALLREDUCE: OK')
    """)


def test_elastic_remesh_rebuilds_and_reshards():
    """Device loss: rebuild a smaller mesh and re-shard params from host."""
    _run("""
        import jax, numpy as np
        from repro.runtime import ElasticMeshManager
        from repro.sharding.rules import make_rules
        from repro.sharding.api import use_rules
        from repro.configs import reduced_config
        from repro.models import transformer as T

        cfg = reduced_config('stablelm-1.6b')
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        host = jax.tree_util.tree_map(np.asarray, params)

        mgr = ElasticMeshManager(model_parallel=2, devices_per_node=1)
        d = mgr.decide(healthy_nodes=6)          # lost 2 of 8 nodes
        assert d.model == 2 and d.data == 3
        mesh = mgr.rebuild_mesh(d)
        rules = make_rules(mesh, n_routed=0)
        resharded = jax.device_put(host, rules.tree_shardings(params))
        for a, b in zip(jax.tree_util.tree_leaves(host),
                        jax.tree_util.tree_leaves(resharded)):
            np.testing.assert_array_equal(a, np.asarray(b))
        print('ELASTIC REMESH: OK')
    """)


def test_dryrun_cell_tiny_mesh():
    """End-to-end dry-run machinery on a small mesh (8 devices) — the same
    code path as the 512-device production run."""
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs import reduced_config, get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.sharding.rules import make_rules
        from repro.sharding.api import use_rules
        from repro.launch.dryrun import train_cell
        from repro.configs.shapes import ShapeSuite
        from repro.launch.hlo_cost import analyze_hlo

        cfg = reduced_config('qwen2-moe-a2.7b')
        shape = ShapeSuite('tiny_train', 64, 8, 'train')
        mesh = make_debug_mesh(2, 4)
        rules = make_rules(mesh, n_routed=cfg.n_routed)
        with use_rules(rules):
            step, specs = train_cell(cfg, shape, mesh, rules)
            compiled = jax.jit(step).lower(*specs).compile()
            mem = compiled.memory_analysis()
            cost = analyze_hlo(compiled.as_text())
        assert mem.temp_size_in_bytes > 0
        assert cost.flops > 0
        print('DRYRUN TINY MESH: OK', cost.flops)
    """)
