"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.besf import BitStopperConfig
from repro.kernels import ref as ref_lib
from repro.kernels.bitstopper_qk import bitstopper_attention_kernel
from repro.kernels.flash_attention import flash_attention_single


def _rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash_attention kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Sq,Sk,d,dv", [
    (128, 128, 64, 64),
    (128, 256, 64, 128),
    (256, 256, 128, 128),
    (64, 128, 32, 32),
])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_matches_ref(Sq, Sk, d, dv, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k = _rand(ks[0], Sq, d), _rand(ks[1], Sk, d)
    v = _rand(ks[2], Sk, dv)
    got = flash_attention_single(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref_lib.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (_rand(x, 128, 128, dtype=dtype) for x in ks)
    got = flash_attention_single(q, k, v)
    want = ref_lib.flash_attention(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=tol, rtol=tol
    )


# ---------------------------------------------------------------------------
# bitstopper fused kernel vs block-granular oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Sq,Sk,d,dv,bq,bk", [
    (64, 64, 32, 32, 32, 32),
    (64, 128, 64, 64, 32, 64),
    (128, 128, 64, 64, 64, 64),
    (32, 256, 64, 32, 32, 64),
])
@pytest.mark.parametrize("alpha", [0.2, 0.6])
def test_bitstopper_kernel_matches_oracle(Sq, Sk, d, dv, bq, bk, alpha):
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    # Heavy-tailed scores so pruning actually fires.
    q = _rand(ks[0], Sq, d) * 2.0
    k = _rand(ks[1], Sk, d) * 2.0
    v = _rand(ks[2], Sk, dv)
    cfg = BitStopperConfig(alpha=alpha)

    got = bitstopper_attention_kernel(q, k, v, cfg=cfg, block_q=bq, block_k=bk)
    want = ref_lib.bitstopper_attention(q, k, v, cfg=cfg, block_q=bq, block_k=bk)

    np.testing.assert_allclose(got.out, want.out, atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(
        np.asarray(got.survivors, bool), np.asarray(want.stats.survivors)
    )
    np.testing.assert_array_equal(got.rounds, want.stats.rounds_per_block)


@pytest.mark.parametrize("causal", [False, True])
def test_bitstopper_kernel_causal(causal):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (_rand(x, 128, 64) for x in ks)
    cfg = BitStopperConfig(alpha=0.6)
    got = bitstopper_attention_kernel(q, k, v, cfg=cfg, block_q=64, block_k=64,
                                      causal=causal)
    want = ref_lib.bitstopper_attention(q, k, v, cfg=cfg, block_q=64, block_k=64,
                                        causal=causal)
    np.testing.assert_allclose(got.out, want.out, atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(
        np.asarray(got.survivors, bool), np.asarray(want.stats.survivors)
    )


def test_bitstopper_kernel_batched():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], 2, 3, 64, 32)   # [B, H, S, d]
    k = _rand(ks[1], 2, 3, 64, 32)
    v = _rand(ks[2], 2, 3, 64, 32)
    got = bitstopper_attention_kernel(q, k, v, block_q=32, block_k=32)
    want = ref_lib.bitstopper_attention(q, k, v, block_q=32, block_k=32)
    assert got.out.shape == (2, 3, 64, 32)
    np.testing.assert_allclose(got.out, want.out, atol=2e-5, rtol=2e-5)


def test_bitstopper_kernel_skips_planes():
    """With a spiky attention distribution whole kv blocks terminate early,
    so the kernel fetches strictly fewer bit planes than the dense 12/block."""
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    d = 64
    u = jax.random.normal(ks[0], (d,))
    u = u / jnp.linalg.norm(u)
    # All queries share a dominant direction; the first kv block contains the
    # only keys aligned with it — every later block is prunable early.  The
    # hot-pair logit is ~ (8*8)/sqrt(64) = 8 » alpha*radius = 2, so LATS has
    # real headroom to prune (a <2-logit spread is *correctly* kept whole).
    q = 8.0 * u[None, :] + 0.05 * jax.random.normal(ks[1], (64, d))
    k_hot = 8.0 * u[None, :] + 0.05 * jax.random.normal(ks[2], (32, d))
    k_cold = 0.05 * jax.random.normal(ks[3], (224, d))
    k = jnp.concatenate([k_hot, k_cold], axis=0)
    v = jax.random.normal(jax.random.PRNGKey(12), (256, d))
    cfg = BitStopperConfig(alpha=0.4)
    got = bitstopper_attention_kernel(q, k, v, cfg=cfg, block_q=32, block_k=32)
    total_rounds = int(np.asarray(got.rounds).sum())
    dense_rounds = got.rounds.size * cfg.bits
    assert total_rounds < dense_rounds, (
        f"no early termination: {total_rounds} == {dense_rounds}"
    )
    # Output must still match the oracle bit-for-bit.
    want = ref_lib.bitstopper_attention(q, k, v, cfg=cfg, block_q=32, block_k=32)
    np.testing.assert_allclose(got.out, want.out, atol=2e-5, rtol=2e-5)


def test_bitstopper_kernel_alpha0_is_exactish_dense():
    """alpha=0 prunes only tokens strictly below the max lower bound; output
    must match dense INT12 attention on the surviving mass ~closely."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (_rand(x, 64, 64) for x in ks)
    cfg = BitStopperConfig(alpha=1.0)  # widest threshold: keep nearly all
    got = bitstopper_attention_kernel(q, k, v, cfg=cfg, block_q=32, block_k=32)
    dense = ref_lib.flash_attention(q, k, v)
    # INT12 quantization error only.
    np.testing.assert_allclose(got.out, dense, atol=5e-2, rtol=5e-2)
