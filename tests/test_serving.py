"""Serving-engine tests: continuous batching, decode/prefill parity,
deterministic sampling, the per-slot and paged KV caches, block/slot
lifecycle, prefix sharing, and the decode-specialized BitStopper path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig, besf_attention, \
    besf_attention_decode
from repro.models import transformer as T
from repro.serving import (
    ContinuousBatchingEngine,
    PagedEngine,
    Request,
    ServeConfig,
    StaticBucketEngine,
)


@pytest.fixture(scope="module")
def model():
    cfg = reduced_config("stablelm-1.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, L, dtype=np.int32),
                    max_new_tokens=max_new)
            for L in lens]


def _engine(cfg, params, **kw):
    scfg = ServeConfig(max_len=kw.pop("max_len", 64),
                       max_slots=kw.pop("max_slots", 2),
                       prefill_bucket=kw.pop("prefill_bucket", 8), **kw)
    return ContinuousBatchingEngine(cfg, params, scfg)


# ---------------------------------------------------------------------------
# decode/prefill parity through the continuous-batching engine
# ---------------------------------------------------------------------------


def test_decode_matches_prefill_bitexact_xla(model):
    """A sequence decoded token-by-token through the engine must follow the
    same greedy path as a one-shot (cache-free) prefill forward pass."""
    cfg, params = model
    eng = _engine(cfg, params)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 9, dtype=np.int32)
    req = Request(prompt=prompt, max_new_tokens=8)
    eng.generate([req], seed=0)

    seq = np.concatenate([prompt, np.asarray(req.generated[:-1], np.int32)])
    logits, _, _ = T.forward(params, jnp.asarray(seq)[None], cfg)
    greedy = np.asarray(jnp.argmax(logits[0], -1))[len(prompt) - 1:]
    assert req.generated == [int(t) for t in greedy]


def test_decode_matches_prefill_bitstopper(model):
    """Same parity on the sparse path, within tolerance: block-granular
    prefill and the per-token decode fast path may disagree on pruned
    (near-zero-mass) candidates, so compare next-token logits loosely and
    the greedy path exactly."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    eng = _engine(cfgb, params)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfgb.vocab, 9, dtype=np.int32)
    req = Request(prompt=prompt, max_new_tokens=5)
    eng.generate([req], seed=0)
    assert len(req.generated) == 5

    # Dense one-shot forward: the sparse serve must track it closely.
    seq = np.concatenate([prompt, np.asarray(req.generated[:-1], np.int32)])
    logits, _, _ = T.forward(params, jnp.asarray(seq)[None],
                             cfg.replace(attn_impl="xla"))
    greedy = [int(t) for t in
              np.asarray(jnp.argmax(logits[0], -1))[len(prompt) - 1:]]
    assert req.generated == greedy


# ---------------------------------------------------------------------------
# continuous batching semantics
# ---------------------------------------------------------------------------


def test_mixed_lengths_isolated_slots(model):
    """Requests of different lengths served together (queue > slots) must
    each produce exactly what they produce when served alone — slot caches
    are isolated and masks respect per-slot fill levels."""
    cfg, params = model
    lens = (5, 11, 17)
    together = _reqs(cfg, lens)
    _engine(cfg, params).generate(together, seed=0)
    assert all(len(r.generated) == 6 for r in together)

    for i, L in enumerate(lens):
        alone = _reqs(cfg, lens)[i]          # same prompts (same seed)
        _engine(cfg, params).generate([alone], seed=0)
        assert alone.generated == together[i].generated, f"slot {i} differs"


def test_queue_admission_and_eviction(model):
    """More requests than slots: all finish, and the engine interleaves
    prefill with in-flight decode (scheduler actually continuous)."""
    cfg, params = model
    reqs = _reqs(cfg, (5, 7, 9, 11, 13), max_new=4)
    eng = _engine(cfg, params, max_slots=2)
    eng.generate(reqs, seed=0)
    assert all(len(r.generated) == 4 for r in reqs)
    assert eng.counters["requests_finished"] == 5
    # with 2 slots and 5 requests, at least one admission must have
    # happened after decoding started (interleaving, not phases)
    assert max(r.admitted_step for r in reqs) > 0
    assert all(r is None for r in eng.slots)


def test_eos_eviction(model):
    cfg, params = model
    eng = _engine(cfg, params)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 9, dtype=np.int32)
    free_run = Request(prompt=prompt.copy(), max_new_tokens=8)
    eng.generate([free_run], seed=0)
    eos = free_run.generated[2]              # force a stop at step 3

    eng2 = ContinuousBatchingEngine(cfg, params, ServeConfig(
        max_len=64, max_slots=2, prefill_bucket=8, eos_id=int(eos)))
    stopped = Request(prompt=prompt.copy(), max_new_tokens=8)
    eng2.generate([stopped], seed=0)
    assert stopped.generated == free_run.generated[:3]


def test_max_len_rejection(model):
    cfg, params = model
    eng = _engine(cfg, params, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(12, np.int32), max_new_tokens=8))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(4, np.int32), max_new_tokens=0))


def test_prefill_bucket_invariance(model):
    """Bucket padding must not change served tokens: pad rows are zeroed
    before attention, so the BitStopper per-tensor quant scale (and hence
    every threshold decision) is independent of the bucket size."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.6))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfgb.vocab, 9, dtype=np.int32)
    outs = []
    for bucket in (1, 8, 16):
        eng = _engine(cfgb, params, prefill_bucket=bucket)
        req = Request(prompt=prompt.copy(), max_new_tokens=5)
        eng.generate([req], seed=0)
        outs.append(req.generated)
    assert outs[0] == outs[1] == outs[2], outs


# ---------------------------------------------------------------------------
# paged engine: parity, block/slot lifecycle, prefix sharing
# ---------------------------------------------------------------------------


def _paged(cfg, params, **kw):
    scfg = ServeConfig(max_len=kw.pop("max_len", 64),
                       max_slots=kw.pop("max_slots", 2),
                       prefill_bucket=kw.pop("prefill_bucket", 8),
                       page_size=kw.pop("page_size", 8), **kw)
    return PagedEngine(cfg, params, scfg)


def test_paged_matches_contiguous_bitexact_greedy(model):
    """Acceptance: the paged engine's served tokens are bit-identical to
    the contiguous ContinuousBatchingEngine on the same trace and seed
    (dense path: per-query attention sees the same KV set, masked paged
    view slots are exact zeros)."""
    cfg, params = model
    a = _reqs(cfg, (5, 11, 17, 9))
    _engine(cfg, params).generate(a, seed=0)
    b = _reqs(cfg, (5, 11, 17, 9))
    _paged(cfg, params).generate(b, seed=0)
    assert [r.generated for r in a] == [r.generated for r in b]


def test_paged_matches_contiguous_bitexact_sampled(model):
    """Same trace, seeded sampling: per-request sampling keys are a pure
    function of (seed, rid, token index), so chunked-prefill scheduling
    differences cannot shift the sampled trace."""
    cfg, params = model
    a = _reqs(cfg, (5, 11, 17), max_new=5)
    _engine(cfg, params, temperature=1.0).generate(a, seed=7)
    b = _reqs(cfg, (5, 11, 17), max_new=5)
    _paged(cfg, params, temperature=1.0).generate(b, seed=7)
    assert [r.generated for r in a] == [r.generated for r in b]


def test_paged_bitstopper_decode_greedy_parity(model):
    """The sparse path through the paged cache: the Sq=1 BESF decode walks
    the block-table view and must still follow the dense greedy path."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfgb.vocab, 9, dtype=np.int32)
    req = Request(prompt=prompt, max_new_tokens=5)
    _paged(cfgb, params).generate([req], seed=0)

    seq = np.concatenate([prompt, np.asarray(req.generated[:-1], np.int32)])
    logits, _, _ = T.forward(params, jnp.asarray(seq)[None],
                             cfg.replace(attn_impl="xla"))
    greedy = [int(t) for t in
              np.asarray(jnp.argmax(logits[0], -1))[len(prompt) - 1:]]
    assert req.generated == greedy


def test_paged_chunked_prefill_invariance(model):
    """Chunk size must not change served tokens on the dense path, and a
    long prompt must actually take several prefill ticks."""
    cfg, params = model
    outs, chunks = [], []
    for chunk in (8, 16, 32):
        eng = _paged(cfg, params, prefill_chunk=chunk)
        req = _reqs(cfg, (37,), max_new=4)[0]
        eng.generate([req], seed=0)
        outs.append(req.generated)
        chunks.append(eng.counters["prefill_chunks"])
    assert outs[0] == outs[1] == outs[2], outs
    assert chunks[0] == 5                     # ceil(37 / 8)


def test_paged_long_generation_beyond_max_len(model):
    """Admission is bounded by pool capacity, not max_len: a request whose
    prompt + max_new_tokens exceed max_len serves once the table/pool
    allow it (the contiguous engine must still reject it)."""
    cfg, params = model
    with pytest.raises(ValueError):
        _engine(cfg, params, max_len=16).submit(
            Request(prompt=np.zeros(10, np.int32), max_new_tokens=20))

    eng = _paged(cfg, params, max_len=16, max_blocks_per_req=8,
                 pool_blocks=17)
    req = _reqs(cfg, (10,), max_new=20)[0]
    eng.generate([req], seed=0)
    assert len(req.generated) == 20
    # ...but a request that cannot ever fit is rejected up front.
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(10, np.int32),
                           max_new_tokens=200))


def test_paged_eviction_returns_all_blocks(model):
    """EOS/finish eviction drains every table reference and reservation:
    after the trace completes the pool is back to full capacity."""
    cfg, params = model
    eng = _paged(cfg, params, max_slots=2, prefix_sharing=False)
    eng.generate(_reqs(cfg, (5, 11, 17, 9, 13), max_new=4), seed=0)
    assert all(s is None for s in eng.slots)
    assert eng.pool.live_blocks() == 0
    assert eng.pool.available() == eng.pool.capacity
    assert (eng.table == 0).all()


def test_paged_recycled_blocks_no_stale_kv(model):
    """A request admitted onto recycled physical blocks must not read the
    previous owner's KV: output equals a fresh-engine run bit for bit.
    The pool is sized so the second batch MUST reuse the first's blocks."""
    cfg, params = model
    # 2 slots, <=2 blocks per request, null block -> 5-block pool is snug.
    eng = _paged(cfg, params, max_slots=2, page_size=8, pool_blocks=5,
                 prefix_sharing=False)
    eng.generate(_reqs(cfg, (12, 9), max_new=4, seed=3), seed=0)
    assert eng.pool.alloc_count >= 4
    reused = _reqs(cfg, (11, 7), max_new=4, seed=4)
    eng.generate(reused, seed=0)

    fresh = _reqs(cfg, (11, 7), max_new=4, seed=4)
    _paged(cfg, params, max_slots=2, page_size=8, pool_blocks=5,
           prefix_sharing=False).generate(fresh, seed=0)
    assert [r.generated for r in reused] == [r.generated for r in fresh]


def test_paged_admission_blocks_on_pool_capacity(model):
    """A free slot is not enough: the head of line waits until evictions
    return blocks, then serves — and still matches an uncontended run."""
    cfg, params = model
    # Each request needs 2 blocks (12+4-1 tokens, page 8); capacity 3, so
    # only one request fits at a time even though 2 slots are free.
    eng = _paged(cfg, params, max_slots=2, page_size=8, pool_blocks=4,
                 prefix_sharing=False)
    tight = _reqs(cfg, (12, 12, 12), max_new=4, seed=6)
    eng.generate(tight, seed=0)
    assert all(len(r.generated) == 4 for r in tight)
    assert eng.pool.available() == eng.pool.capacity

    for i in range(3):
        alone = _reqs(cfg, (12, 12, 12), max_new=4, seed=6)[i]
        _paged(cfg, params, max_slots=2, page_size=8,
               prefix_sharing=False).generate([alone], seed=0)
        assert alone.generated == tight[i].generated, f"request {i} differs"


def test_paged_prefix_sharing_bitident_and_saves_blocks(model):
    """Requests with a common system prompt: shared serving produces
    bit-identical tokens to unshared serving, actually hits the prefix
    cache, and keeps fewer blocks live."""
    cfg, params = model
    sys_prompt = np.random.default_rng(42).integers(
        0, cfg.vocab, 24, dtype=np.int32)

    def reqs(seed=1):
        r = np.random.default_rng(seed)
        return [Request(prompt=np.concatenate(
                            [sys_prompt,
                             r.integers(0, cfg.vocab, L, dtype=np.int32)]),
                        max_new_tokens=4)
                for L in (3, 7, 5, 9)]

    es = _paged(cfg, params, max_slots=2)
    eu = _paged(cfg, params, max_slots=2, prefix_sharing=False)
    # Publish the system prompt once (steady-state serving), then measure
    # the batch: every request should map the shared blocks.
    for eng in (es, eu):
        eng.generate([Request(prompt=sys_prompt.copy(), max_new_tokens=1)],
                     seed=0)
        eng.pool.peak_live_blocks = 0
    shared = reqs()
    es.generate(shared, seed=0)
    unshared = reqs()
    eu.generate(unshared, seed=0)

    assert [r.generated for r in shared] == [r.generated for r in unshared]
    assert es.counters["prefix_hit_tokens"] >= 24 * 3
    assert es.pool.peak_live_blocks < eu.pool.peak_live_blocks
    assert es.kv_bytes_resident() < eu.kv_bytes_resident()
    # shared blocks are refcounted back to zero at the end
    assert es.pool.live_blocks() == 0


def _fused_vs_fallback(cfg, params, make_reqs, seed=0, **kw):
    """Serve the same trace through the fused Pallas kernel (interpret on
    CPU) and the pure-JAX gather fallback; return both token lists."""
    outs = []
    for fused in (True, False):
        eng = _paged(cfg, params, fused_decode=fused, **kw)
        reqs = make_reqs()
        eng.generate(reqs, seed=seed)
        outs.append([r.generated for r in reqs])
    return outs


def test_fused_decode_matches_gather_fallback_greedy(model):
    """Acceptance: flipping ServeConfig.fused_decode never changes served
    tokens — the kernel is bit-identical to the paged oracle."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    fused, fallback = _fused_vs_fallback(
        cfgb, params, lambda: _reqs(cfgb, (5, 11, 17, 9)))
    assert fused == fallback


def test_fused_decode_matches_gather_fallback_sampled(model):
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.6))
    fused, fallback = _fused_vs_fallback(
        cfgb, params, lambda: _reqs(cfgb, (5, 11, 17), max_new=5),
        seed=7, temperature=1.0)
    assert fused == fallback


def test_fused_decode_matches_fallback_shared_prefix_and_recycled(model):
    """The hard pool states: refcount>1 prefix blocks mapped by several
    tables at once, and a pool snug enough that physical blocks recycle
    mid-trace — the fused walk must still match the fallback token for
    token."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    sys_prompt = np.random.default_rng(42).integers(
        0, cfgb.vocab, 16, dtype=np.int32)

    def reqs():
        r = np.random.default_rng(9)
        return [Request(prompt=np.concatenate(
                            [sys_prompt,
                             r.integers(0, cfgb.vocab, L, dtype=np.int32)]),
                        max_new_tokens=4)
                for L in (3, 7, 5, 9, 6)]

    # pool snug: 5 requests x ~4 blocks, 2 slots, 9 allocatable blocks
    fused, fallback = _fused_vs_fallback(cfgb, params, reqs,
                                         pool_blocks=10)
    assert fused == fallback
    # and the whole thing still follows the dense greedy path per request
    for i, toks in enumerate(fused):
        assert len(toks) == 4


def test_kv_bytes_resident_counts_plane_pool(model):
    """Honest memory accounting: with the fused kernel on, every live
    block also carries its packed bit-plane pool (bits x Hkv x D bits per
    token) plus the static amax scale state — resident bytes must reflect
    it, not just the f32 K/V rows."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    engines = {}
    for fused in (True, False):
        eng = _paged(cfgb, params, fused_decode=fused)
        reqs = _reqs(cfgb, (9, 14), max_new=4)
        eng.generate(reqs, seed=0)
        engines[fused] = eng
    assert (engines[True].pool.peak_live_blocks
            == engines[False].pool.peak_live_blocks)
    with_planes = engines[True].kv_bytes_resident()
    without = engines[False].kv_bytes_resident()
    assert with_planes > without
    # the gap is exactly the plane pool: bits/8 bytes per (token, kv-head,
    # dim) per BitStopper layer, over peak live tokens
    acfg = cfgb.attn_config(False)
    per_tok_planes = (cfgb.n_layers * acfg.bitstopper.bits
                      * acfg.n_kv_heads * acfg.head_dim) // 8
    blocks = engines[True].pool.peak_live_blocks
    page = engines[True].scfg.page_size
    assert with_planes - without == blocks * page * per_tok_planes
    # amax scale state is charged on both bitstopper engines
    dense = _paged(cfg, params)
    dense.generate(_reqs(cfg, (9, 14), max_new=4), seed=0)
    assert dense.pool.peak_live_blocks == blocks
    amax_bytes = cfgb.n_layers * 2 * acfg.n_kv_heads * 4
    assert without - dense.kv_bytes_resident() == amax_bytes


def test_fused_decode_page_size_validation():
    with pytest.raises(ValueError):
        ServeConfig(fused_decode=True, page_size=12)
    # planeless pools (page % 8 != 0) still serve through the dense gather
    scfg = ServeConfig(page_size=12)
    assert scfg.fused_decode is None


def test_paged_bitstopper_window_layer_fused():
    """local_attn layers decode through the paged path with window
    masking (position-masked, no ring); fused and fallback must agree
    there too."""
    from repro.models.config import BlockSpec, ModelConfig
    cfgw = ModelConfig(
        name="win-test", family="dense", d_model=64, vocab=256,
        segments=(((BlockSpec("local_attn"),), 2),),
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, window=8,
        attn_impl="bitstopper_xla", bitstopper=BitStopperConfig(alpha=0.8))
    params = T.init_model(jax.random.PRNGKey(1), cfgw)
    fused, fallback = _fused_vs_fallback(
        cfgw, params, lambda: _reqs(cfgw, (9, 13), max_new=4))
    assert fused == fallback


# ---------------------------------------------------------------------------
# oversubscription: victim preemption + lossless resume
# ---------------------------------------------------------------------------

# Pool sized so worst-case reservations of the three requests (4 blocks
# each at page 8) cannot coexist, but their *actual* footprints can — the
# shape oversubscription exists for.  max_new is large enough that decode
# outgrows the prompt-sized reservations and a mid-decode claim must
# preempt.
_OS = dict(max_slots=3, page_size=8, pool_blocks=10, oversubscribe=True)


def _os_reqs(cfg, max_new=16, seed=0):
    return _reqs(cfg, (12, 9, 11), max_new=max_new, seed=seed)


def test_oversubscribed_preemption_bitident_greedy(model):
    """Acceptance: an oversubscribed trace completes with >=1 observed
    preemption and its token streams are bit-identical to an uncontended
    (worst-case-reserved, ample pool) run — the preempted request resumes
    via chunked-prefill recompute without perturbing a single token."""
    cfg, params = model
    a = _os_reqs(cfg)
    _paged(cfg, params, max_slots=3).generate(a, seed=0)
    eng = _paged(cfg, params, **_OS)
    b = _os_reqs(cfg)
    eng.generate(b, seed=0)
    assert eng.counters["preemptions"] >= 1
    assert [r.generated for r in a] == [r.generated for r in b]
    assert sum(r.preemptions for r in b) == eng.counters["preemptions"]
    # full cleanup: no leaked blocks or reservations after the trace
    assert eng.pool.available() == eng.pool.capacity
    assert (eng.table == 0).all()


def test_oversubscribed_preemption_bitident_sampled(model):
    """Seeded sampling: keys are (seed, rid, token index), so preemption
    and resume cannot shift the sampled trace either."""
    cfg, params = model
    a = _os_reqs(cfg)
    _paged(cfg, params, max_slots=3, temperature=1.0).generate(a, seed=7)
    eng = _paged(cfg, params, temperature=1.0, **_OS)
    b = _os_reqs(cfg)
    eng.generate(b, seed=7)
    assert eng.counters["preemptions"] >= 1
    assert [r.generated for r in a] == [r.generated for r in b]


def test_oversubscribed_lifo_policy_bitident(model):
    """The victim-choice policy changes WHO recomputes, never WHAT is
    served."""
    cfg, params = model
    a = _os_reqs(cfg)
    _paged(cfg, params, max_slots=3).generate(a, seed=0)
    eng = _paged(cfg, params, preempt_policy="lifo", **_OS)
    b = _os_reqs(cfg)
    eng.generate(b, seed=0)
    assert eng.counters["preemptions"] >= 1
    assert [r.generated for r in a] == [r.generated for r in b]


def test_oversubscribed_prefix_sharing_resumes_shared_blocks(model):
    """With a common system prompt, preemption decrefs the shared prefix
    blocks (they stay registered) and resume re-maps them for free — and
    the served tokens still match the uncontended unshared run."""
    cfg, params = model
    sys_prompt = np.random.default_rng(42).integers(
        0, cfg.vocab, 16, dtype=np.int32)

    def reqs():
        r = np.random.default_rng(5)
        return [Request(prompt=np.concatenate(
                            [sys_prompt,
                             r.integers(0, cfg.vocab, L, dtype=np.int32)]),
                        max_new_tokens=16)
                for L in (3, 7, 5)]

    a = reqs()
    _paged(cfg, params, max_slots=3, prefix_sharing=False).generate(
        a, seed=0)
    eng = _paged(cfg, params, pool_blocks=11, max_slots=3, page_size=8,
                 oversubscribe=True)
    b = reqs()
    eng.generate(b, seed=0)
    assert eng.counters["preemptions"] >= 1
    assert eng.counters["prefix_hit_tokens"] > 0
    assert [r.generated for r in a] == [r.generated for r in b]
    assert eng.pool.available() == eng.pool.capacity


def test_oversubscribed_speculative_bitident(model):
    """Speculative decoding under oversubscription: draft blocks are never
    worth a preemption (drafts truncate instead), and the combined
    spec+preemption trace still equals plain uncontended serving."""
    cfg, params = model
    a = _os_reqs(cfg)
    _paged(cfg, params, max_slots=3).generate(a, seed=0)
    eng = _paged(cfg, params, speculative="ngram", draft_k=3, **_OS)
    b = _os_reqs(cfg)
    eng.generate(b, seed=0)
    assert eng.counters["preemptions"] >= 1
    assert [r.generated for r in a] == [r.generated for r in b]
    assert eng.pool.available() == eng.pool.capacity


def test_oversubscribed_spec_rollback_spare_capacity(model):
    """Adversarial drafter: every draft is (almost always) rejected, so
    draft-tail blocks — claimed from the admission reservation AND from
    oversubscribed spare capacity — are constantly rolled back.  Spare
    claims must free outright (no phantom reservations earmarking shared
    capacity), the pool must drain clean, and the trace stays lossless."""
    cfg, params = model

    class RepeatDrafter:
        def propose(self, ctx, k):
            return [int(ctx[-1])] * k

    a = _os_reqs(cfg, max_new=24)
    _paged(cfg, params, max_slots=3).generate(a, seed=0)
    eng = _paged(cfg, params, speculative="ngram", draft_k=6,
                 **_OS)
    eng._drafter = RepeatDrafter()
    b = _os_reqs(cfg, max_new=24)
    eng.generate(b, seed=0)
    assert [r.generated for r in a] == [r.generated for r in b]
    assert eng.counters["spec_proposed"] > eng.counters["spec_accepted"]
    assert eng.pool.available() == eng.pool.capacity
    assert eng.pool._reserved == 0


def test_oversubscribed_bitstopper_greedy_parity(model):
    """The sparse serving path preempts and resumes too: BitStopper greedy
    under an oversubscribed pool matches its own uncontended run (the
    rewritten KV rows are recomputed from the same hidden states)."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    a = _os_reqs(cfgb)
    _paged(cfgb, params, max_slots=3).generate(a, seed=0)
    eng = _paged(cfgb, params, **_OS)
    b = _os_reqs(cfgb)
    eng.generate(b, seed=0)
    assert eng.counters["preemptions"] >= 1
    assert [r.generated for r in a] == [r.generated for r in b]


def test_oversubscribe_requires_paged_engine(model):
    cfg, params = model
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(cfg, params,
                                 ServeConfig(oversubscribe=True))


def test_serve_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(max_slots=0)
    with pytest.raises(ValueError):
        ServeConfig(prefill_bucket=0)
    with pytest.raises(ValueError):
        ServeConfig(max_len=-1)
    with pytest.raises(ValueError):
        ServeConfig(page_size=0)
    with pytest.raises(ValueError):
        ServeConfig(pool_blocks=1)
    with pytest.raises(ValueError):
        ServeConfig(max_blocks_per_req=0)
    with pytest.raises(ValueError):
        ServeConfig(prefill_chunk=0)
    with pytest.raises(ValueError):
        ServeConfig(prefill_bucket=16, prefill_chunk=24)  # not a multiple
    with pytest.raises(ValueError):
        ServeConfig(temperature=-0.5)
    with pytest.raises(ValueError):
        ServeConfig(cache_dtype="float16")
    with pytest.raises(ValueError):
        ServeConfig(preempt_policy="roulette")
    # valid construction resolves defaults
    scfg = ServeConfig(max_len=64, page_size=16)
    assert scfg.resolved_max_blocks() == 4
    assert scfg.resolved_pool_blocks() == 1 + 4 * 4
    assert scfg.resolved_chunk() % scfg.prefill_bucket == 0


# ---------------------------------------------------------------------------
# deterministic sampling
# ---------------------------------------------------------------------------


def test_sampling_deterministic_under_seed(model):
    cfg, params = model
    outs = []
    for _ in range(2):
        reqs = _reqs(cfg, (5, 11), max_new=6)
        _engine(cfg, params, temperature=1.0).generate(reqs, seed=7)
        outs.append([r.generated for r in reqs])
    assert outs[0] == outs[1], "same seed must reproduce every token"

    reqs = _reqs(cfg, (5, 11), max_new=6)
    _engine(cfg, params, temperature=1.0).generate(reqs, seed=8)
    assert [r.generated for r in reqs] != outs[0], \
        "different seed should change sampled tokens"


def test_greedy_ignores_seed(model):
    cfg, params = model
    a = _reqs(cfg, (9,), max_new=5)
    b = _reqs(cfg, (9,), max_new=5)
    _engine(cfg, params).generate(a, seed=0)
    _engine(cfg, params).generate(b, seed=123)
    assert a[0].generated == b[0].generated


def test_static_engine_deterministic(model):
    cfg, params = model
    scfg = ServeConfig(max_len=64, temperature=1.0)
    outs = []
    for _ in range(2):
        reqs = _reqs(cfg, (8, 8, 12), max_new=5)
        StaticBucketEngine(cfg, params, scfg).generate(reqs, seed=3)
        outs.append([r.generated for r in reqs])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# per-slot cache + decode-specialized BESF internals
# ---------------------------------------------------------------------------


def test_per_slot_cache_layout(model):
    cfg, params = model
    caches = T.init_caches(cfg, 3, 32, per_slot=True)
    leaf = caches["seg0"]
    leaf = leaf[0] if isinstance(leaf, list) else \
        jax.tree_util.tree_map(lambda a: a[0], leaf)
    c = leaf["b0"]
    assert c["pos"].shape == (3, 32) and c["length"].shape == (3,)
    assert bool((c["pos"] >= 2 ** 30).all())


def test_per_slot_rejects_non_attention():
    cfg = reduced_config("mamba2-130m")
    with pytest.raises(NotImplementedError):
        T.init_caches(cfg, 2, 16, per_slot=True)


def test_besf_decode_bitexact_vs_reference():
    """The Sq=1 fast path (fused plane contraction + elementwise LATS)
    must reproduce the faithful per-round reference bit for bit —
    survivors, planes fetched, scores, and output."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(64, 16)) * 2, jnp.float32)
    v = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    mask = jnp.asarray(rng.random(64) > 0.2)[None]
    for alpha in (0.2, 0.6, 1.0):
        cfg = BitStopperConfig(alpha=alpha)
        ref = besf_attention(q, k, v, cfg, mask=mask)
        dec = besf_attention_decode(q, k, v, cfg, mask=mask)
        np.testing.assert_array_equal(np.asarray(ref.stats.survivors),
                                      np.asarray(dec.stats.survivors))
        np.testing.assert_array_equal(np.asarray(ref.stats.planes_fetched),
                                      np.asarray(dec.stats.planes_fetched))
        np.testing.assert_array_equal(np.asarray(ref.scores),
                                      np.asarray(dec.scores))
        np.testing.assert_array_equal(np.asarray(ref.out),
                                      np.asarray(dec.out))


def test_besf_decode_batched_per_example_masks():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(3, 4, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(3, 4, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(3, 4, 32, 16)), jnp.float32)
    m = jnp.asarray(rng.random((3, 1, 1, 32)) > 0.3)
    cfg = BitStopperConfig(alpha=0.6)
    ref = besf_attention(q, k, v, cfg, mask=m)
    dec = besf_attention_decode(q, k, v, cfg, mask=m)
    np.testing.assert_array_equal(np.asarray(ref.out), np.asarray(dec.out))


# ---------------------------------------------------------------------------
# served-traffic accounting
# ---------------------------------------------------------------------------


def test_sparsity_report_per_request(model):
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla")
    eng = _engine(cfgb, params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfgb.vocab, L, dtype=np.int32)
               for L in (8, 16, 24)]
    rep = eng.sparsity_report(prompts)
    assert len(rep["per_request"]) == 3
    assert [r["prompt_len"] for r in rep["per_request"]] == [8, 16, 24]
    for r in rep["per_request"]:
        assert 0.0 < r["plane_fraction"] <= 1.0
        assert 0.0 < r["survivor_fraction"] <= 1.0
    # aggregate is the block-weighted mean (long prompts carry more units)
    w = np.array([r["n_blocks"] for r in rep["per_request"]], float)
    v = np.array([r["plane_fraction"] for r in rep["per_request"]])
    assert rep["plane_fraction"] == pytest.approx((v * w).sum() / w.sum())
