"""Serving-engine tests: continuous batching, decode/prefill parity,
deterministic sampling, the per-slot KV cache, and the decode-specialized
BitStopper path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig, besf_attention, \
    besf_attention_decode
from repro.models import transformer as T
from repro.serving import (
    ContinuousBatchingEngine,
    Request,
    ServeConfig,
    StaticBucketEngine,
)


@pytest.fixture(scope="module")
def model():
    cfg = reduced_config("stablelm-1.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, lens, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, L, dtype=np.int32),
                    max_new_tokens=max_new)
            for L in lens]


def _engine(cfg, params, **kw):
    scfg = ServeConfig(max_len=kw.pop("max_len", 64),
                       max_slots=kw.pop("max_slots", 2),
                       prefill_bucket=kw.pop("prefill_bucket", 8), **kw)
    return ContinuousBatchingEngine(cfg, params, scfg)


# ---------------------------------------------------------------------------
# decode/prefill parity through the continuous-batching engine
# ---------------------------------------------------------------------------


def test_decode_matches_prefill_bitexact_xla(model):
    """A sequence decoded token-by-token through the engine must follow the
    same greedy path as a one-shot (cache-free) prefill forward pass."""
    cfg, params = model
    eng = _engine(cfg, params)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 9, dtype=np.int32)
    req = Request(prompt=prompt, max_new_tokens=8)
    eng.generate([req], seed=0)

    seq = np.concatenate([prompt, np.asarray(req.generated[:-1], np.int32)])
    logits, _, _ = T.forward(params, jnp.asarray(seq)[None], cfg)
    greedy = np.asarray(jnp.argmax(logits[0], -1))[len(prompt) - 1:]
    assert req.generated == [int(t) for t in greedy]


def test_decode_matches_prefill_bitstopper(model):
    """Same parity on the sparse path, within tolerance: block-granular
    prefill and the per-token decode fast path may disagree on pruned
    (near-zero-mass) candidates, so compare next-token logits loosely and
    the greedy path exactly."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    eng = _engine(cfgb, params)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfgb.vocab, 9, dtype=np.int32)
    req = Request(prompt=prompt, max_new_tokens=5)
    eng.generate([req], seed=0)
    assert len(req.generated) == 5

    # Dense one-shot forward: the sparse serve must track it closely.
    seq = np.concatenate([prompt, np.asarray(req.generated[:-1], np.int32)])
    logits, _, _ = T.forward(params, jnp.asarray(seq)[None],
                             cfg.replace(attn_impl="xla"))
    greedy = [int(t) for t in
              np.asarray(jnp.argmax(logits[0], -1))[len(prompt) - 1:]]
    assert req.generated == greedy


# ---------------------------------------------------------------------------
# continuous batching semantics
# ---------------------------------------------------------------------------


def test_mixed_lengths_isolated_slots(model):
    """Requests of different lengths served together (queue > slots) must
    each produce exactly what they produce when served alone — slot caches
    are isolated and masks respect per-slot fill levels."""
    cfg, params = model
    lens = (5, 11, 17)
    together = _reqs(cfg, lens)
    _engine(cfg, params).generate(together, seed=0)
    assert all(len(r.generated) == 6 for r in together)

    for i, L in enumerate(lens):
        alone = _reqs(cfg, lens)[i]          # same prompts (same seed)
        _engine(cfg, params).generate([alone], seed=0)
        assert alone.generated == together[i].generated, f"slot {i} differs"


def test_queue_admission_and_eviction(model):
    """More requests than slots: all finish, and the engine interleaves
    prefill with in-flight decode (scheduler actually continuous)."""
    cfg, params = model
    reqs = _reqs(cfg, (5, 7, 9, 11, 13), max_new=4)
    eng = _engine(cfg, params, max_slots=2)
    eng.generate(reqs, seed=0)
    assert all(len(r.generated) == 4 for r in reqs)
    assert eng.counters["requests_finished"] == 5
    # with 2 slots and 5 requests, at least one admission must have
    # happened after decoding started (interleaving, not phases)
    assert max(r.admitted_step for r in reqs) > 0
    assert all(r is None for r in eng.slots)


def test_eos_eviction(model):
    cfg, params = model
    eng = _engine(cfg, params)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 9, dtype=np.int32)
    free_run = Request(prompt=prompt.copy(), max_new_tokens=8)
    eng.generate([free_run], seed=0)
    eos = free_run.generated[2]              # force a stop at step 3

    eng2 = ContinuousBatchingEngine(cfg, params, ServeConfig(
        max_len=64, max_slots=2, prefill_bucket=8, eos_id=int(eos)))
    stopped = Request(prompt=prompt.copy(), max_new_tokens=8)
    eng2.generate([stopped], seed=0)
    assert stopped.generated == free_run.generated[:3]


def test_max_len_rejection(model):
    cfg, params = model
    eng = _engine(cfg, params, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(12, np.int32), max_new_tokens=8))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.zeros(4, np.int32), max_new_tokens=0))


def test_prefill_bucket_invariance(model):
    """Bucket padding must not change served tokens: pad rows are zeroed
    before attention, so the BitStopper per-tensor quant scale (and hence
    every threshold decision) is independent of the bucket size."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.6))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfgb.vocab, 9, dtype=np.int32)
    outs = []
    for bucket in (1, 8, 16):
        eng = _engine(cfgb, params, prefill_bucket=bucket)
        req = Request(prompt=prompt.copy(), max_new_tokens=5)
        eng.generate([req], seed=0)
        outs.append(req.generated)
    assert outs[0] == outs[1] == outs[2], outs


# ---------------------------------------------------------------------------
# deterministic sampling
# ---------------------------------------------------------------------------


def test_sampling_deterministic_under_seed(model):
    cfg, params = model
    outs = []
    for _ in range(2):
        reqs = _reqs(cfg, (5, 11), max_new=6)
        _engine(cfg, params, temperature=1.0).generate(reqs, seed=7)
        outs.append([r.generated for r in reqs])
    assert outs[0] == outs[1], "same seed must reproduce every token"

    reqs = _reqs(cfg, (5, 11), max_new=6)
    _engine(cfg, params, temperature=1.0).generate(reqs, seed=8)
    assert [r.generated for r in reqs] != outs[0], \
        "different seed should change sampled tokens"


def test_greedy_ignores_seed(model):
    cfg, params = model
    a = _reqs(cfg, (9,), max_new=5)
    b = _reqs(cfg, (9,), max_new=5)
    _engine(cfg, params).generate(a, seed=0)
    _engine(cfg, params).generate(b, seed=123)
    assert a[0].generated == b[0].generated


def test_static_engine_deterministic(model):
    cfg, params = model
    scfg = ServeConfig(max_len=64, temperature=1.0)
    outs = []
    for _ in range(2):
        reqs = _reqs(cfg, (8, 8, 12), max_new=5)
        StaticBucketEngine(cfg, params, scfg).generate(reqs, seed=3)
        outs.append([r.generated for r in reqs])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# per-slot cache + decode-specialized BESF internals
# ---------------------------------------------------------------------------


def test_per_slot_cache_layout(model):
    cfg, params = model
    caches = T.init_caches(cfg, 3, 32, per_slot=True)
    leaf = caches["seg0"]
    leaf = leaf[0] if isinstance(leaf, list) else \
        jax.tree_util.tree_map(lambda a: a[0], leaf)
    c = leaf["b0"]
    assert c["pos"].shape == (3, 32) and c["length"].shape == (3,)
    assert bool((c["pos"] >= 2 ** 30).all())


def test_per_slot_rejects_non_attention():
    cfg = reduced_config("mamba2-130m")
    with pytest.raises(NotImplementedError):
        T.init_caches(cfg, 2, 16, per_slot=True)


def test_besf_decode_bitexact_vs_reference():
    """The Sq=1 fast path (fused plane contraction + elementwise LATS)
    must reproduce the faithful per-round reference bit for bit —
    survivors, planes fetched, scores, and output."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(64, 16)) * 2, jnp.float32)
    v = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    mask = jnp.asarray(rng.random(64) > 0.2)[None]
    for alpha in (0.2, 0.6, 1.0):
        cfg = BitStopperConfig(alpha=alpha)
        ref = besf_attention(q, k, v, cfg, mask=mask)
        dec = besf_attention_decode(q, k, v, cfg, mask=mask)
        np.testing.assert_array_equal(np.asarray(ref.stats.survivors),
                                      np.asarray(dec.stats.survivors))
        np.testing.assert_array_equal(np.asarray(ref.stats.planes_fetched),
                                      np.asarray(dec.stats.planes_fetched))
        np.testing.assert_array_equal(np.asarray(ref.scores),
                                      np.asarray(dec.scores))
        np.testing.assert_array_equal(np.asarray(ref.out),
                                      np.asarray(dec.out))


def test_besf_decode_batched_per_example_masks():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(3, 4, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(3, 4, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(3, 4, 32, 16)), jnp.float32)
    m = jnp.asarray(rng.random((3, 1, 1, 32)) > 0.3)
    cfg = BitStopperConfig(alpha=0.6)
    ref = besf_attention(q, k, v, cfg, mask=m)
    dec = besf_attention_decode(q, k, v, cfg, mask=m)
    np.testing.assert_array_equal(np.asarray(ref.out), np.asarray(dec.out))


# ---------------------------------------------------------------------------
# served-traffic accounting
# ---------------------------------------------------------------------------


def test_sparsity_report_per_request(model):
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla")
    eng = _engine(cfgb, params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfgb.vocab, L, dtype=np.int32)
               for L in (8, 16, 24)]
    rep = eng.sparsity_report(prompts)
    assert len(rep["per_request"]) == 3
    assert [r["prompt_len"] for r in rep["per_request"]] == [8, 16, 24]
    for r in rep["per_request"]:
        assert 0.0 < r["plane_fraction"] <= 1.0
        assert 0.0 < r["survivor_fraction"] <= 1.0
    # aggregate is the block-weighted mean (long prompts carry more units)
    w = np.array([r["n_blocks"] for r in rep["per_request"]], float)
    v = np.array([r["plane_fraction"] for r in rep["per_request"]])
    assert rep["plane_fraction"] == pytest.approx((v * w).sum() / w.sum())
