"""`hypothesis` compatibility shim for the property tests.

When `hypothesis` is installed it is used verbatim.  When it is absent
(the CPU CI container deliberately carries only jax/numpy/pytest) a
minimal vendored fallback provides the same decorator surface —
``given`` / ``settings`` / ``strategies`` — backed by a deterministic
per-test PRNG.  The property tests then still *run* (a fixed number of
seeded examples per test) instead of dying at collection with
``ModuleNotFoundError: No module named 'hypothesis'``.

The fallback implements exactly the strategy combinators this suite
uses: ``integers``, ``floats``, ``sampled_from``, ``lists`` and
``composite``.  It does no shrinking and no example databases — it is a
seeded example generator, not a reimplementation of hypothesis.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        """A strategy is just a seeded-draw function."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                def draw_fn(rng):
                    return fn(lambda s: s.example(rng), *args, **kwargs)

                return _Strategy(draw_fn)

            return make

    strategies = _StrategiesModule()

    class settings:  # noqa: N801 - mirrors the hypothesis name
        def __init__(self, max_examples=_DEFAULT_EXAMPLES, deadline=None,
                     **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._max_examples = self.max_examples
            return fn

    def given(*strats, **kwstrats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                # Deterministic per-test stream: failures reproduce.
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for _ in range(n):
                    drawn = tuple(s.example(rng) for s in strats)
                    kdrawn = {k: s.example(rng) for k, s in kwstrats.items()}
                    fn(*args, *drawn, **kwargs, **kdrawn)

            # Hide the strategy-supplied parameters from pytest, which would
            # otherwise treat them as (missing) fixtures.  Positional
            # strategies fill the test's trailing parameters, as in
            # hypothesis.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if strats:
                params = params[:-len(strats)]
            params = [p for p in params if p.name not in kwstrats]
            wrapper.__signature__ = sig.replace(parameters=params)
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            return wrapper

        return deco
