"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.models import transformer as T

ARCHS = list_archs()


def _inputs(cfg, batch=2, seq=16):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    return tokens


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = reduced_config(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = _inputs(cfg)
    if cfg.frontend == "vision":
        from repro.models.frontend import vision_frontend
        patches = jax.random.normal(jax.random.PRNGKey(2), (2, 4, cfg.d_model))
        embeds = vision_frontend(params, tokens, patches, cfg)
        logits, _, aux = T.forward(params, tokens, cfg, embeds=embeds)
        assert logits.shape == (2, 16 + 4, cfg.vocab)
    else:
        logits, _, aux = T.forward(params, tokens, cfg)
        assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    """One SGD step must produce finite grads for every param."""
    cfg = reduced_config(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = _inputs(cfg)

    def loss_fn(p):
        logits, _, aux = T.forward(p, tokens, cfg)
        tgt = jnp.roll(tokens, -1, axis=1)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1).mean()
        return nll + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: NaN grads"
    # At least one gradient must be nonzero (the graph is connected).
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "granite-20b",
                                  "qwen2-moe-a2.7b", "deepseek-v3-671b",
                                  "recurrentgemma-2b", "mamba2-130m"])
def test_decode_smoke(arch):
    """Prefill + 3 decode steps; cache-backed logits stay finite and match
    the full forward pass at the last position."""
    cfg = reduced_config(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = _inputs(cfg, batch=2, seq=12)
    caches = T.init_caches(cfg, 2, 32)
    lf, _, _ = T.forward(params, tokens, cfg)
    x = None
    for t in range(12):
        x, caches, _ = T.forward(params, tokens[:, t:t + 1], cfg,
                                 caches=caches, positions=jnp.arange(t, t + 1))
    np.testing.assert_allclose(np.asarray(x[:, 0]), np.asarray(lf[:, 11]),
                               atol=2e-3, rtol=2e-3)


def test_full_configs_construct():
    """The FULL assigned configs must at least construct and report sane
    layer counts (they are lowered only via the dry-run)."""
    expect_layers = {
        "musicgen-medium": 48, "stablelm-12b": 40, "stablelm-1.6b": 24,
        "qwen2.5-14b": 48, "granite-20b": 52, "recurrentgemma-2b": 26,
        "mamba2-130m": 24, "qwen2-moe-a2.7b": 24, "deepseek-v3-671b": 61,
        "llava-next-34b": 60, "paper-opt1.3b": 24,
    }
    for arch, n in expect_layers.items():
        cfg = get_config(arch)
        assert cfg.n_layers == n, (arch, cfg.n_layers, n)


def test_mtp_head():
    cfg = reduced_config("deepseek-v3-671b")
    assert cfg.mtp
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = _inputs(cfg)
    logits, _, _ = T.forward(params, tokens, cfg)
    # MTP needs hidden states: recompute trunk then the extra head.
    from repro.models import layers as L
    x = L.embed(params["embed"], tokens).astype(cfg.activation_dtype)
    from repro.models.transformer import run_segments, mtp_logits
    h, _, _ = run_segments(params, x, jnp.arange(16), cfg)
    ml = mtp_logits(params, tokens, h, cfg, jnp.arange(16))
    assert ml.shape == logits.shape
    assert not bool(jnp.isnan(ml).any())
