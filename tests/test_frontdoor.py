"""Async front-door tests: the JetStream-style engine API
(prefill/insert/generate_step), async token streaming, SLA tick mapping,
prefill/decode disaggregation, and graceful shutdown.

The load-bearing invariant everywhere: async streaming, fairness-aware
admission, and disaggregated handoff are *scheduling* features — served
tokens are bit-identical to the synchronous ``PagedEngine`` trace on
every path, because rids pin sampling keys at arrival and an inserted
prefix is indistinguishable from a post-preemption resume."""

import asyncio

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.besf import BitStopperConfig
from repro.models import transformer as T
from repro.runtime import ManualClock
from repro.serving import (
    InsufficientBlocks,
    PagedEngine,
    Request,
    ServeConfig,
)
from repro.serving.frontdoor import (
    AsyncFrontDoor,
    DisaggController,
    SlaMapper,
    TransferQueue,
)


@pytest.fixture(scope="module")
def model():
    cfg = reduced_config("stablelm-1.6b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _paged(cfg, params, **kw):
    scfg = ServeConfig(max_len=kw.pop("max_len", 64),
                       max_slots=kw.pop("max_slots", 2),
                       prefill_bucket=kw.pop("prefill_bucket", 8),
                       page_size=kw.pop("page_size", 8), **kw)
    return PagedEngine(cfg, params, scfg)


def _reqs(cfg, lens, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, L, dtype=np.int32),
                    max_new_tokens=max_new)
            for L in lens]


def _sync_ref(cfg, params, lens, max_new=5, seed=0, **kw):
    reqs = _reqs(cfg, lens, max_new=max_new)
    _paged(cfg, params, **kw).generate(reqs, seed=seed)
    return [r.generated for r in reqs]


def _stream_all(door, rids):
    """Drive the door to drain completion; return each rid's streamed
    tokens (in stream order, the bit-identity object under test)."""
    async def go():
        task = asyncio.create_task(door.run())

        async def collect(rid):
            return [tok async for tok in door.stream(rid)]

        gathered = asyncio.gather(*(collect(r) for r in rids))
        door.shutdown("drain")
        toks = await gathered
        await task
        return toks

    return asyncio.run(go())


def _door_trace(cfg, params, lens, max_new=5, seed=0, **kw):
    door = AsyncFrontDoor(_paged(cfg, params, **kw), seed=seed)
    door.start()
    rng = np.random.default_rng(0)
    rids = [door.submit(rng.integers(0, cfg.vocab, L, dtype=np.int32),
                        max_new_tokens=max_new)
            for L in lens]
    return _stream_all(door, rids), door


# ---------------------------------------------------------------------------
# engine API: prefill -> insert -> generate_step
# ---------------------------------------------------------------------------


def test_engine_api_bitident_to_generate(model):
    """Acceptance: driving the engine through the JetStream-style surface
    (prefill each request to a Prefix, insert into a free slot, loop
    generate_step) reproduces generate()'s tokens bit-exactly."""
    cfg, params = model
    ref = _sync_ref(cfg, params, (5, 9, 7), max_new=5)

    eng = _paged(cfg, params)
    eng.begin(0)
    reqs = _reqs(cfg, (5, 9, 7))
    out = {}
    pending = list(reqs)
    live = 0
    while pending or live:
        while pending and eng.free_slots():
            req = pending[0]
            prefix = eng.prefill(req)
            eng.insert(prefix, eng.free_slots()[0])
            # JetStream semantics: the FIRST token comes back with the
            # prefill result, before any generate_step
            out[req.rid] = list(req.generated)
            pending.pop(0)
            live += 1
        for ev in eng.generate_step():
            out.setdefault(ev["rid"], []).extend(ev["tokens"])
            if ev["finished"]:
                live -= 1
    assert [out[r.rid] for r in reqs] == ref
    assert [r.generated for r in reqs] == ref
    assert eng.counters["prefixes_prefilled"] == 3
    assert eng.counters["prefixes_inserted"] == 3
    # clean pool after the trace: no leaked blocks or reservations
    assert eng.pool.available() == eng.pool.capacity


def test_insert_into_occupied_slot_rejected(model):
    """Mandated: inserting a prefix into a slot that is still serving a
    live request must raise, not clobber the resident block table."""
    cfg, params = model
    eng = _paged(cfg, params)
    eng.begin(0)
    r0, r1 = _reqs(cfg, (5, 7), max_new=8)
    eng.insert(eng.prefill(r0), 0)
    with pytest.raises(RuntimeError, match="occupied slot"):
        eng.insert(eng.prefill(r1), 0)
    # the resident request is untouched and still completes
    while eng.pending():
        eng.step()
    assert len(r0.generated) == 8


# ---------------------------------------------------------------------------
# async streaming: bit-identity to the synchronous engine on every path
# ---------------------------------------------------------------------------


def test_streamed_bitident_xla_seeded(model):
    """Seeded sampling through the door: streamed tokens == synchronous
    trace (keys are (seed, rid, n); admission order can't move them)."""
    cfg, params = model
    kw = dict(temperature=0.9)
    ref = _sync_ref(cfg, params, (5, 9, 7), seed=7, **kw)
    toks, door = _door_trace(cfg, params, (5, 9, 7), seed=7, **kw)
    assert toks == ref
    assert door.admission_log == [0, 1, 2]


@pytest.mark.parametrize("fused", [True, False])
def test_streamed_bitident_bitstopper(model, fused):
    """BitStopper decode (fused Pallas kernel and gather fallback):
    greedy streamed tokens == the synchronous trace."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    kw = dict(fused_decode=fused)
    ref = _sync_ref(cfgb, params, (5, 11, 7), **kw)
    toks, _ = _door_trace(cfgb, params, (5, 11, 7), **kw)
    assert toks == ref


def test_streamed_bitident_speculative(model):
    """Speculative decoding behind the door: lossless (tokens never
    change), and the stream commits multi-token bursts per tick."""
    cfg, params = model
    ref = _sync_ref(cfg, params, (12, 9), max_new=8)
    toks, _ = _door_trace(cfg, params, (12, 9), max_new=8,
                          speculative="ngram", draft_k=3)
    assert toks == ref


def test_streamed_bitident_oversubscribed_seeded(model):
    """Oversubscribed pool + seeded sampling through the door: preemption
    and resume underneath the streams never perturbs a token."""
    cfg, params = model
    kw = dict(max_slots=3, pool_blocks=10, oversubscribe=True,
              temperature=1.0)
    ref = _sync_ref(cfg, params, (12, 9, 11), max_new=16, seed=7,
                    max_slots=3, temperature=1.0)
    door = AsyncFrontDoor(
        _paged(cfg, params, **kw), seed=7)
    door.start()
    rng = np.random.default_rng(0)
    rids = [door.submit(rng.integers(0, cfg.vocab, L, dtype=np.int32),
                        max_new_tokens=16)
            for L in (12, 9, 11)]
    toks = _stream_all(door, rids)
    assert toks == ref
    assert door.backend.counters["preemptions"] >= 1


def test_fairness_admission_order(model):
    """Admission round-robins one per non-empty SLO class (strict first),
    so a besteffort backlog can't starve strict arrivals; rids stay
    arrival-ordered so reordering is observable but token-neutral."""
    cfg, params = model
    door = AsyncFrontDoor(_paged(cfg, params), seed=0)
    door.start()
    rng = np.random.default_rng(0)
    p = [rng.integers(0, cfg.vocab, L, dtype=np.int32)
         for L in (5, 9, 7, 12, 6)]
    rids = [door.submit(p[0], 3, slo="besteffort"),
            door.submit(p[1], 3, slo="besteffort"),
            door.submit(p[2], 3, slo="besteffort"),
            door.submit(p[3], 3, slo="strict"),
            door.submit(p[4], 3, slo="standard")]
    _stream_all(door, rids)
    assert rids == [0, 1, 2, 3, 4]
    assert door.admission_log == [3, 4, 0, 1, 2]


# ---------------------------------------------------------------------------
# SLA mapper: wall-clock deadlines -> engine ticks
# ---------------------------------------------------------------------------


def test_sla_quantize_rounds_up_at_granularity():
    """Deadlines quantize UP to the clock granularity: a client deadline
    is a budget, and rounding down would promise time the clock cannot
    observe.  Exact multiples stay exact (binary-exact granularity)."""
    sla = SlaMapper(granularity=0.125)
    assert sla.quantize(0.125) == 0.125          # exact multiple: unmoved
    assert sla.quantize(0.250) == 0.250
    assert sla.quantize(0.126) == 0.250          # boundary+eps: next step
    assert sla.quantize(0.1) == 0.125            # below one step: one step
    assert sla.quantize(0.3749999) == 0.375


def test_sla_ticks_for_uses_tick_estimate():
    sla = SlaMapper(granularity=0.125, default_tick_s=0.25)
    assert sla.ticks_for(0.5) == 2               # 0.5s / 0.25s per tick
    assert sla.ticks_for(0.25) == 1
    assert sla.ticks_for(0.01) == 1              # never below one tick
    # EMA tracks observed tick durations and remaps future deadlines
    for _ in range(200):
        sla.observe_tick(0.125)
    assert abs(sla.tick_estimate - 0.125) < 1e-6
    assert sla.ticks_for(0.5) == 4
    with pytest.raises(ValueError):
        sla.ticks_for(0.0)


def test_door_maps_deadline_s_to_deadline_ticks(model):
    """A deadline_s on submit lands on the engine as deadline_ticks via
    the mapper; a ManualClock that never advances keeps the default
    estimate, so the mapping is deterministic."""
    cfg, params = model
    clock = ManualClock(granularity=0.125)
    sla = SlaMapper(granularity=0.125, default_tick_s=0.25)
    door = AsyncFrontDoor(_paged(cfg, params), clock=clock, sla=sla,
                          seed=0)
    door.start()
    rng = np.random.default_rng(0)
    rid = door.submit(rng.integers(0, cfg.vocab, 5, dtype=np.int32),
                      max_new_tokens=32, deadline_s=0.5)
    with pytest.raises(ValueError, match="not both"):
        door.submit(rng.integers(0, cfg.vocab, 5, dtype=np.int32),
                    deadline_s=0.5, deadline_ticks=3)
    _stream_all(door, [rid])
    req = door.result(rid)
    assert req.deadline_ticks == 2
    # the deadline bit: the request was truncated or finished inside it
    assert req.deadline_hit or req.finished_step >= 0


# ---------------------------------------------------------------------------
# disaggregation: prefill engine -> transfer queue -> decode engine
# ---------------------------------------------------------------------------


def _disagg(cfg, params, decode_slots=2, **kw):
    return DisaggController(
        _paged(cfg, params, max_slots=1, **kw),
        _paged(cfg, params, max_slots=decode_slots, **kw))


def test_disagg_parity_xla_seeded(model):
    """Mandated: disaggregated prefill->decode serving is bit-identical
    to the colocated synchronous trace — the handoff serializes block
    contents through the pool, and the first token (sampled on the
    prefill side) uses the same (seed, rid, n) key."""
    cfg, params = model
    kw = dict(temperature=0.9)
    ref = _sync_ref(cfg, params, (5, 9, 7, 12), seed=7, **kw)
    ctl = _disagg(cfg, params, **kw)
    reqs = _reqs(cfg, (5, 9, 7, 12))
    ctl.generate(reqs, seed=7)
    assert [r.generated for r in reqs] == ref
    assert ctl.xfer.counters["prefixes_transferred"] == 4
    assert ctl.xfer.counters["payload_bytes"] > 0
    # both pools drain clean
    assert ctl.decode_engine.pool.live_blocks() == 0


def test_disagg_parity_bitstopper_fused(model):
    """BitStopper fused decode on the decode instance: the kq bit-planes
    are rebuilt from transferred K rows + merged amax at insert, bit-
    identical to the incrementally-written planes."""
    cfg, params = model
    cfgb = cfg.replace(attn_impl="bitstopper_xla",
                       bitstopper=BitStopperConfig(alpha=0.8))
    kw = dict(fused_decode=True)
    ref = _sync_ref(cfgb, params, (5, 11, 7), **kw)
    ctl = _disagg(cfgb, params, **kw)
    reqs = _reqs(cfgb, (5, 11, 7))
    ctl.generate(reqs, seed=0)
    assert [r.generated for r in reqs] == ref


def test_disagg_through_door_streams_bitident(model):
    """The DisaggController behind the AsyncFrontDoor: streamed tokens
    across the two-instance handoff == the synchronous colocated run."""
    cfg, params = model
    ref = _sync_ref(cfg, params, (5, 9, 7))
    door = AsyncFrontDoor(_disagg(cfg, params), seed=0)
    door.start()
    rng = np.random.default_rng(0)
    rids = [door.submit(rng.integers(0, cfg.vocab, L, dtype=np.int32),
                        max_new_tokens=5)
            for L in (5, 9, 7)]
    assert _stream_all(door, rids) == ref


def test_disagg_sampling_config_must_agree(model):
    """The first token samples on the prefill engine — mismatched
    sampling config across the instances would silently change tokens,
    so the controller refuses to build."""
    cfg, params = model
    with pytest.raises(ValueError, match="temperature"):
        DisaggController(_paged(cfg, params, max_slots=1),
                         _paged(cfg, params, temperature=0.9))
    eng = _paged(cfg, params)
    with pytest.raises(ValueError, match="distinct"):
        DisaggController(eng, eng)


def test_transfer_queue_requires_detached():
    q = TransferQueue()
    attached = type("P", (), {"payload": None})()
    with pytest.raises(ValueError, match="DETACHED"):
        q.put(attached, 1)


# ---------------------------------------------------------------------------
# graceful shutdown: drain + snapshot/restore losslessness
# ---------------------------------------------------------------------------


def test_shutdown_refuses_new_submissions(model):
    cfg, params = model
    door = AsyncFrontDoor(_paged(cfg, params), seed=0)
    door.start()
    door.shutdown("drain")
    with pytest.raises(RuntimeError, match="shutting down"):
        door.submit(np.zeros(4, np.int32))
    with pytest.raises(ValueError, match="mode"):
        door.shutdown("now")


def test_snapshot_shutdown_restore_lossless(model, tmp_path):
    """Mandated: SIGTERM-style snapshot shutdown mid-flight, then a fresh
    door restores and the reattached streams replay every token already
    served before continuing — the full stream equals the undisturbed
    synchronous trace."""
    cfg, params = model
    ref = _sync_ref(cfg, params, (5, 9, 7))
    snap = str(tmp_path / "snap")

    door = AsyncFrontDoor(_paged(cfg, params), snapshot_dir=snap, seed=0)
    assert door.start() is False
    rng = np.random.default_rng(0)
    rids, partial = [], {}

    async def phase1():
        for L in (5, 9, 7):
            rid = door.submit(rng.integers(0, cfg.vocab, L, np.int32),
                              max_new_tokens=5)
            rids.append(rid)
            partial[rid] = []

        async def collect(rid):
            async for tok in door.stream(rid):
                partial[rid].append(tok)

        task = asyncio.create_task(door.run())
        collectors = [asyncio.create_task(collect(r)) for r in rids]
        for _ in range(200):
            await asyncio.sleep(0)
            if any(len(v) >= 2 for v in partial.values()):
                break
        door.shutdown("snapshot")
        await task
        await asyncio.gather(*collectors)

    asyncio.run(phase1())
    assert door.interrupted                      # stopped mid-flight
    assert any(partial.values())                 # ...with tokens streamed

    door2 = AsyncFrontDoor(_paged(cfg, params), snapshot_dir=snap, seed=0)
    assert door2.start() is True and door2.restored

    async def phase2():
        task = asyncio.create_task(door2.run())

        async def collect(rid):
            return [tok async for tok in door2.stream(rid)]

        gathered = asyncio.gather(*(collect(r) for r in rids))
        door2.shutdown("drain")
        toks = await gathered
        await task
        return toks

    full = asyncio.run(phase2())
    assert full == ref                           # lossless end-to-end
    for rid, seen in partial.items():            # replay covers phase 1
        assert full[rids.index(rid)][:len(seen)] == seen


def test_snapshot_dir_requires_snapshot_backend(model):
    cfg, params = model
    with pytest.raises(ValueError, match="snapshot-capable"):
        AsyncFrontDoor(_disagg(cfg, params), snapshot_dir="/tmp/x")


# ---------------------------------------------------------------------------
# capacity errors surface as the retryable type
# ---------------------------------------------------------------------------


def test_prefill_insufficient_blocks_is_retryable(model):
    """A pool that is FULL RIGHT NOW (but large enough in principle)
    raises InsufficientBlocks from prefill() — retryable, capacity
    returns as live requests drain — distinct from the permanent
    validation ValueError for a request that could never fit."""
    cfg, params = model
    eng = _paged(cfg, params, pool_blocks=6)   # capacity 5 usable blocks
    eng.begin(0)
    r1, r2 = _reqs(cfg, (12, 26), max_new=12)
    eng.insert(eng.prefill(r1), 0)      # commits 3 of the 5 blocks
    with pytest.raises(InsufficientBlocks):
        eng.prefill(r2)                 # needs 4 ctx blocks, 2 free
    # permanent impossibility is a ValueError, not the retryable type
    (huge,) = _reqs(cfg, (40,), max_new=16)
    with pytest.raises(ValueError, match="pool"):
        eng.prefill(huge)
