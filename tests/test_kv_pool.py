"""Unit tests for the paged KV-cache block allocator (host-side half of
the paged serving cache): free-list lifecycle, refcounted sharing, the
prefix registry with LRU resurrection, reservation accounting, and the
host-side swap/spill tier (``SwapPool``)."""

import pytest

from repro.serving.kv_pool import KVBlockPool, SwapPool


def test_null_block_reserved():
    pool = KVBlockPool(4, 8)
    got = {pool.alloc() for _ in range(3)}
    assert 0 not in got
    assert got == {1, 2, 3}
    with pytest.raises(RuntimeError):
        pool.alloc()


def test_alloc_free_cycle_returns_blocks():
    pool = KVBlockPool(5, 8)
    bids = [pool.alloc() for _ in range(4)]
    assert pool.live_blocks() == 4 and pool.available() == 0
    for b in bids:
        pool.decref(b)
    assert pool.live_blocks() == 0
    assert pool.available() == pool.capacity == 4
    # freed blocks are allocatable again
    again = [pool.alloc() for _ in range(4)]
    assert sorted(again) == sorted(bids)


def test_refcounted_sharing():
    pool = KVBlockPool(4, 8)
    b = pool.alloc()
    pool.register((1, 2), b)
    assert pool.lookup((1, 2)) == b          # second ref
    pool.decref(b)
    assert pool.live_blocks() == 1           # still held by the sharer
    pool.decref(b)
    assert pool.live_blocks() == 0


def test_lookup_miss_and_disabled():
    pool = KVBlockPool(4, 8)
    assert pool.lookup((9,)) is None
    off = KVBlockPool(4, 8, prefix_sharing=False)
    b = off.alloc()
    off.register((1,), b)
    assert off.lookup((1,)) is None


def test_registered_block_parks_and_resurrects():
    pool = KVBlockPool(4, 8)
    b = pool.alloc()
    pool.register((7, 8), b)
    pool.decref(b)
    # Parked, not freed: still counted available, resurrectable by key.
    assert pool.live_blocks() == 0 and pool.available() == 3
    assert pool.lookup((7, 8)) == b
    assert pool.live_blocks() == 1
    pool.decref(b)


def test_lru_eviction_of_parked_blocks():
    pool = KVBlockPool(3, 8)
    a, b = pool.alloc(), pool.alloc()
    pool.register(("a",), a)
    pool.register(("b",), b)
    pool.decref(a)                           # parked first -> LRU victim
    pool.decref(b)
    c = pool.alloc()                         # free list empty: evicts a
    assert c == a
    assert pool.lookup(("a",)) is None       # deregistered on eviction
    assert pool.lookup(("b",)) == b          # survivor still resurrectable


def test_reservation_accounting():
    pool = KVBlockPool(5, 8)
    pool.reserve(3)
    assert pool.available() == 1
    with pytest.raises(RuntimeError):
        pool.reserve(2)
    b = pool.alloc(reserved=True)            # consumes one reservation unit
    assert pool.available() == 1
    pool.cancel_reservation(2)
    assert pool.available() == 3
    with pytest.raises(RuntimeError):
        pool.cancel_reservation(1)           # nothing outstanding
    pool.decref(b)


def test_peak_tracking():
    pool = KVBlockPool(6, 8)
    bids = [pool.alloc() for _ in range(3)]
    for b in bids:
        pool.decref(b)
    pool.alloc()
    assert pool.peak_live_blocks == 3


def test_rollback_returns_blocks_and_restores_reservation():
    """Speculative tail rollback: blocks return to the free list, their
    refcount entries vanish, and the reservation units they were claimed
    from are re-created atomically."""
    pool = KVBlockPool(4, 8)                 # capacity 3, fully reserved
    pool.reserve(3)
    spec = [pool.alloc(reserved=True) for _ in range(3)]
    assert pool.available() == 0 and pool.live_blocks() == 3
    pool.rollback(spec[1:])
    # two blocks free again, two reservation units back outstanding
    assert pool.live_blocks() == 1
    assert pool.available() == 0             # freed capacity re-reserved
    # rolled-back blocks are allocatable again under the reservation
    again = [pool.alloc(reserved=True) for _ in range(2)]
    assert set(again) == set(spec[1:])
    for b in [spec[0]] + again:
        pool.decref(b)
    assert pool.available() == pool.capacity


def test_rollback_refuses_shared_blocks():
    """A refcount > 1 block is mapped by another table; a registered block
    is a published prompt prefix — rolling either back would cross the
    prefix-shared boundary, so the pool refuses."""
    pool = KVBlockPool(5, 8)
    shared = pool.alloc()
    pool.incref(shared)
    with pytest.raises(RuntimeError):
        pool.rollback([shared])
    reg = pool.alloc()
    pool.register((1, 2), reg)
    with pytest.raises(RuntimeError):
        pool.rollback([reg])
    # both untouched
    assert pool.live_blocks() == 2
    assert pool.lookup((1, 2)) == reg
    pool.decref(reg)                         # drop the lookup ref
    # Atomicity: a mixed list with one bad bid mutates NOTHING — the good
    # scratch block stays live and no reservation unit appears.
    scratch = pool.alloc()
    avail = pool.available()
    with pytest.raises(RuntimeError):
        pool.rollback([scratch, shared])
    assert pool._ref[scratch] == 1
    assert pool.available() == avail


def test_rollback_unreserved_frees_without_earmarking():
    """A draft block claimed from oversubscribed *spare* capacity rolls
    back with ``reserve=False``: the block frees outright and no phantom
    reservation appears — the spare capacity stays shared."""
    pool = KVBlockPool(4, 8)                 # capacity 3, nothing reserved
    b = pool.alloc()                         # unreserved spare-capacity claim
    assert pool.available() == 2
    pool.rollback([b], reserve=False)
    assert pool.live_blocks() == 0
    assert pool.available() == 3             # back to fully shared
    # the same guards still apply
    shared = pool.alloc()
    pool.incref(shared)
    with pytest.raises(RuntimeError):
        pool.rollback([shared], reserve=False)


def test_rollback_then_realloc_is_clean():
    """A rolled-back block re-enters circulation like any freed block:
    fresh refcount 1, no registry residue."""
    pool = KVBlockPool(2, 8)                 # single allocatable block
    pool.reserve(1)
    b = pool.alloc(reserved=True)
    pool.rollback([b])
    c = pool.alloc(reserved=True)
    assert c == b
    pool.decref(c)
    assert pool.available() == pool.capacity


def test_unreserved_alloc_respects_reservations():
    """An unreserved alloc must never consume capacity another request
    reserved: with every free block spoken for, only reserved claims
    succeed — the guarantee oversubscribed claiming leans on when it
    checks ``available()`` before allocating without a reservation."""
    pool = KVBlockPool(4, 8)                 # capacity 3
    pool.reserve(3)
    with pytest.raises(RuntimeError):
        pool.alloc()
    b = pool.alloc(reserved=True)            # reserved claims still work
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.decref(b)
    pool.cancel_reservation(2)
    assert pool.alloc() in (1, 2, 3)         # spare capacity: unreserved ok


def test_preempt_returns_blocks_without_reservation():
    """Preemption frees a victim's exclusive blocks WITHOUT re-creating
    reservation units (contrast rollback): the freed capacity is exactly
    what the preemption hands to other requests.  Accounting balances —
    available() grows by the freed count."""
    pool = KVBlockPool(5, 8)                 # capacity 4
    pool.reserve(4)
    victim = [pool.alloc(reserved=True) for _ in range(3)]
    assert pool.available() == 0             # 3 live + 1 outstanding unit
    pool.preempt(victim[1:])
    assert pool.live_blocks() == 1
    assert pool.available() == 2             # freed, NOT re-reserved
    # the survivor unit + freed capacity are claimable again
    got = [pool.alloc(reserved=True), pool.alloc(), pool.alloc()]
    assert sorted(got) == sorted(victim[1:] + [4])
    for b in [victim[0]] + got:
        pool.decref(b)
    assert pool.available() == pool.capacity


def test_preempt_refuses_shared_and_registered_blocks():
    """Shared (refcount > 1) and registered prefix blocks must outlive a
    preemption — the scheduler decrefs them instead.  A mixed list with
    one bad bid mutates nothing (validate-before-mutate)."""
    pool = KVBlockPool(6, 8)
    shared = pool.alloc()
    pool.incref(shared)
    with pytest.raises(RuntimeError):
        pool.preempt([shared])
    reg = pool.alloc()
    pool.register((3, 4), reg)
    with pytest.raises(RuntimeError):
        pool.preempt([reg])
    assert pool.live_blocks() == 2
    assert pool.lookup((3, 4)) == reg        # registry intact
    pool.decref(reg)                         # drop the lookup ref
    scratch = pool.alloc()
    avail = pool.available()
    with pytest.raises(RuntimeError):
        pool.preempt([scratch, shared])
    assert pool.refcount(scratch) == 1       # untouched by the refusal
    assert pool.available() == avail


def test_preempted_registered_block_parks_for_resume():
    """The resume-for-free path: a victim's registered prefix block is
    decref'd (not preempted) and parks in the LRU — a later lookup under
    the same chain key resurrects it with its content intact."""
    pool = KVBlockPool(4, 8)
    b = pool.alloc()
    pool.register((1, 2, 3), b)
    assert pool.refcount(b) == 1 and pool.is_registered(b)
    pool.decref(b)                           # the victim's reference
    assert pool.refcount(b) == 0
    assert pool.available() == 3             # parked blocks stay claimable
    assert pool.lookup((1, 2, 3)) == b       # resume re-maps for free
    pool.decref(b)


def test_constructor_validation():
    with pytest.raises(ValueError):
        KVBlockPool(1, 8)
    with pytest.raises(ValueError):
        KVBlockPool(4, 0)


def test_saturation_counts_live_and_reserved():
    """saturation() = 1 - available/capacity: live blocks AND outstanding
    reservations both count as committed — the load-shedding watermark
    signal (docs/robustness.md)."""
    pool = KVBlockPool(5, 8)                 # capacity 4
    assert pool.saturation() == 0.0
    b = pool.alloc()
    assert pool.saturation() == pytest.approx(0.25)
    pool.reserve(2)                          # promised, not yet in use
    assert pool.saturation() == pytest.approx(0.75)
    pool.cancel_reservation(2)
    pool.decref(b)
    assert pool.saturation() == 0.0
    # A parked (registered, refcount-0) block is still available capacity.
    c = pool.alloc()
    pool.register((1,), c)
    pool.decref(c)
    assert pool.saturation() == 0.0


def test_alloc_evict_cb_fires_before_steal():
    """LRU-stealing a parked registered block fires ``evict_cb(key, bid)``
    exactly once, before the new owner exists — the downstream spill
    hook's only chance to copy the device content out."""
    fired = []
    pool = KVBlockPool(3, 8, evict_cb=lambda k, b: fired.append((k, b)))
    a, b = pool.alloc(), pool.alloc()
    pool.register(("a",), a)
    pool.register(("b",), b)
    pool.decref(a)
    pool.decref(b)
    c = pool.alloc()                         # free list empty: steals a
    assert c == a and fired == [(("a",), a)]
    # a plain free-list alloc never fires the hook
    pool.lookup(("b",))                      # resurrect b (refcount 1)
    pool.decref(b)
    pool.decref(c)
    d = pool.alloc()                         # free list holds c: no steal
    assert d == c and len(fired) == 1


def test_registered_items_enumerates_the_registry():
    pool = KVBlockPool(4, 8)
    assert pool.registered_items() == []
    a, b = pool.alloc(), pool.alloc()
    pool.register((5, 6), b)
    pool.register((1, 2), a)
    assert pool.registered_items() == [((1, 2), a), ((5, 6), b)]  # sorted
    pool.decref(a)                           # parked blocks still listed
    assert pool.registered_items() == [((1, 2), a), ((5, 6), b)]


def test_swap_pool_put_get_take_lru_order():
    sp = SwapPool(budget_bytes=100)
    assert sp.put("x", {"v": 1}, 40)
    assert sp.put("y", {"v": 2}, 40)
    assert sp.bytes_used == 80 and sp.put_count == 2
    assert sp.get("x") == {"v": 1}           # peek + LRU touch
    assert [k for k, _ in sp.items()] == ["y", "x"]   # oldest first
    assert sp.take("y") == {"v": 2}          # pop
    assert sp.bytes_used == 40
    assert sp.take("y") is None and sp.get("nope") is None
    sp.drop("x")
    assert sp.bytes_used == 0 and sp.peak_bytes == 80


def test_swap_pool_replace_same_key_reaccounts():
    sp = SwapPool(budget_bytes=100)
    assert sp.put("k", {"v": 1}, 60)
    assert sp.put("k", {"v": 2}, 30)         # replace, not additive
    assert sp.bytes_used == 30
    assert sp.get("k") == {"v": 2}


def test_swap_pool_refuses_over_budget_without_evict_cb():
    """Policy 1 (engine swap tier): a put that does not fit is refused —
    the caller falls back to recompute; nothing is half-stored."""
    sp = SwapPool(budget_bytes=100)
    assert sp.put("a", {}, 70)
    assert not sp.put("b", {}, 50)           # would exceed budget
    assert not sp.put("huge", {}, 101)       # larger than the whole budget
    assert sp.refused_count == 2
    assert sp.bytes_used == 70 and sp.get("a") == {}
    assert sp.get("b") is None


def test_swap_pool_evicts_lru_through_cb():
    """Policy 2 (host prefix tier): an over-budget put evicts
    LRU-oldest records through ``evict_cb(key, record, nbytes)`` — the
    cascade that spills host-tier prefixes on to disk."""
    spilled = []
    sp = SwapPool(budget_bytes=100,
                  evict_cb=lambda k, r, n: spilled.append((k, r, n)))
    sp.put("a", {"v": 1}, 40)
    sp.put("b", {"v": 2}, 40)
    assert sp.put("c", {"v": 3}, 40)         # evicts "a"
    assert spilled == [("a", {"v": 1}, 40)]
    assert sp.evict_count == 1 and sp.bytes_used == 80
    sp.get("b")                              # touch: "c" becomes LRU-oldest
    assert sp.put("d", {"v": 4}, 80)         # evicts "c" then "b"
    assert [k for k, _, _ in spilled] == ["a", "c", "b"]
    assert sp.bytes_used == 80
    # a record larger than the whole budget still refuses (nothing to
    # evict could ever make it fit)
    assert not sp.put("huge", {}, 101)
    assert sp.refused_count == 1


def test_snapshot_is_plain_json_and_faithful():
    """pool.snapshot() is the allocator's contribution to the engine crash
    snapshot: JSON-serializable plain data mirroring the full state."""
    import json

    pool = KVBlockPool(6, 8, prefix_sharing=True)
    a, b = pool.alloc(), pool.alloc(reserved=False)
    pool.register((1, 2), a)
    pool.incref(a)
    pool.reserve(2)
    pool.decref(b)

    snap = pool.snapshot()
    assert snap == json.loads(json.dumps(snap))   # round-trips as JSON
    assert snap["pool_blocks"] == 6 and snap["page_size"] == 8
    assert snap["ref"] == {str(a): 2}
    assert snap["registry"] == [[[1, 2], a]]
    assert snap["reserved"] == 2
    assert b in snap["free"]
    assert snap["alloc_count"] == 2
    assert snap["peak_live_blocks"] == 2
    # snapshot() is read-only: the pool keeps working untouched.
    assert pool.live_blocks() == 1 and pool.available() == 2
