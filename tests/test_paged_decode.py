"""Fused paged BESF decode: kernel-vs-oracle bit-exactness on adversarial
block tables (shared prefixes, recycled blocks, mid-page fills), parity with
the dense gather path, DMA-level early termination, and the incremental
bit-plane pool's write invariants (rescale-on-demand, free/realloc)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as qlib
from repro.core.besf import (
    BitStopperConfig,
    besf_attention_decode,
    besf_attention_decode_paged,
)
from repro.kernels.paged_decode import paged_bitstopper_decode
from repro.models.attention import (
    POS_SENTINEL,
    AttnConfig,
    PagedLayout,
    _update_paged_cache,
    gather_paged_view,
    init_cache,
)

BITS = 12


def _pack_pool(k_pool, k_amax, bits=BITS):
    """One-shot packing of the whole pool (the canonical shared layout).
    The independent check is `_assert_invariant`, which unpacks to bit
    level and compares the *incrementally written* pool against this
    one-shot requant — write-path vs reference, not copy vs copy."""
    return qlib.pack_pool_planes(k_pool, k_amax, bits)


def _unpack_pool(kq):
    """uint8[P, bits, bs8, H, D] -> bit planes uint8[bits, P, bs, H, D]."""
    P, bits, bs8, H, D = kq.shape
    shifts = jnp.arange(8, dtype=jnp.uint32).reshape(1, 1, 1, 8, 1, 1)
    u = (kq.astype(jnp.uint32)[:, :, :, None] >> shifts) & 1
    return u.reshape(P, bits, bs8 * 8, H, D).astype(jnp.uint8).transpose(
        1, 0, 2, 3, 4)


def _pool_state(seed, P=9, bs=16, Hkv=2, D=16, Dv=16, spiky=False):
    rng = np.random.default_rng(seed)
    k_pool = rng.normal(size=(P, bs, Hkv, D)) * 2
    v_pool = rng.normal(size=(P, bs, Hkv, Dv))
    if spiky:
        u = rng.normal(size=D)
        u /= np.linalg.norm(u)
        k_pool *= 0.02
        k_pool[1, :, :, :] += 8.0 * u            # hot page: physical block 1
    k_pool = jnp.asarray(k_pool, jnp.float32)
    v_pool = jnp.asarray(v_pool, jnp.float32)
    k_amax = jnp.max(jnp.abs(k_pool), axis=(0, 1, 3))
    v_amax = jnp.max(jnp.abs(v_pool), axis=(0, 1, 3))
    return k_pool, v_pool, k_amax, v_amax


# ---------------------------------------------------------------------------
# kernel (interpret mode) vs pure-JAX paged oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alpha,window,G", [
    (0.2, None, 1),
    (0.6, None, 2),
    (0.8, 24, 2),
    (1.0, 16, 1),
])
def test_paged_kernel_matches_oracle(alpha, window, G):
    """Bit-exact parity on a pool with a shared-prefix block (physical
    block 1 mapped by two tables), recycled/stale blocks (7, 8 hold
    garbage from a 'finished request', unreferenced), and rows ending
    mid-page."""
    k_pool, v_pool, k_amax, v_amax = _pool_state(0)
    # Stale garbage in unreferenced blocks must be unobservable even
    # though it is LARGER than the pool amax (recycled after requant).
    k_pool = k_pool.at[8].set(50.0)
    rng = np.random.default_rng(1)
    Hkv = k_pool.shape[2]
    Hq = Hkv * G
    table = jnp.asarray([[1, 2, 3, 4], [1, 5, 6, 0], [7, 3, 0, 0]],
                        jnp.int32)
    lengths = jnp.asarray([64, 40, 19], jnp.int32)      # row 2 mid-page
    q_pos = lengths - 1
    q = jnp.asarray(rng.normal(size=(3, Hq, k_pool.shape[-1])) * 2,
                    jnp.float32)
    cfg = BitStopperConfig(alpha=alpha)
    kq_pool = _pack_pool(k_pool, k_amax)

    ora = besf_attention_decode_paged(q, k_pool, v_pool, table, lengths,
                                      q_pos, k_amax, v_amax, cfg=cfg,
                                      window=window)
    ker = paged_bitstopper_decode(q, kq_pool, v_pool, table, lengths,
                                  q_pos, k_amax, v_amax, cfg=cfg,
                                  window=window, interpret=True)
    np.testing.assert_array_equal(np.asarray(ora.rounds),
                                  np.asarray(ker.rounds))
    np.testing.assert_array_equal(np.asarray(ora.survivors),
                                  np.asarray(ker.survivors))
    np.testing.assert_array_equal(np.asarray(ora.v_fetched),
                                  np.asarray(ker.v_fetched))
    np.testing.assert_allclose(np.asarray(ora.out), np.asarray(ker.out),
                               atol=1e-6, rtol=1e-6)
    # pages past a row's fill level are never touched: zero planes fetched
    rounds = np.asarray(ora.rounds)
    assert rounds[1, 3] == 0 and (rounds[2, 2:] == 0).all()


def test_paged_oracle_matches_dense_gather_path():
    """Against the retained dense gather path (`besf_attention_decode` on
    the gathered logical view): with a single row the pool-wide scale
    equals the per-row view scale, so the ONLY semantic difference left is
    LATS granularity — page-sequential prefix-max thresholds keep a
    superset of the global per-round reference's survivors, and the extra
    tokens carry provably negligible softmax mass."""
    k_pool, v_pool, k_amax, v_amax = _pool_state(2)
    bs = k_pool.shape[1]
    Hkv, D = k_pool.shape[2], k_pool.shape[3]
    table = jnp.asarray([[1, 2, 3]], jnp.int32)
    lengths = jnp.asarray([3 * bs], jnp.int32)
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, Hkv, D)), jnp.float32)
    # pool amax must equal the row's view amax for scale identity
    view = k_pool[table[0]].reshape(3 * bs, Hkv, D)
    k_amax = jnp.max(jnp.abs(view), axis=(0, 2))
    v_view = v_pool[table[0]].reshape(3 * bs, Hkv, D)
    v_amax = jnp.max(jnp.abs(v_view), axis=(0, 2))

    cfg = BitStopperConfig(alpha=1.0)
    paged = besf_attention_decode_paged(
        q, k_pool, v_pool, table, lengths, lengths - 1, k_amax, v_amax,
        cfg=cfg)
    # dense gather reference: head-major repeated-KV layout, per-(row,
    # head) view quantization — exactly what _cached_attention dispatches
    kr = view.transpose(1, 0, 2)[None]                  # [1, Hkv, Tv, D]
    vr = v_view.transpose(1, 0, 2)[None]
    ref = besf_attention_decode(q[:, :, None, :], kr, vr, cfg=cfg)
    # paged survivors must be a superset of the reference's (prefix-max
    # thresholds are conservative — they only ever keep MORE)
    s_paged = np.asarray(paged.survivors)[0]            # [Hq, Tv]
    s_ref = np.asarray(ref.stats.survivors)[0, :, 0]    # [Hq, Tv]
    assert (s_paged | ~s_ref.astype(bool)).all()
    # outputs agree up to the LATS guarantee: any survivor-set slack
    # carries softmax mass < e^{-alpha*radius} per token (~6.7e-3 here)
    np.testing.assert_allclose(np.asarray(paged.out)[0],
                               np.asarray(ref.out)[0, :, 0], atol=0.05)


def test_paged_decode_early_termination_skips_planes_and_v():
    """Spiky attention: one hot page dominates, so cold pages terminate
    after a few planes and their V is never fetched — the fused path's
    per-step traffic drops below the dense 12-plane/page floor."""
    k_pool, v_pool, k_amax, v_amax = _pool_state(4, spiky=True)
    Hkv, D = k_pool.shape[2], k_pool.shape[3]
    table = jnp.asarray([[1, 2, 3, 4, 5]], jnp.int32)
    lengths = jnp.asarray([5 * k_pool.shape[1]], jnp.int32)
    rng = np.random.default_rng(5)
    u = np.asarray(k_pool[1, 0, 0] / jnp.linalg.norm(k_pool[1, 0, 0]))
    q = jnp.asarray(8.0 * u[None, None]
                    + 0.05 * rng.normal(size=(1, Hkv, D)), jnp.float32)
    cfg = BitStopperConfig(alpha=0.4)
    kq_pool = _pack_pool(k_pool, k_amax)
    ker = paged_bitstopper_decode(q, kq_pool, v_pool, table, lengths,
                                  lengths - 1, k_amax, v_amax, cfg=cfg,
                                  interpret=True)
    ora = besf_attention_decode_paged(q, k_pool, v_pool, table, lengths,
                                      lengths - 1, k_amax, v_amax, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(ora.rounds),
                                  np.asarray(ker.rounds))
    rounds = np.asarray(ker.rounds)[0]
    vf = np.asarray(ker.v_fetched)[0]
    assert rounds[0] == cfg.bits and vf[0]              # hot page completes
    assert rounds.sum() < cfg.bits * len(rounds), rounds
    assert not vf.all(), vf                             # some V never moved


# ---------------------------------------------------------------------------
# incremental bit-plane pool: write path invariants
# ---------------------------------------------------------------------------


def _acfg(Hkv=2, D=8):
    # fused_decode=True: the packed plane pool is only maintained when the
    # fused kernel will read it (fallback decode keeps scales only).
    return AttnConfig(d_model=Hkv * D, n_heads=Hkv, n_kv_heads=Hkv,
                      head_dim=D, impl="bitstopper_xla", fused_decode=True)


def _write(cache, k, v, positions):
    return _update_paged_cache(cache, jnp.asarray(k, jnp.float32),
                               jnp.asarray(v, jnp.float32),
                               jnp.asarray(positions, jnp.int32))


def _assert_invariant(cache):
    """Planes stored in kq must equal requantizing the f32 pool under the
    current running scale, for every slot written through any table row."""
    nb, bits, bs8, H, D = cache["kq"].shape
    bs = bs8 * 8
    table = np.asarray(cache["table"])
    length = np.asarray(cache["length"])
    live = np.zeros((nb, bs), bool)
    for b in range(table.shape[0]):
        for j in range(table.shape[1]):
            n = int(np.clip(length[b] - j * bs, 0, bs))
            if table[b, j] > 0 and n > 0:
                live[table[b, j], :n] = True
    got = np.asarray(_unpack_pool(cache["kq"]))         # [bits, P, bs, H, D]
    want = np.asarray(_unpack_pool(_pack_pool(cache["k"], cache["k_amax"])))
    mask = live[None, :, :, None, None]
    np.testing.assert_array_equal(got * mask, want * mask)


def test_plane_pool_incremental_writes_and_rescale():
    """Appends keep the packed pool consistent with the f32 pool; a write
    that grows the running max-abs triggers the requant path and the
    invariant still holds (including previously written tokens)."""
    cfg = _acfg()
    cache = init_cache(cfg, batch=2, max_len=64, paged=PagedLayout(6, 8, 3))
    assert "kq" in cache and cache["kq"].shape == (6, 12, 1, 2, 8)
    cache = dict(cache, table=jnp.asarray([[1, 2, 3], [4, 5, 0]], jnp.int32))
    rng = np.random.default_rng(0)

    def toks(B, S, scale=1.0):
        return (rng.normal(size=(B, S, 2, 8)) * scale,
                rng.normal(size=(B, S, 2, 8)) * scale)

    # row 0 writes 5 tokens (mid-page), row 1 idles at the sentinel
    k, v = toks(2, 5)
    pos = np.stack([np.arange(5), np.full(5, POS_SENTINEL)])
    cache = _write(cache, k, v, pos)
    assert cache["length"].tolist() == [5, 0]
    _assert_invariant(cache)
    amax0 = np.asarray(cache["k_amax"]).copy()

    # append 6 more to row 0 (crosses a page boundary), 3 to row 1
    k, v = toks(2, 6, scale=0.5)                        # no amax growth
    pos = np.stack([np.arange(5, 11),
                    np.concatenate([np.arange(3), [POS_SENTINEL] * 3])])
    cache = _write(cache, k, v, pos)
    assert cache["length"].tolist() == [11, 3]
    np.testing.assert_array_equal(np.asarray(cache["k_amax"]), amax0)
    _assert_invariant(cache)

    # a loud token grows the scale -> whole-pool requant, old tokens too
    k, v = toks(2, 1, scale=20.0)
    pos = np.asarray([[11], [POS_SENTINEL]])
    cache = _write(cache, k, v, pos)
    assert (np.asarray(cache["k_amax"]) > amax0).any()
    _assert_invariant(cache)


def test_plane_pool_survives_free_and_realloc():
    """A physical block freed by one request and reallocated to another
    must serve the NEW owner's planes: the write path fully overwrites the
    recycled page (low-mask merge starts at bit 0), and the paged decode
    of the new owner matches a pool that never saw the old content."""
    cfg = _acfg()
    layout = PagedLayout(4, 8, 2)
    cache = init_cache(cfg, batch=1, max_len=32, paged=layout)
    rng = np.random.default_rng(1)

    # request A fills physical blocks 1-2 through its table
    cache_a = dict(cache, table=jnp.asarray([[1, 2]], jnp.int32))
    kA = rng.normal(size=(1, 12, 2, 8))
    vA = rng.normal(size=(1, 12, 2, 8))
    cache_a = _write(cache_a, kA, vA, np.arange(12)[None])
    _assert_invariant(cache_a)

    # A finishes; B is admitted onto the SAME physical blocks (recycled),
    # with content quieter than A's (running amax must not shrink).
    cache_b = dict(cache_a, table=jnp.asarray([[2, 1]], jnp.int32),
                   length=jnp.zeros((1,), jnp.int32))
    kB = rng.normal(size=(1, 10, 2, 8)) * 0.5
    vB = rng.normal(size=(1, 10, 2, 8)) * 0.5
    cache_b = _write(cache_b, kB, vB, np.arange(10)[None])
    _assert_invariant(cache_b)

    # decode for B through the recycled pool == decode through a pristine
    # pool holding only B's content under the same running scales
    fresh = dict(init_cache(cfg, batch=1, max_len=32, paged=layout),
                 table=jnp.asarray([[2, 1]], jnp.int32),
                 k_amax=cache_a["k_amax"], v_amax=cache_a["v_amax"])
    fresh = _write(fresh, kB, vB, np.arange(10)[None])
    q = jnp.asarray(rng.normal(size=(1, 2, 8)), jnp.float32)
    args = (cache_b["table"], cache_b["length"], cache_b["length"] - 1,
            cache_b["k_amax"], cache_b["v_amax"])
    out_b = paged_bitstopper_decode(q, cache_b["kq"], cache_b["v"], *args,
                                    interpret=True)
    out_f = paged_bitstopper_decode(q, fresh["kq"], fresh["v"], *args)
    np.testing.assert_array_equal(np.asarray(out_b.out),
                                  np.asarray(out_f.out))
    np.testing.assert_array_equal(np.asarray(out_b.survivors),
                                  np.asarray(out_f.survivors))


def test_gather_view_gated_to_active_rows():
    """The on-demand gather masks inactive rows to the null block — their
    view is all-invalid — while active rows see exactly the old dense
    view semantics (zeroed past the fill level)."""
    cfg = _acfg()
    cache = init_cache(cfg, batch=2, max_len=32, paged=PagedLayout(4, 8, 2))
    cache = dict(cache, table=jnp.asarray([[1, 2], [3, 0]], jnp.int32))
    rng = np.random.default_rng(2)
    k = rng.normal(size=(2, 5, 2, 8))
    v = rng.normal(size=(2, 5, 2, 8))
    pos = np.stack([np.arange(5), np.arange(5)])
    cache = _write(cache, k, v, pos)

    kv_all = gather_paged_view(cache)
    kv_act = gather_paged_view(cache, jnp.asarray([True, False]))
    np.testing.assert_array_equal(np.asarray(kv_all[0][0]),
                                  np.asarray(kv_act[0][0]))
    assert (np.asarray(kv_act[2][1]) == POS_SENTINEL).all()
    # fill-level masking: row 0 slots past length are zero
    assert (np.asarray(kv_all[0][0][5:]) == 0).all()
