"""Property + behaviour tests for the faithful BESF algorithm and LATS.

The key invariants (hypothesis-driven):
  1. margin soundness:  lower <= exact score <= upper at every round;
  2. argmax survival:   the max-logit valid token is never pruned;
  3. exactness:         survivors' final logits equal dense INT12 logits;
  4. containment:       block-streaming keeps a superset of per-token ref;
  5. monotone traffic:  smaller alpha => fewer or equal planes fetched.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import margins as margins_lib
from repro.core import quantization as qlib
from repro.core.baselines import dense_attention
from repro.core.besf import BitStopperConfig, besf_attention
from repro.core.block_adaptation import block_bitstopper_attention


def _random_qkv(seed, Sq=8, Sk=32, d=16, spiky=True):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(Sq, d)).astype(np.float32)
    k = rng.normal(size=(Sk, d)).astype(np.float32)
    if spiky:
        for i in range(Sq):
            j = rng.integers(0, Sk)
            q[i] += 6.0 * k[j] / (np.linalg.norm(k[j]) ** 2) * np.sqrt(d)
    v = rng.normal(size=(Sk, d)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_margin_soundness(seed):
    rng = np.random.default_rng(seed)
    d = 8
    q = jnp.asarray(rng.normal(size=(4, d)) * 2, jnp.float32)
    k = jnp.asarray(rng.normal(size=(16, d)) * 2, jnp.float32)
    q_int, _ = qlib.quantize(q, 12)
    k_int, _ = qlib.quantize(k, 12)
    planes = qlib.to_bitplanes(k_int, 12)
    exact = (q_int @ k_int.T).astype(np.int64)
    m_min, m_max = margins_lib.bit_margins(q_int, 12)
    for r in range(12):
        part = np.zeros_like(np.asarray(exact))
        w = np.array([-(2 ** 11)] + [2 ** (11 - t) for t in range(1, 12)])
        for t in range(r + 1):
            part = part + w[t] * np.asarray(q_int) @ np.asarray(planes[t]).T.astype(np.int64)
        lower = part + np.asarray(m_min[r])[:, None]
        upper = part + np.asarray(m_max[r])[:, None]
        assert np.all(lower <= np.asarray(exact) + 1e-6)
        assert np.all(np.asarray(exact) <= upper + 1e-6)
        if r == 11:  # all bits seen: interval collapses
            np.testing.assert_allclose(lower, upper)
            np.testing.assert_allclose(lower, np.asarray(exact))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.2, 0.5, 0.8]))
def test_argmax_always_survives(seed, alpha):
    q, k, v = _random_qkv(seed)
    cfg = BitStopperConfig(alpha=alpha)
    res = besf_attention(q, k, v, cfg)
    # dense INT12 logits define the true argmax
    _, info = dense_attention(q, k, v)
    arg = jnp.argmax(info["logits"], axis=-1)
    surv_at_arg = jnp.take_along_axis(res.stats.survivors, arg[:, None], axis=-1)
    assert bool(jnp.all(surv_at_arg))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_survivor_logits_exact(seed):
    """Stage fusion: a survivor's logit equals the dense INT12 logit exactly."""
    q, k, v = _random_qkv(seed)
    res = besf_attention(q, k, v, BitStopperConfig(alpha=0.6))
    _, info = dense_attention(q, k, v)
    surv = np.asarray(res.stats.survivors)
    np.testing.assert_allclose(
        np.asarray(res.scores)[surv], np.asarray(info["logits"])[surv], rtol=1e-6
    )


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_block_keeps_superset(seed):
    """Streaming prefix-max thresholds are conservative vs per-token ref."""
    q, k, v = _random_qkv(seed, Sq=8, Sk=32, d=16)
    cfg = BitStopperConfig(alpha=0.6)
    ref = besf_attention(q, k, v, cfg)
    blk = block_bitstopper_attention(q, k, v, cfg, block_q=4, block_k=8)
    assert bool(jnp.all(ref.stats.survivors <= blk.stats.survivors))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_alpha_monotone_traffic(seed):
    q, k, v = _random_qkv(seed)
    prev = None
    for alpha in (0.2, 0.5, 0.8):
        res = besf_attention(q, k, v, BitStopperConfig(alpha=alpha))
        tot = int(res.stats.planes_fetched.sum())
        if prev is not None:
            assert tot >= prev  # larger alpha keeps more -> fetches more
        prev = tot


def test_probs_normalized_over_survivors():
    q, k, v = _random_qkv(3)
    res = besf_attention(q, k, v, BitStopperConfig(alpha=0.6))
    sums = np.asarray(res.probs.sum(-1))
    np.testing.assert_allclose(sums, 1.0, atol=1e-5)
    assert np.all(np.asarray(res.probs)[~np.asarray(res.stats.survivors)] == 0)


def test_causal_mask_respected():
    q, k, v = _random_qkv(4, Sq=16, Sk=16)
    res = besf_attention(q, k, v, BitStopperConfig(alpha=0.8), causal=True)
    probs = np.asarray(res.probs)
    assert np.all(np.triu(probs, k=1) == 0)
    assert np.all(np.isfinite(np.asarray(res.out)))
    # planes are never fetched for masked-out (invalid) pairs
    fetched = np.asarray(res.stats.planes_fetched)
    assert np.all(np.triu(fetched, k=1) == 0)


def test_alpha_zero_keeps_only_near_max():
    """alpha=0: threshold == max lower bound -> minimal survivors."""
    q, k, v = _random_qkv(5)
    res0 = besf_attention(q, k, v, BitStopperConfig(alpha=0.0))
    res1 = besf_attention(q, k, v, BitStopperConfig(alpha=1.0))
    assert int(res0.stats.survivors.sum()) <= int(res1.stats.survivors.sum())
    assert int(res0.stats.survivors.sum()) >= q.shape[0]  # argmax per row


def test_batched_matches_loop():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 2, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 16, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 16, 16)), jnp.float32)
    cfg = BitStopperConfig(alpha=0.6)
    batched = besf_attention(q, k, v, cfg)
    for b in range(2):
        for h in range(2):
            single = besf_attention(q[b, h], k[b, h], v[b, h], cfg)
            np.testing.assert_allclose(
                np.asarray(batched.out[b, h]), np.asarray(single.out), rtol=2e-5, atol=2e-6
            )


def test_decode_shape_single_query():
    q, k, v = _random_qkv(9, Sq=1, Sk=64, d=32)
    res = besf_attention(q, k, v, BitStopperConfig(alpha=0.6))
    assert res.out.shape == (1, 32)
    assert bool(jnp.all(jnp.isfinite(res.out)))
